"""Speculative decoding benchmark: draft/verify vs the plain engine.

Trains the benchmark tiny LM (so greedy argmax is peaked — a random
init makes every compressed draft disagree and acceptance collapses to
noise), compresses MPIFA drafts at a sweep of densities, and measures:

  * accepted draft tokens per verify dispatch (the paper-level win:
    tokens/dispatch > 1 means the density dial bought real speedup
    headroom — plain decode is pinned at exactly 1),
  * acceptance rate (how often the cheap draft matches the target),
  * wall-clock tokens/s vs the single-dispatch engine (CPU container
    numbers: the draft here costs the same dispatch overhead as the
    target, so tokens/s gains need real accelerator asymmetry — the
    accounting columns are the portable result),
  * greedy bit-identity against plain engine generation (hard fail if
    it ever diverges).

Two further blocks lock down the ISSUE-4 surface:

  * **families**: mamba2 (SSM), zamba2 (hybrid) and gemma3 (ring-cache)
    smoke targets run greedy draft/verify through the per-step
    state-checkpoint rollback path — bit-identity is a hard gate, and
    the identical-weights draft must beat 1 token/verify-dispatch;
  * **sampled scheduler slots**: temperature/top-k speculative
    scheduler slots must reproduce the batch-1
    ``engine.generate_speculative`` stream of each request's key
    (``spec_request_key``) — also a hard gate.

Writes machine-readable ``BENCH_spec.json``.

  PYTHONPATH=src python benchmarks/spec_bench.py
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import BENCH_CFG, calib_tokens, emit, trained_tiny  # noqa: E402

from repro.configs.base import get_smoke_config  # noqa: E402
from repro.core.mpifa import MpifaConfig, compress_transformer  # noqa: E402
from repro.launch.serve import compress_generic  # noqa: E402
from repro.models.model import build_model  # noqa: E402
from repro.runtime.engine import GenerationEngine  # noqa: E402
from repro.runtime.scheduler import Request, ServingScheduler  # noqa: E402

DRAFT_DENSITIES = (0.8, 0.6, 0.4)
FAMILY_ARCHS = ("mamba2_2p7b", "zamba2_1p2b", "gemma3_12b")


def bench_families(max_new: int, spec_k: int, seed: int) -> dict:
    """Greedy draft/verify for the checkpoint-rollback families: SSM,
    hybrid, ring.  Hard-fails on any bit-identity divergence; returns
    per-family rows for identical and compressed drafts."""
    rows = {}
    rng = np.random.default_rng(seed)
    for arch in FAMILY_ARCHS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))
        prompts = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
        k = min(spec_k, cfg.sliding_window - 1) if cfg.sliding_window \
            else spec_k
        eng = GenerationEngine(model)
        ref = eng.generate(params, prompts, max_new)
        arch_rows = []
        for dlabel, dparams in (
                ("identical", params),
                ("pifa_0.5", compress_generic(model, params, 0.5))):
            res = eng.generate_speculative(params, dparams, prompts,
                                           max_new, spec_k=k)
            exact = bool(jnp.all(res.tokens == ref.tokens))
            if not exact:
                raise SystemExit(
                    f"{arch}/{dlabel}: speculative greedy output "
                    "diverged from plain engine generation")
            row = {
                "draft": dlabel, "spec_k": k,
                "acceptance_rate": round(res.acceptance_rate, 3),
                "emitted_per_dispatch": round(res.emitted_per_dispatch,
                                              3),
                "verify_dispatches": res.rounds,
                "bit_identical_greedy": exact,
            }
            arch_rows.append(row)
            emit(f"spec/{arch}/{dlabel}/k{k}", 0.0,
                 f"accept {row['acceptance_rate']} "
                 f"emit/disp {row['emitted_per_dispatch']}")
        if arch_rows[0]["emitted_per_dispatch"] <= 1.0:
            raise SystemExit(
                f"{arch}: identical-weights draft failed to beat 1 "
                "token/verify-dispatch — checkpoint rollback is eating "
                "accepted runs")
        rows[arch] = arch_rows
    return rows


def bench_sampled_scheduler(model, params, draft, *, spec_k: int,
                            seed: int) -> dict:
    """Sampled speculative scheduler slots vs per-request engine
    streams (the sampled-slot key-threading contract).  Hard-fails on
    any stream divergence."""
    temperature, top_k = 0.8, 4
    rng = np.random.default_rng(seed + 1)
    reqs = [Request(request_id=i,
                    prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m))
            for i, (l, m) in enumerate(zip((12, 16, 9, 14),
                                           (16, 10, 14, 12)))]
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,),
                             cache_len=16 + 16 + spec_k + 1,
                             draft_params=draft, spec_k=spec_k,
                             temperature=temperature, top_k=top_k,
                             sample_seed=seed)
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = reqs[r.request_id]
        ref = eng.generate_speculative(
            params, draft, jnp.asarray(req.prompt[None, :]), req.max_new,
            spec_k=spec_k, temperature=temperature, top_k=top_k,
            key=sched.spec_request_key(req.request_id))
        if not np.array_equal(r.tokens, np.asarray(ref.tokens[0])):
            raise SystemExit(
                f"sampled scheduler slot {r.request_id} diverged from "
                "the batch-1 engine stream for its request key")
    row = {
        "temperature": temperature, "top_k": top_k, "spec_k": spec_k,
        "requests": len(reqs),
        "acceptance_rate": round(run.acceptance_rate, 3),
        "matches_engine_streams": True,
    }
    emit(f"spec/scheduler_sampled/k{spec_k}", 0.0,
         f"accept {row['acceptance_rate']} streams match engine")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--spec-k", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--target-density", type=float, default=0.7,
                    help="PIFA target variant's MPIFA density")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args(argv)

    model, params = trained_tiny(steps=args.train_steps, seed=args.seed)
    calib = calib_tokens()
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, BENCH_CFG.vocab_size,
                     (args.batch, args.prompt_len)), jnp.int32)
    engine = GenerationEngine(model)

    drafts = {}
    for dd in DRAFT_DENSITIES:
        t0 = time.time()
        drafts[dd] = compress_transformer(model, params, calib,
                                          MpifaConfig(density=dd))
        print(f"[spec_bench] draft density {dd} compressed in "
              f"{time.time()-t0:.1f}s", flush=True)
    target_pifa = compress_transformer(
        model, params, calib, MpifaConfig(density=args.target_density))

    report = {
        "config": {
            "model": BENCH_CFG.name,
            "train_steps": args.train_steps,
            "batch": args.batch,
            "prompt_len": args.prompt_len,
            "max_new": args.max_new,
            "spec_k": list(args.spec_k),
            "draft_densities": list(DRAFT_DENSITIES),
            "target_density": args.target_density,
            "seed": args.seed,
            "backend": jax.default_backend(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "targets": {},
    }

    best_emitted = 0.0
    for tlabel, tparams in (("dense", params), ("pifa", target_pifa)):
        ref = engine.generate(tparams, prompts, args.max_new)
        # warm plain-engine rerun for an honest tokens/s baseline
        ref = engine.generate(tparams, prompts, args.max_new)
        rows = {"plain_tokens_per_sec": round(ref.tokens_per_sec, 1),
                "spec": []}
        for dd in DRAFT_DENSITIES:
            for k in args.spec_k:
                res = engine.generate_speculative(
                    tparams, drafts[dd], prompts, args.max_new, spec_k=k)
                exact = bool(jnp.all(res.tokens == ref.tokens))
                if not exact:
                    raise SystemExit(
                        f"{tlabel}/draft{dd}/k{k}: speculative greedy "
                        "output diverged from plain engine generation")
                row = {
                    "draft_density": dd,
                    "spec_k": k,
                    "tokens_per_sec": round(res.tokens_per_sec, 1),
                    "speedup_vs_plain": round(
                        res.tokens_per_sec / max(ref.tokens_per_sec, 1e-9),
                        3),
                    "acceptance_rate": round(res.acceptance_rate, 3),
                    "accepted_per_dispatch": round(
                        res.accepted / max(res.alive_rounds, 1), 3),
                    "emitted_per_dispatch": round(
                        res.emitted_per_dispatch, 3),
                    "verify_dispatches": res.rounds,
                    "bit_identical_greedy": exact,
                }
                rows["spec"].append(row)
                best_emitted = max(best_emitted,
                                   row["emitted_per_dispatch"])
                emit(f"spec/{tlabel}/d{dd}/k{k}",
                     0.0,
                     f"{row['tokens_per_sec']} tok/s "
                     f"accept {row['acceptance_rate']} "
                     f"emit/disp {row['emitted_per_dispatch']}")
        report["targets"][tlabel] = rows

    # ---- checkpoint-rollback families (SSM / hybrid / ring): greedy
    # bit-identity is a hard gate, identical draft must beat 1 tok/disp
    report["families"] = bench_families(args.max_new, max(args.spec_k),
                                        args.seed)
    for arch_rows in report["families"].values():
        best_emitted = max(best_emitted,
                           max(r["emitted_per_dispatch"]
                               for r in arch_rows))

    # ---- sampled speculative scheduler slots: stream-equality with
    # the batch-1 engine per request key is a hard gate
    report["sampled_scheduler"] = bench_sampled_scheduler(
        model, params, drafts[0.6], spec_k=min(args.spec_k),
        seed=args.seed)

    report["best_emitted_per_dispatch"] = best_emitted
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[spec_bench] wrote {out} "
          f"(best emitted/dispatch {best_emitted})", flush=True)
    if best_emitted <= 1.0:
        print("[spec_bench] WARNING: no draft beat 1 token/dispatch",
              flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
