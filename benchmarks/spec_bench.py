"""Speculative decoding benchmark: draft/verify vs the plain engine.

Trains the benchmark tiny LM (so greedy argmax is peaked — a random
init makes every compressed draft disagree and acceptance collapses to
noise), compresses MPIFA drafts at a sweep of densities, and measures:

  * accepted draft tokens per verify dispatch (the paper-level win:
    tokens/dispatch > 1 means the density dial bought real speedup
    headroom — plain decode is pinned at exactly 1),
  * acceptance rate (how often the cheap draft matches the target),
  * wall-clock tokens/s vs the single-dispatch engine (CPU container
    numbers: the draft here costs the same dispatch overhead as the
    target, so tokens/s gains need real accelerator asymmetry — the
    accounting columns are the portable result),
  * greedy bit-identity against plain engine generation (hard fail if
    it ever diverges).

Writes machine-readable ``BENCH_spec.json``.

  PYTHONPATH=src python benchmarks/spec_bench.py
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import BENCH_CFG, calib_tokens, emit, trained_tiny  # noqa: E402

from repro.core.mpifa import MpifaConfig, compress_transformer  # noqa: E402
from repro.runtime.engine import GenerationEngine  # noqa: E402

DRAFT_DENSITIES = (0.8, 0.6, 0.4)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=64)
    ap.add_argument("--spec-k", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--train-steps", type=int, default=400)
    ap.add_argument("--target-density", type=float, default=0.7,
                    help="PIFA target variant's MPIFA density")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_spec.json")
    args = ap.parse_args(argv)

    model, params = trained_tiny(steps=args.train_steps, seed=args.seed)
    calib = calib_tokens()
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, BENCH_CFG.vocab_size,
                     (args.batch, args.prompt_len)), jnp.int32)
    engine = GenerationEngine(model)

    drafts = {}
    for dd in DRAFT_DENSITIES:
        t0 = time.time()
        drafts[dd] = compress_transformer(model, params, calib,
                                          MpifaConfig(density=dd))
        print(f"[spec_bench] draft density {dd} compressed in "
              f"{time.time()-t0:.1f}s", flush=True)
    target_pifa = compress_transformer(
        model, params, calib, MpifaConfig(density=args.target_density))

    report = {
        "config": {
            "model": BENCH_CFG.name,
            "train_steps": args.train_steps,
            "batch": args.batch,
            "prompt_len": args.prompt_len,
            "max_new": args.max_new,
            "spec_k": list(args.spec_k),
            "draft_densities": list(DRAFT_DENSITIES),
            "target_density": args.target_density,
            "seed": args.seed,
            "backend": jax.default_backend(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "targets": {},
    }

    best_emitted = 0.0
    for tlabel, tparams in (("dense", params), ("pifa", target_pifa)):
        ref = engine.generate(tparams, prompts, args.max_new)
        # warm plain-engine rerun for an honest tokens/s baseline
        ref = engine.generate(tparams, prompts, args.max_new)
        rows = {"plain_tokens_per_sec": round(ref.tokens_per_sec, 1),
                "spec": []}
        for dd in DRAFT_DENSITIES:
            for k in args.spec_k:
                res = engine.generate_speculative(
                    tparams, drafts[dd], prompts, args.max_new, spec_k=k)
                exact = bool(jnp.all(res.tokens == ref.tokens))
                if not exact:
                    raise SystemExit(
                        f"{tlabel}/draft{dd}/k{k}: speculative greedy "
                        "output diverged from plain engine generation")
                row = {
                    "draft_density": dd,
                    "spec_k": k,
                    "tokens_per_sec": round(res.tokens_per_sec, 1),
                    "speedup_vs_plain": round(
                        res.tokens_per_sec / max(ref.tokens_per_sec, 1e-9),
                        3),
                    "acceptance_rate": round(res.acceptance_rate, 3),
                    "accepted_per_dispatch": round(
                        res.accepted / max(res.alive_rounds, 1), 3),
                    "emitted_per_dispatch": round(
                        res.emitted_per_dispatch, 3),
                    "verify_dispatches": res.rounds,
                    "bit_identical_greedy": exact,
                }
                rows["spec"].append(row)
                best_emitted = max(best_emitted,
                                   row["emitted_per_dispatch"])
                emit(f"spec/{tlabel}/d{dd}/k{k}",
                     0.0,
                     f"{row['tokens_per_sec']} tok/s "
                     f"accept {row['acceptance_rate']} "
                     f"emit/disp {row['emitted_per_dispatch']}")
        report["targets"][tlabel] = rows

    report["best_emitted_per_dispatch"] = best_emitted
    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[spec_bench] wrote {out} "
          f"(best emitted/dispatch {best_emitted})", flush=True)
    if best_emitted <= 1.0:
        print("[spec_bench] WARNING: no draft beat 1 token/dispatch",
              flush=True)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
