"""Figure 1: parameter-count ratios of dense / low-rank / PIFA vs rank."""
from repro.core.pifa import (dense_param_count, lowrank_param_count,
                             pifa_param_count)
from benchmarks.common import emit


def run():
    d = 4096
    for frac in (0.125, 0.25, 0.375, 0.5, 0.625, 0.75):
        r = int(d * frac)
        dense = dense_param_count(d, d)
        lr = lowrank_param_count(d, d, r) / dense
        pf = pifa_param_count(d, d, r) / dense
        emit(f"fig1.r{frac:g}.lowrank_ratio", 0.0, f"{lr:.4f}")
        emit(f"fig1.r{frac:g}.pifa_ratio", 0.0, f"{pf:.4f}")
    # headline: r/d = 0.5 -> PIFA saves ~24-25% vs (U,Vt) (paper: 24.2%)
    r = d // 2
    saving = 1 - pifa_param_count(d, d, r) / lowrank_param_count(d, d, r)
    emit("fig1.halfdim.pifa_saving_vs_lowrank", 0.0, f"{saving:.4f}")
    assert 0.23 < saving < 0.26


if __name__ == "__main__":
    run()
