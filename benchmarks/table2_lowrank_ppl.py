"""Table 2 analogue: PPL vs density for SVD / ASVD / SVD-LLM(W) / MPIFA.

CPU-scale reproduction: a trained tiny LM on structured synthetic data
stands in for LLaMA2/WikiText2 (DESIGN.md §8); the claim validated is
the ORDERING and the monotone degradation with density, not absolute
perplexities.
"""
from repro.core.mpifa import MpifaConfig, compress_transformer
from benchmarks.common import calib_tokens, emit, eval_ppl, time_us, trained_tiny


def run():
    model, params = trained_tiny()
    calib = calib_tokens(8)
    emit("table2.dense", 0.0, f"{eval_ppl(model, params):.3f}")
    methods = {
        "svd": dict(prune="svd", reconstruct="none", final_repr="lowrank"),
        "asvd": dict(prune="asvd", reconstruct="none", final_repr="lowrank"),
        "svdllm_w": dict(prune="whiten", reconstruct="none",
                         final_repr="lowrank"),
        "mpifa": dict(prune="whiten", reconstruct="m", final_repr="pifa"),
    }
    for density in (0.8, 0.6, 0.5, 0.4):
        for name, kw in methods.items():
            import time
            t0 = time.perf_counter()
            cp = compress_transformer(model, params, calib,
                                      MpifaConfig(density=density, **kw))
            us = (time.perf_counter() - t0) * 1e6
            ppl = eval_ppl(model, cp, unstacked=True)
            emit(f"table2.d{density:g}.{name}", us, f"{ppl:.3f}")


if __name__ == "__main__":
    run()
