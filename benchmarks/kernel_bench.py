"""Kernel-level microbench: PIFA vs two-GEMM low-rank vs dense.

Rows labelled ``*_ref`` time the pure-jnp oracles (what the models run
under jit on CPU) — these carry the paper's layer-level claims.  Rows
labelled ``*_pallas*`` time the REAL Pallas kernels; on a CPU container
they execute in interpreter mode (``interpret=True``), so their
microseconds measure the Python interpreter, not the TPU — they are
correctness/coverage rows here and become the perf rows on TPU, where
the fusion's analytic saving is the ``hbm_bytes`` column (y_p never
round-trips HBM).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.density import rank_for_density_pifa
from benchmarks.common import emit, time_us
from repro.kernels.lowrank_matmul.ref import lowrank_matmul_ref, matmul_ref
from repro.kernels.pifa_matmul.ops import pifa_matmul, pifa_matmul_fused
from repro.kernels.pifa_matmul.ref import pifa_matmul_ref


def run():
    rng = np.random.default_rng(0)
    b, d = 512, 1024
    density = 0.55
    r = rank_for_density_pifa(d, d, density)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(r, d)) / 32, jnp.float32)
    c = jnp.asarray(rng.normal(size=(d - r, r)) / 16, jnp.float32)
    inv = jnp.asarray(rng.permutation(d), jnp.int32)
    bias = jnp.asarray(rng.normal(size=(d,)) / 8, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)) / 32, jnp.float32)
    r_lr = int(density * d / 2)
    u = jnp.asarray(rng.normal(size=(d, r_lr)) / 16, jnp.float32)
    vt = jnp.asarray(rng.normal(size=(r_lr, d)) / 32, jnp.float32)

    # --- jnp oracles (CPU-meaningful timings) -----------------------------
    t_d = time_us(jax.jit(matmul_ref), x, w)
    t_l = time_us(jax.jit(lowrank_matmul_ref), x, u, vt)
    t_p = time_us(jax.jit(pifa_matmul_ref), x, wp, c)
    emit("kernel.dense_ref", t_d, f"hbm_bytes={4*(b*d + d*d + b*d)}")
    emit("kernel.lowrank_ref", t_l,
         f"hbm_bytes={4*(b*d + r_lr*d*2 + b*r_lr*2 + b*d)}")
    emit("kernel.pifa_ref", t_p,
         f"hbm_bytes={4*(b*d + r*d + (d-r)*r + b*d + b*r*2)}")
    emit("kernel.pifa_ref_speedup_vs_dense", 0.0, f"{t_d/t_p:.3f}x")

    # --- real Pallas kernels (interpret mode on CPU) ----------------------
    # fused: y_p stays in VMEM — its two HBM round trips disappear from
    # the analytic traffic; fused epilogue also folds bias + gather.
    t_pk = time_us(lambda: pifa_matmul(x, wp, c, use_kernel=True),
                   iters=3, warmup=1)
    emit("kernel.pifa_pallas", t_pk,
         f"hbm_bytes={4*(b*d + r*d + (d-r)*r + b*d)}")
    t_pf = time_us(lambda: pifa_matmul_fused(x, wp, c, inv, bias,
                                             use_kernel=True),
                   iters=3, warmup=1)
    emit("kernel.pifa_pallas_fused", t_pf,
         f"hbm_bytes={4*(b*d + r*d + (d-r)*r + b*d)}")
    # decode-shaped (small-batch GEMV) variant: block_b drops to 8
    xd = x[:8]
    t_pd = time_us(lambda: pifa_matmul_fused(xd, wp, c, inv, bias,
                                             use_kernel=True),
                   iters=3, warmup=1)
    emit("kernel.pifa_pallas_fused_decode_b8", t_pd,
         f"hbm_bytes={4*(8*d + r*d + (d-r)*r + 8*d)}")
    # correctness cross-check while we are here (interpret-mode run)
    y_ref = jnp.take(pifa_matmul_ref(x[:32], wp, c), inv, axis=-1) + bias
    y_krn = pifa_matmul_fused(x[:32], wp, c, inv, bias, use_kernel=True)
    emit("kernel.pifa_pallas_fused_max_err", 0.0,
         f"{float(jnp.abs(y_krn - y_ref).max()):.2e}")

    # --- the paper's layer claim (Fig. 7): at the SAME RANK r/d = 0.5,
    # PIFA is ~24.6% faster and ~24.2% smaller than the (U, Vt) layer.
    r2 = d // 2
    wp2 = jnp.asarray(rng.normal(size=(r2, d)) / 32, jnp.float32)
    c2 = jnp.asarray(rng.normal(size=(d - r2, r2)) / 22, jnp.float32)
    u2 = jnp.asarray(rng.normal(size=(d, r2)) / 22, jnp.float32)
    vt2 = jnp.asarray(rng.normal(size=(r2, d)) / 32, jnp.float32)
    t_l2 = time_us(jax.jit(lowrank_matmul_ref), x, u2, vt2)
    t_p2 = time_us(jax.jit(pifa_matmul_ref), x, wp2, c2)
    emit("kernel.equal_rank.lowrank_ref", t_l2, f"params={r2*2*d}")
    emit("kernel.equal_rank.pifa_ref", t_p2, f"params={r2*2*d - r2*r2 + r2}")
    emit("kernel.equal_rank.pifa_time_saving", 0.0,
         f"{1 - t_p2/t_l2:.3f}")
    emit("kernel.equal_rank.pifa_mem_saving", 0.0,
         f"{1 - (r2*2*d - r2*r2 + r2)/(r2*2*d):.3f}")


if __name__ == "__main__":
    run()
