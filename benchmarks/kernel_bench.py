"""Kernel-level microbench: fused PIFA kernel vs two-GEMM low-rank vs
dense, interpret-mode-correctness plus analytic VMEM-traffic accounting
(the TPU fusion saving: y_p never round-trips HBM)."""
import jax.numpy as jnp
import numpy as np

from repro.core.density import rank_for_density_pifa
from benchmarks.common import emit, time_us
from repro.kernels.lowrank_matmul.ref import lowrank_matmul_ref, matmul_ref
from repro.kernels.pifa_matmul.ref import pifa_matmul_ref


def run():
    rng = np.random.default_rng(0)
    b, d = 512, 1024
    density = 0.55
    r = rank_for_density_pifa(d, d, density)
    x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(r, d)) / 32, jnp.float32)
    c = jnp.asarray(rng.normal(size=(d - r, r)) / 16, jnp.float32)
    w = jnp.asarray(rng.normal(size=(d, d)) / 32, jnp.float32)
    r_lr = int(density * d / 2)
    u = jnp.asarray(rng.normal(size=(d, r_lr)) / 16, jnp.float32)
    vt = jnp.asarray(rng.normal(size=(r_lr, d)) / 32, jnp.float32)

    import jax
    t_d = time_us(jax.jit(matmul_ref), x, w)
    t_l = time_us(jax.jit(lowrank_matmul_ref), x, u, vt)
    t_p = time_us(jax.jit(pifa_matmul_ref), x, wp, c)
    emit("kernel.dense", t_d, f"hbm_bytes={4*(b*d + d*d + b*d)}")
    emit("kernel.lowrank", t_l,
         f"hbm_bytes={4*(b*d + r_lr*d*2 + b*r_lr*2 + b*d)}")
    # fused PIFA: y_p stays in VMEM — subtract its two HBM round trips
    emit("kernel.pifa_fused", t_p,
         f"hbm_bytes={4*(b*d + r*d + (d-r)*r + b*d)}")
    emit("kernel.pifa_speedup_vs_dense", 0.0, f"{t_d/t_p:.3f}x")

    # --- the paper's layer claim (Fig. 7): at the SAME RANK r/d = 0.5,
    # PIFA is ~24.6% faster and ~24.2% smaller than the (U, Vt) layer.
    r2 = d // 2
    wp2 = jnp.asarray(rng.normal(size=(r2, d)) / 32, jnp.float32)
    c2 = jnp.asarray(rng.normal(size=(d - r2, r2)) / 22, jnp.float32)
    u2 = jnp.asarray(rng.normal(size=(d, r2)) / 22, jnp.float32)
    vt2 = jnp.asarray(rng.normal(size=(r2, d)) / 32, jnp.float32)
    t_l2 = time_us(jax.jit(lowrank_matmul_ref), x, u2, vt2)
    t_p2 = time_us(jax.jit(pifa_matmul_ref), x, wp2, c2)
    emit("kernel.equal_rank.lowrank", t_l2, f"params={r2*2*d}")
    emit("kernel.equal_rank.pifa", t_p2, f"params={r2*2*d - r2*r2 + r2}")
    emit("kernel.equal_rank.pifa_time_saving", 0.0,
         f"{1 - t_p2/t_l2:.3f}")
    emit("kernel.equal_rank.pifa_mem_saving", 0.0,
         f"{1 - (r2*2*d - r2*r2 + r2)/(r2*2*d):.3f}")


if __name__ == "__main__":
    run()
