"""Table 7 analogue: end-to-end serve throughput, dense vs MPIFA-PIFA.

CPU tokens/s on the trained tiny LM with batched greedy decoding; the
TPU-scale picture is the dry-run's decode cells (dense vs pifa roofline
terms).  Also reports parameter bytes (the memory column of Table 7).
"""
import jax
import numpy as np

from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.launch.serve import generate
from benchmarks.common import (BENCH_CFG, calib_tokens, emit, eval_ppl,
                               trained_tiny)


def _param_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run():
    import jax.numpy as jnp
    model, params = trained_tiny()
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, BENCH_CFG.vocab_size, (8, 16)),
                          jnp.int32)
    _, tps_dense = generate(model, params, prompts, 24, 48)
    emit("table7.dense.tokens_per_s", 0.0, f"{tps_dense:.1f}")
    emit("table7.dense.param_bytes", 0.0, _param_bytes(params))

    cp = compress_transformer(model, params, calib_tokens(6),
                              MpifaConfig(density=0.55))
    _, tps_pifa = generate(model, cp, prompts, 24, 48, unstacked=True)
    emit("table7.mpifa55.tokens_per_s", 0.0, f"{tps_pifa:.1f}")
    emit("table7.mpifa55.param_bytes", 0.0, _param_bytes(cp))
    emit("table7.mpifa55.ppl", 0.0,
         f"{eval_ppl(model, cp, unstacked=True):.3f}")


if __name__ == "__main__":
    run()
