"""Table 7 analogue: end-to-end serve throughput, dense vs MPIFA-PIFA.

CPU tokens/s on the trained tiny LM with batched greedy decoding, under
BOTH serving loops:

  * ``engine``  — the single-dispatch scanned engine (one jitted
    prefill+decode program; `runtime/engine.py`)
  * ``legacy``  — the per-token Python dispatch loop (`launch/serve.generate`)

The engine/legacy ratio is the dispatch-overhead recovery that makes
the paper's layer-level speedup visible end-to-end; the TPU-scale
picture is the dry-run's decode cells.  Also reports parameter bytes
(the memory column of Table 7) and an MPIFA_NS row showing the
rank-bucketed restack replacing the old O(T^2) fallback.
"""
import jax
import numpy as np

from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.launch.serve import generate
from repro.runtime.engine import GenerationEngine
from benchmarks.common import (BENCH_CFG, calib_tokens, emit, eval_ppl,
                               trained_tiny)


def _param_bytes(tree):
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def run():
    import jax.numpy as jnp
    model, params = trained_tiny()
    engine = GenerationEngine(model, max_buckets=4)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, BENCH_CFG.vocab_size, (8, 16)),
                          jnp.int32)

    _, tps_dense = generate(model, params, prompts, 24, 48)
    res_d = engine.generate(params, prompts, 24, 48)
    emit("table7.dense.legacy_tokens_per_s", 0.0, f"{tps_dense:.1f}")
    emit("table7.dense.engine_tokens_per_s", 0.0,
         f"{res_d.tokens_per_sec:.1f}")
    emit("table7.dense.engine_speedup", 0.0,
         f"{res_d.tokens_per_sec / tps_dense:.2f}x")
    emit("table7.dense.param_bytes", 0.0, _param_bytes(params))

    cp = compress_transformer(model, params, calib_tokens(6),
                              MpifaConfig(density=0.55))
    _, tps_pifa = generate(model, cp, prompts, 24, 48, unstacked=True)
    res_p = engine.generate(cp, prompts, 24, 48)
    emit("table7.mpifa55.legacy_tokens_per_s", 0.0, f"{tps_pifa:.1f}")
    emit("table7.mpifa55.engine_tokens_per_s", 0.0,
         f"{res_p.tokens_per_sec:.1f}")
    emit("table7.mpifa55.engine_speedup", 0.0,
         f"{res_p.tokens_per_sec / tps_pifa:.2f}x")
    emit("table7.mpifa55.param_bytes", 0.0, _param_bytes(cp))
    emit("table7.mpifa55.ppl", 0.0,
         f"{eval_ppl(model, cp, unstacked=True):.3f}")

    # MPIFA_NS (per-layer densities): heterogeneous ranks used to force
    # the O(T^2) full-recompute loop; the engine pads into rank buckets.
    md = {}
    for bi in range(BENCH_CFG.num_layers):
        rho = 0.45 if bi < BENCH_CFG.num_layers // 2 else 0.65
        for info in model.linears_in_block():
            md[f"block{bi}/" + "/".join(info.path)] = rho
    cp_ns = compress_transformer(model, params, calib_tokens(6),
                                 MpifaConfig(density=0.55,
                                             module_density=md))
    _, tps_ns_legacy = generate(model, cp_ns, prompts, 24, 48,
                                unstacked=True)
    res_ns = engine.generate(cp_ns, prompts, 24, 48)
    emit("table7.mpifa_ns.legacy_tokens_per_s", 0.0, f"{tps_ns_legacy:.1f}")
    emit("table7.mpifa_ns.engine_tokens_per_s", 0.0,
         f"{res_ns.tokens_per_sec:.1f}")
    emit("table7.mpifa_ns.engine_speedup", 0.0,
         f"{res_ns.tokens_per_sec / tps_ns_legacy:.2f}x")


if __name__ == "__main__":
    run()
