"""Table 6 / Fig. 7 analogue: PIFA layer vs dense vs (U,Vt) low-rank.

Three views (no GPU/TPU attached, DESIGN.md §8):
  * analytic FLOPs + parameter bytes (exact, hardware-independent),
  * measured CPU wall-clock of the jit'd layers (sanity signal: the
    ordering and the growth-with-dimension trend match the paper),
  * the TPU-roofline view lives in the dry-run (--compression pifa).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.density import rank_for_density_pifa
from repro.core.pifa import (dense_flops, lowrank_flops, pifa_flops,
                             dense_param_count, lowrank_param_count,
                             pifa_param_count, pivoting_factorize)
from repro.models.linear import apply_linear
from benchmarks.common import emit, time_us


def run():
    rng = np.random.default_rng(0)
    b = 256  # tokens
    density = 0.55
    for d in (512, 1024, 2048):
        r = rank_for_density_pifa(d, d, density)
        x = jnp.asarray(rng.normal(size=(b, d)), jnp.float32)
        w = rng.normal(size=(d, r)) @ rng.normal(size=(r, d))
        f = pivoting_factorize(w, r)

        dense_p = {"w": jnp.asarray(rng.normal(size=(d, d)), jnp.float32)}
        # low-rank at the SAME parameter budget (its own density->rank map)
        r_lr = int(density * d * d / (2 * d))
        lr_p = {"u": jnp.asarray(rng.normal(size=(d, r_lr)), jnp.float32),
                "vt": jnp.asarray(rng.normal(size=(r_lr, d)), jnp.float32)}
        pifa_p = {"wp": f.wp.astype(jnp.float32),
                  "c": f.c.astype(jnp.float32),
                  "inv_perm": f.inv_perm}

        apply_d = jax.jit(lambda p, x: apply_linear(p, x))
        t_dense = time_us(apply_d, dense_p, x)
        t_lr = time_us(apply_d, lr_p, x)
        t_pifa = time_us(apply_d, pifa_p, x)

        emit(f"table6.d{d}.dense", t_dense, f"flops={dense_flops(d, d, b)}")
        emit(f"table6.d{d}.lowrank", t_lr,
             f"flops={lowrank_flops(d, d, r_lr, b)};"
             f"params={lowrank_param_count(d, d, r_lr)}")
        emit(f"table6.d{d}.pifa", t_pifa,
             f"flops={pifa_flops(d, d, r, b)};"
             f"params={pifa_param_count(d, d, r)}")
        emit(f"table6.d{d}.pifa_speedup_vs_dense", 0.0,
             f"{t_dense / t_pifa:.3f}x")
        emit(f"table6.d{d}.mem_ratio_pifa", 0.0,
             f"{pifa_param_count(d, d, r) / dense_param_count(d, d):.3f}")


if __name__ == "__main__":
    run()
