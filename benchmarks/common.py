"""Shared benchmark scaffolding: a trained tiny LM (cached per run),
PPL evaluation, timing helpers, CSV emission.

Every ``table*/fig*`` module maps to one paper table/figure (DESIGN.md
section 7) and prints ``name,us_per_call,derived`` rows — ``derived``
carries the table's own metric (PPL, ratio, tokens/s ...).
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model, make_train_step
from repro.optim.adamw import AdamW

# Big enough that low-rank compression behaves qualitatively like an LLM
# (some overparameterization), small enough to train on one CPU core.
BENCH_CFG = ModelConfig(name="bench-tiny", family="dense", num_layers=6,
                        d_model=128, num_heads=4, num_kv_heads=4, d_ff=384,
                        vocab_size=256, tie_embeddings=True)

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived) -> None:
    row = f"{name},{us_per_call:.2f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@functools.lru_cache(maxsize=1)
def trained_tiny(steps: int = 400, seed: int = 0):
    """Train the benchmark LM once per process (~1 min on 1 CPU core)."""
    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(seed))
    optim = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, BENCH_CFG, optim))
    opt = optim.init(params)
    pipe = TokenPipeline(DataConfig(vocab_size=BENCH_CFG.vocab_size,
                                    seq_len=64, global_batch=8, seed=seed))
    for i in range(steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        loss, params, opt = step(params, opt, batch)
    return model, params


def eval_ppl(model, params, *, unstacked: bool = False, seed: int = 123,
             batches: int = 4) -> float:
    pipe = TokenPipeline(DataConfig(vocab_size=BENCH_CFG.vocab_size,
                                    seq_len=64, global_batch=4, seed=seed))
    tot, n = 0.0, 0
    for i in range(batches):
        b = pipe.batch_at(10_000 + i)
        toks, labels = jnp.asarray(b["tokens"]), jnp.asarray(b["labels"])
        fwd = model.forward_unstacked if unstacked else model.forward
        logits = fwd(params, toks).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, -1)
        tot += float(-jnp.take_along_axis(lp, labels[..., None], -1).sum())
        n += labels.size
    return float(np.exp(tot / n))


def calib_tokens(n_samples: int = 8, seed: int = 7, seq: int = 64):
    pipe = TokenPipeline(DataConfig(vocab_size=BENCH_CFG.vocab_size,
                                    seq_len=seq, global_batch=1, seed=seed))
    return [jnp.asarray(pipe.batch_at(i)["tokens"])
            for i in range(n_samples)]


def time_us(fn, *args, iters: int = 20, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6
