"""Figure 6 + Fig. 8: PPL and solve condition numbers vs #calibration
samples; reconstructing U+V is more sample-hungry than U-only."""
import numpy as np

from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.core.reconstruct import CalibStats
from benchmarks.common import (BENCH_CFG, calib_tokens, emit, eval_ppl,
                               trained_tiny)


def run():
    model, params = trained_tiny()
    for n in (1, 4, 16):
        calib = calib_tokens(n)
        for label, update_v in (("u_only", False), ("u_and_v", True)):
            cp = compress_transformer(
                model, params, calib,
                MpifaConfig(density=0.5, update_v=update_v,
                            final_repr="pifa"))
            emit(f"fig6.n{n}.{label}", 0.0,
                 f"{eval_ppl(model, cp, unstacked=True):.3f}")
    # Fig. 8: condition number of XX^T shrinks with more samples
    rng = np.random.default_rng(0)
    dim = 64
    conds = {}
    for n_tok in (32, 256, 2048):
        x = rng.normal(size=(n_tok, dim)) @ rng.normal(size=(dim, dim))
        st = CalibStats(dim, dim)
        st.update(x, x)
        conds[n_tok] = float(np.linalg.cond(
            st.xxt + 1e-3 * np.eye(dim)))
        emit(f"fig8.cond_xxt.n{n_tok}", 0.0, f"{conds[n_tok]:.3e}")
    assert conds[2048] <= conds[32]


if __name__ == "__main__":
    run()
