"""Table 4 analogue: fine-tuning after pruning recovers quality.

The paper fine-tunes pruned models (Table 4) and notes PIFA accelerates
BOTH passes (unlike 2:4, whose transposed masks break the backward),
and §6 that PIFA is fully differentiable.  We demonstrate exactly that:
gradient steps THROUGH the PIFA factors (wp, c — inv_perm is structural)
on the training distribution recover part of the compression loss.
"""
import jax
import jax.numpy as jnp

from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model, make_train_step
from repro.optim.adamw import AdamW
from benchmarks.common import (BENCH_CFG, calib_tokens, emit, eval_ppl,
                               trained_tiny)


def run():
    model, params = trained_tiny()
    ppl_dense = eval_ppl(model, params)
    emit("table4.dense", 0.0, f"{ppl_dense:.3f}")

    cp = compress_transformer(model, params, calib_tokens(8),
                              MpifaConfig(density=0.55))
    ppl_pruned = eval_ppl(model, cp, unstacked=True)
    emit("table4.mpifa55.before_ft", 0.0, f"{ppl_pruned:.3f}")

    # fine-tune the PIFA factors themselves (restacked => scanned step)
    stacked = model.restack_blocks(cp)
    assert stacked is not None
    optim = AdamW(lr=5e-4, weight_decay=0.0)
    step = jax.jit(make_train_step(model, BENCH_CFG, optim))
    opt = optim.init(stacked)
    pipe = TokenPipeline(DataConfig(vocab_size=BENCH_CFG.vocab_size,
                                    seq_len=64, global_batch=8, seed=42))
    fparams = stacked
    for i in range(60):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        loss, fparams, opt = step(fparams, opt, batch)
    ppl_ft = eval_ppl(model, fparams)
    emit("table4.mpifa55.after_ft", 0.0, f"{ppl_ft:.3f}")
    emit("table4.recovered_frac", 0.0,
         f"{(ppl_pruned - ppl_ft) / max(ppl_pruned - ppl_dense, 1e-9):.3f}")
    # inv_perm must remain a valid permutation (structural, not trained)
    inv = fparams["blocks"]["mlp"]["gate"]["inv_perm"][0]
    assert sorted(jax.device_get(inv).tolist()) == list(range(inv.shape[0]))


if __name__ == "__main__":
    run()
