"""Table 3 analogue: 2:4 semi-structured baselines vs MPIFA_NS at
matched memory (55% density).

On TPU the 2:4 masks buy NO speedup (no sparse-tensor-core analogue,
DESIGN.md §2) — this benchmark is the quality half of Table 3 plus the
NS (non-uniform sparsity) allocator of App. B.2.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.core.semistructured import (magnitude_score, prune_nm, ria_score,
                                       wanda_score)
from repro.core.sparsity import (ModuleBudget, allocate_densities,
                                 owl_layer_densities,
                                 owl_scores_from_model)
from benchmarks.common import (BENCH_CFG, calib_tokens, emit, eval_ppl,
                               trained_tiny)


def _prune_model_24(model, params, scorer):
    """Apply a 2:4 mask to every block linear (quality baseline)."""
    p = model.unstack_blocks(params)
    rng = np.random.default_rng(0)
    act = np.abs(rng.normal(size=(BENCH_CFG.d_model,))) + 0.5
    new_blocks = []
    for bp in p["blocks"]:
        bp = jax.tree.map(lambda x: x, bp)
        for path in (("attn", "q"), ("attn", "k"), ("attn", "v"),
                     ("attn", "o"), ("mlp", "up"), ("mlp", "gate"),
                     ("mlp", "down")):
            node = bp
            for k in path[:-1]:
                node = node[k]
            if path[-1] not in node:
                continue
            lin = node[path[-1]]
            w = np.asarray(lin["w"], np.float64)
            a = act[: w.shape[1]] if w.shape[1] <= act.shape[0] else \
                np.resize(act, w.shape[1])
            node[path[-1]] = {"w": jnp.asarray(prune_nm(w, scorer, a),
                                               jnp.float32)}
        new_blocks.append(bp)
    p["blocks"] = new_blocks
    return p


def run():
    model, params = trained_tiny()
    calib = calib_tokens(8)
    emit("table3.dense", 0.0, f"{eval_ppl(model, params):.3f}")
    for name, scorer in (("magnitude24", magnitude_score),
                         ("wanda24", wanda_score),
                         ("ria24", ria_score)):
        pruned = _prune_model_24(model, params, scorer)
        emit(f"table3.{name}", 0.0,
             f"{eval_ppl(model, pruned, unstacked=True):.3f}")

    # MPIFA at 55% (uniform) and MPIFA_NS (type + OWL layer densities)
    cp = compress_transformer(model, params, calib,
                              MpifaConfig(density=0.55))
    emit("table3.mpifa55", 0.0, f"{eval_ppl(model, cp, unstacked=True):.3f}")

    infos = model.linears_in_block()
    budgets = []
    for b in range(BENCH_CFG.num_layers):
        for i in infos:
            budgets.append(ModuleBudget(f"block{b}/{'/'.join(i.path)}", b,
                                        i.kind, i.in_dim * i.out_dim))
    # real OWL scores from calibration activations (App. B.2)
    owl = owl_scores_from_model(model, params, calib)
    layer_d = {i: float(x) for i, x in enumerate(owl_layer_densities(
        owl, [1] * BENCH_CFG.num_layers, 0.55))}
    alloc = allocate_densities(budgets, 0.55, layer_density=layer_d,
                               type_density={"attn": 0.45, "mlp": 0.587})
    cp_ns = compress_transformer(
        model, params, calib,
        MpifaConfig(density=0.55, module_density=alloc))
    emit("table3.mpifa_ns55", 0.0,
         f"{eval_ppl(model, cp_ns, unstacked=True):.3f}")


if __name__ == "__main__":
    run()
