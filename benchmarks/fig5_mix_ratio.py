"""Figure 5: PPL vs mix ratio lambda (Eq. 7) at 0.5 density.

Expected shape: lambda=0 (pure degraded flow, as prior work) is worse
than a moderate lambda; very large lambda overfits calibration data.
"""
from repro.core.mpifa import MpifaConfig, compress_transformer
from benchmarks.common import calib_tokens, emit, eval_ppl, trained_tiny


def run():
    model, params = trained_tiny()
    calib = calib_tokens(8)
    for lam in (0.0, 0.25, 0.5, 1.0):
        cp = compress_transformer(
            model, params, calib,
            MpifaConfig(density=0.5, lam=lam, final_repr="pifa"))
        emit(f"fig5.lam{lam:g}", 0.0,
             f"{eval_ppl(model, cp, unstacked=True):.3f}")


if __name__ == "__main__":
    run()
