"""Serving benchmark: continuous batching vs run-to-completion, the
paged-KV capacity sweep, and the preemption-under-burst sweep.

Poisson arrivals with mixed prompt/output lengths through the
slot-allocated scheduler (runtime/scheduler.py), against the *same*
machinery restricted to run-to-completion admission ("drain": slots
only refill when the whole batch finished — what the engine's fixed
batches do).  Both modes share jitted chunk/prefill functions shapes,
so the comparison isolates the admission policy: freed rows idling
behind the slowest request of their batch.

The **capacity-at-equal-HBM sweep** pits the paged block-table cache
(``cache="paged"``, runtime/paging.py) against contiguous slots under
a simultaneous burst of mixed prompt/budget requests, holding the KV
pool to the SAME token count the contiguous cache allocates.  Because
contiguous slots each cost a full worst-case ``cache_len`` row while
paged slots reserve only their own prompt+budget pages, the paged
scheduler sustains more concurrent requests in the same memory.  The
sweep HARD-GATES: peak paged concurrency must be >= 1.3x contiguous
(and every request's tokens must match the contiguous run exactly) or
the benchmark exits non-zero — CI runs it.

The **shared-prefix sweep** (ISSUE 8) replays a prefix-heavy burst —
every prompt opens with the same page-aligned template — through a
``prefix_cache=True`` scheduler: cache-hit admissions map the shared
physical pages (refcount + 1, copy-on-write before any divergent
write) and prefill only the tail, so at the same pool HBM the warm
drain's peak concurrency beats the contiguous run by >= 4.0x and TTFT
for cache-hit prompts drops >= 5x vs cold full-bucket prefills.  Both
are HARD GATES, with zero token mismatches against the contiguous
scheduler — sharing must be invisible in every stream.

The **preemption-under-burst sweep** (ISSUE 6) saturates every slot
with low-priority long requests and lands short high-priority
latecomers mid-run, measuring their p99 latency with preemption OFF
(they queue behind a long completion) vs ``preemption="save_restore"``
(they evict a victim at the next chunk boundary; the victim resumes
from its saved pages).  HARD GATE: the no-preempt/preempt latency
ratio must be >= 1.2 with zero token mismatches across the two runs —
preemption must cut tail latency without touching a single stream.

Reports aggregate tokens/s, p50/p99 per-request latency and mean slot
occupancy, and writes machine-readable ``BENCH_serving.json`` so the
perf trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/serving_bench.py [--compressed]
  PYTHONPATH=src python benchmarks/serving_bench.py --paged-gate-only
  PYTHONPATH=src python benchmarks/serving_bench.py --prefix-gate-only
  PYTHONPATH=src python benchmarks/serving_bench.py --preempt-gate-only
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import BENCH_CFG, emit  # noqa: E402

from repro.models.model import build_model  # noqa: E402
from repro.runtime.scheduler import Request, ServingScheduler  # noqa: E402

# budget mix: mostly short answers, a heavy tail — the regime where
# run-to-completion batching wastes the most slot-time (a batch of 8
# carries at least one long request w.p. ~0.73, which then holds all
# 8 slots while the short ones idle)
BUDGET_MIX = (4, 8, 16, 128)
BUDGET_P = (0.35, 0.30, 0.20, 0.15)
PROMPT_MIX = (8, 16, 24, 32)


def make_requests(n: int, rate: float, vocab: int, seed: int,
                  max_new_cap: int):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    reqs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_MIX))
        budget = min(int(rng.choice(BUDGET_MIX, p=BUDGET_P)), max_new_cap)
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new=budget,
            arrival_time=float(arrivals[i])))
    return reqs


def run_modes(model, params, requests, *, capacity: int, chunk: int,
              eos_id, warm_requests, repeats: int = 3) -> dict:
    """Both admission modes, repeats interleaved (D C D C ...), best-of
    per mode: container CPU throughput is noisy, chunk counts are
    deterministic — interleaving keeps machine drift from landing on
    one mode's measurement window."""
    scheds = {}
    for mode in ("drain", "continuous"):
        scheds[mode] = ServingScheduler(
            model, params, capacity=capacity, chunk=chunk, eos_id=eos_id,
            admission=mode,
            cache_len=max(PROMPT_MIX) + max(BUDGET_MIX) + 1)
        scheds[mode].run(list(warm_requests))   # compile chunk + admits
    best = {}
    for _ in range(repeats):
        for mode, sched in scheds.items():
            run = sched.run(list(requests))
            if (mode not in best
                    or run.tokens_per_sec > best[mode].tokens_per_sec):
                best[mode] = run
    rows = {}
    for mode, run in best.items():
        lat = run.latencies()
        rows[mode] = {
            "tokens_per_sec": round(run.tokens_per_sec, 1),
            "generated": run.generated,
            "elapsed_s": round(run.elapsed, 4),
            "chunks": run.chunks,
            "mean_occupancy": round(run.mean_occupancy, 3),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
            "latency_p99_s": round(float(np.percentile(lat, 99)), 4),
            "requests": len(run.results),
        }
    return rows


def paged_capacity_sweep(model, params, *, contig_capacity: int = 6,
                         page_size: int = 16, burst: int = 32,
                         chunk: int = 4, seed: int = 0) -> dict:
    """Concurrent-request capacity at EQUAL cache HBM, mixed lengths.

    Contiguous: ``contig_capacity`` slots of ``cache_len`` rows.
    Paged: one slot per burst request, but the page pool holds exactly
    the contiguous cache's token count (num_pages * page_size + one
    sentinel page == contig_capacity * cache_len) — concurrency is
    limited by page reservations alone.  Capacity metric: peak slot
    occupancy over the drain.  Hard correctness check: every request's
    tokens match the contiguous run bit-for-bit.
    """
    from repro.runtime.paging import pages_for
    cache_len = max(PROMPT_MIX) + max(BUDGET_MIX) + 1
    cache_len += (-cache_len) % page_size            # page-aligned
    n_logical = pages_for(cache_len, page_size)
    # equal HBM including the sentinel page
    num_pages = contig_capacity * n_logical - 1
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(burst):                            # simultaneous burst
        plen = int(rng.choice(PROMPT_MIX))
        budget = int(rng.choice(BUDGET_MIX, p=BUDGET_P))
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                plen).astype(np.int32),
            max_new=budget))

    def peak(run):
        return max(occ for _, occ in run.occupancy)

    contig = ServingScheduler(model, params, capacity=contig_capacity,
                              chunk=chunk, cache_len=cache_len)
    run_c = contig.run([Request(r.request_id, r.prompt, r.max_new)
                        for r in reqs])
    paged = ServingScheduler(model, params, capacity=burst, chunk=chunk,
                             cache_len=cache_len, cache="paged",
                             page_size=page_size, num_pages=num_pages)
    run_p = paged.run([Request(r.request_id, r.prompt, r.max_new)
                       for r in reqs])
    assert paged._alloc.free_pages == num_pages, "pages leaked"

    toks_c = {r.request_id: r.tokens for r in run_c.results}
    mismatches = sum(
        0 if np.array_equal(r.tokens, toks_c[r.request_id]) else 1
        for r in run_p.results)
    ratio = peak(run_p) / max(peak(run_c), 1)
    row = {
        "cache_len": cache_len,
        "page_size": page_size,
        "pool_tokens": (num_pages + 1) * page_size,
        "contiguous_tokens": contig_capacity * cache_len,
        "burst_requests": burst,
        "peak_concurrency_contiguous": peak(run_c),
        "peak_concurrency_paged": peak(run_p),
        "capacity_ratio": round(ratio, 2),
        "paged_deferrals": dict(run_p.deferrals),
        "token_mismatches": mismatches,
    }
    emit("serving/paged/capacity_at_equal_hbm", 0.0,
         f"{row['peak_concurrency_paged']} vs "
         f"{row['peak_concurrency_contiguous']} concurrent "
         f"({ratio:.2f}x, {row['pool_tokens']} pool tokens)")
    return row


def prefix_sweep(model, params, *, contig_capacity: int = 6,
                 page_size: int = 16, burst: int = 32, chunk: int = 4,
                 seed: int = 0, ttft_prompt_pages: int = 48,
                 ttft_repeats: int = 5) -> dict:
    """Shared-prefix serving under a prefix-heavy burst (ISSUE 8).

    Two measurements, one refcounted prefix-cache scheduler each:

    **Capacity at equal HBM.**  The paged pool again holds exactly the
    contiguous cache's token count, but the burst is prefix-heavy —
    every prompt opens with the same two-page template (the
    system-prompt traffic shape) and budgets are short answers.  The
    burst drains twice through ONE scheduler: the cold pass seeds the
    content-hash index, the warm pass (fresh request ids, same mix)
    admits cache hits that map the shared pages at refcount + 1 and
    reserve only their private tail — peak concurrency on the warm
    drain is the capacity metric, against the contiguous run of the
    same mix.  Hard correctness bar: every stream (cold AND warm)
    bit-identical to the contiguous scheduler's.

    **TTFT cold vs warm.**  Single long-prompt requests
    (``ttft_prompt_pages`` pages + a 2-token tail) with ``max_new=1``:
    cold repeats use a unique prompt each time (full-bucket prefill),
    warm repeats re-send one prompt whose pages are indexed (tail-only
    prefill).  Both admit-fn shapes are compiled before timing; the
    metric is the median run wall-clock ratio.  First tokens must
    agree with a cold engine-reference run of the same prompt.
    """
    from repro.runtime.paging import pages_for
    cache_len = max(PROMPT_MIX) + max(BUDGET_MIX) + 1
    cache_len += (-cache_len) % page_size
    n_logical = pages_for(cache_len, page_size)
    num_pages = contig_capacity * n_logical - 1
    rng = np.random.default_rng(seed)
    template = rng.integers(0, BENCH_CFG.vocab_size,
                            2 * page_size).astype(np.int32)
    prompts, budgets = [], []
    for _ in range(burst):
        tail = rng.integers(0, BENCH_CFG.vocab_size,
                            int(rng.integers(2, page_size + 1)))
        prompts.append(np.concatenate([template, tail.astype(np.int32)]))
        budgets.append(int(rng.choice(BUDGET_MIX[:2])))  # short answers

    def mk(base_id):
        return [Request(request_id=base_id + i, prompt=prompts[i],
                        max_new=budgets[i]) for i in range(burst)]

    def peak(run):
        return max(occ for _, occ in run.occupancy)

    contig = ServingScheduler(model, params, capacity=contig_capacity,
                              chunk=chunk, cache_len=cache_len)
    run_c = contig.run(mk(0))
    toks_c = [r.tokens for r in
              sorted(run_c.results, key=lambda r: r.request_id)]

    sched = ServingScheduler(model, params, capacity=burst, chunk=chunk,
                             cache_len=cache_len, cache="paged",
                             page_size=page_size, num_pages=num_pages,
                             prefix_cache=True)
    run_cold = sched.run(mk(1000))
    run_warm = sched.run(mk(2000))
    mismatches = 0
    for run in (run_cold, run_warm):
        for r in sorted(run.results, key=lambda r: r.request_id):
            i = r.request_id % 1000
            if not np.array_equal(r.tokens, toks_c[i]):
                mismatches += 1
    # index-aware pool-clean accounting: live slots hold nothing, the
    # only outstanding pages are the index pins — dropping the index
    # must hand every page back
    assert (sched._alloc.free_pages + sched._prefix.resident_pages()
            == sched._alloc.num_pages), "pages leaked past the index"
    sched._alloc.check_invariants()
    sched._prefix.drop()
    assert sched._alloc.free_pages == num_pages, "pages leaked"

    ratio = peak(run_warm) / max(peak(run_c), 1)

    # ---- TTFT: cold full-bucket prefill vs warm tail-only prefill
    plen = ttft_prompt_pages * page_size + 2
    bucket = plen + (-plen) % page_size
    t_cache_len = bucket + page_size
    # small symmetric pools: per-dispatch cost scales with pool bytes
    # (the layer scan rewrites every pool page), so both sides get the
    # same 2x-slack pool — cold runs on a plain paged scheduler, which
    # keeps the timed cold admissions from seeding (and then spilling)
    # the warm scheduler's index mid-measurement
    t_pages = pages_for(t_cache_len, page_size) * 2
    tkw = dict(capacity=1, chunk=1, cache_len=t_cache_len,
               cache="paged", page_size=page_size, num_pages=t_pages,
               prompt_buckets=(bucket,))
    tcold = ServingScheduler(model, params, **tkw)
    twarm = ServingScheduler(model, params, prefix_cache=True, **tkw)
    hot = rng.integers(0, BENCH_CFG.vocab_size, plen).astype(np.int32)

    def cold_prompt():
        return rng.integers(0, BENCH_CFG.vocab_size,
                            plen).astype(np.int32)

    def one(sched, rid, prompt):
        t0 = time.perf_counter()
        run = sched.run([Request(request_id=rid, prompt=prompt,
                                 max_new=1)])
        return time.perf_counter() - t0, run

    one(tcold, 1, cold_prompt())        # compile the full prefill
    _, seed_run = one(twarm, 2, hot)    # seed the index (sh=0 compile)
    one(twarm, 3, hot)                  # compile the cache-hit tail
    cold_ts, warm_ts = [], []
    first_tok = {}
    for rep in range(ttft_repeats):
        dt_c, _ = one(tcold, 100 + rep, cold_prompt())
        cold_ts.append(dt_c)
        dt_w, run_w = one(twarm, 200 + rep, hot)
        warm_ts.append(dt_w)
        assert run_w.prefix_hits == 1, "warm TTFT request missed"
        first_tok[rep] = int(run_w.results[0].tokens[plen])
    # warm streams must equal the unshared run of the hot prompt
    ref_tok = int(seed_run.results[0].tokens[plen])
    ttft_mismatches = sum(1 for t in first_tok.values() if t != ref_tok)
    mismatches += ttft_mismatches
    cold_ttft = float(np.median(cold_ts))
    warm_ttft = float(np.median(warm_ts))
    ttft_ratio = cold_ttft / max(warm_ttft, 1e-9)

    row = {
        "cache_len": cache_len,
        "page_size": page_size,
        "pool_tokens": (num_pages + 1) * page_size,
        "contiguous_tokens": contig_capacity * cache_len,
        "burst_requests": burst,
        "shared_prefix_pages": len(template) // page_size,
        "peak_concurrency_contiguous": peak(run_c),
        "peak_concurrency_cold": peak(run_cold),
        "peak_concurrency_warm": peak(run_warm),
        "capacity_ratio": round(ratio, 2),
        "prefix_hits_cold": run_cold.prefix_hits,
        "prefix_hits_warm": run_warm.prefix_hits,
        "prefix_misses_warm": run_warm.prefix_misses,
        "cow_copies": run_cold.cow_copies + run_warm.cow_copies,
        "swap_ins": run_cold.swap_ins + run_warm.swap_ins,
        "swap_outs": run_cold.swap_outs + run_warm.swap_outs,
        "page_high_water": max(run_cold.page_high_water,
                               run_warm.page_high_water),
        "ttft_prompt_len": plen,
        "ttft_cold_s": round(cold_ttft, 4),
        "ttft_warm_s": round(warm_ttft, 4),
        "ttft_ratio": round(ttft_ratio, 2),
        "token_mismatches": mismatches,
    }
    emit("serving/prefix/capacity_at_equal_hbm", 0.0,
         f"{row['peak_concurrency_warm']} vs "
         f"{row['peak_concurrency_contiguous']} concurrent "
         f"({ratio:.2f}x warm, {run_warm.prefix_hits} hits)")
    emit("serving/prefix/ttft", warm_ttft * 1e6,
         f"{warm_ttft*1e3:.1f}ms warm vs {cold_ttft*1e3:.1f}ms cold "
         f"({ttft_ratio:.2f}x)")
    return row


def preemption_sweep(model, params, *, capacity: int = 4, chunk: int = 4,
                     page_size: int = 16, n_high: int = 3,
                     low_budget: int = 128, high_budget: int = 8,
                     prompt_len: int = 16, seed: int = 0) -> dict:
    """Preemption under burst: high-priority latency with and without
    eviction.

    ``capacity`` low-priority long requests saturate every slot; a few
    short high-priority requests arrive mid-run.  Without preemption
    they wait for the first low completion; with ``save_restore`` they
    evict a victim at the next chunk boundary and the victim resumes
    later.  Metrics: high-priority p99 latency in both modes (the gate
    ratio), preempt/resume counts, and a hard correctness bar — every
    request's tokens identical across the two runs (preemption must be
    invisible in every stream, including the victims')."""
    cache_len = prompt_len + low_budget + 1
    cache_len += (-cache_len) % page_size
    rng = np.random.default_rng(seed)
    high_ids = list(range(100, 100 + n_high))

    def mk(arrivals_live: bool):
        reqs = [Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                prompt_len).astype(np.int32),
            max_new=low_budget) for i in range(capacity)]
        for j, rid in enumerate(high_ids):
            reqs.append(Request(
                request_id=rid,
                prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                    prompt_len).astype(np.int32),
                max_new=high_budget,
                arrival_time=(0.1 + 0.05 * j) if arrivals_live else 0.0,
                priority=1))
        return reqs

    rng_state = rng.bit_generator.state
    runs = {}
    for label, mode in (("no_preempt", "off"), ("preempt", "save_restore")):
        sched = ServingScheduler(model, params, capacity=capacity,
                                 chunk=chunk, cache_len=cache_len,
                                 cache="paged", page_size=page_size,
                                 prompt_buckets=(prompt_len,),
                                 preemption=mode)
        # warm with LIVE arrivals so the evict/restore device
        # gathers/scatters compile before the measured run
        rng.bit_generator.state = rng_state
        sched.run(mk(arrivals_live=True))
        rng.bit_generator.state = rng_state
        runs[label] = sched.run(mk(arrivals_live=True))
        assert sched._alloc.free_pages == sched._alloc.num_pages, (
            "pages leaked")

    def hi_lat(run):
        lats = [r.finished_at - r.arrival_time for r in run.results
                if r.request_id in high_ids]
        return float(np.percentile(lats, 99))

    toks_off = {r.request_id: r.tokens for r in runs["no_preempt"].results}
    mismatches = sum(
        0 if np.array_equal(r.tokens, toks_off[r.request_id]) else 1
        for r in runs["preempt"].results)
    p99_off, p99_on = hi_lat(runs["no_preempt"]), hi_lat(runs["preempt"])
    ratio = p99_off / max(p99_on, 1e-9)
    row = {
        "capacity": capacity,
        "low_budget": low_budget,
        "high_budget": high_budget,
        "high_requests": n_high,
        "high_p99_latency_no_preempt_s": round(p99_off, 4),
        "high_p99_latency_preempt_s": round(p99_on, 4),
        "latency_ratio": round(ratio, 2),
        "preemptions": runs["preempt"].preemptions,
        "resumes": runs["preempt"].resumes,
        "rejected": len(runs["preempt"].rejected),
        "token_mismatches": mismatches,
    }
    emit("serving/preempt/high_priority_p99", p99_on * 1e6,
         f"{p99_on:.3f}s vs {p99_off:.3f}s unpreempted ({ratio:.2f}x, "
         f"{row['preemptions']} preempts, {row['resumes']} resumes)")
    return row


def recovery_sweep(model, params, *, capacity: int = 4, chunk: int = 4,
                   page_size: int = 16, n_requests: int = 10,
                   crash_step: int = 3, snapshot_every: int = 2,
                   seed: int = 0) -> dict:
    """Crash recovery: wall-clock recovery time + zero token loss.

    One request mix, three runs: an uninterrupted reference, a journaled
    run killed by an injected ``SchedulerCrash`` at ``crash_step``, and
    a recovery (fresh scheduler <- journal + latest snapshot) that
    drains to completion.  Metrics: recovery time (journal replay +
    snapshot load + slot restore, before the first resumed dispatch)
    and the two zero-token-loss bars — every journaled pre-crash token
    re-emitted identically, and every merged stream bit-equal to the
    reference.  Both must be zero-mismatch; CI hard-gates on it."""
    import tempfile

    from repro.runtime.durability import (Durability, finish_recovered,
                                          recover_into)
    from repro.runtime.fault_tolerance import FaultPlan, SchedulerCrash

    prompt_len = max(PROMPT_MIX)
    max_new = 16
    cache_len = prompt_len + max_new + 1
    cache_len += (-cache_len) % page_size
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.choice(PROMPT_MIX))
        budget = min(int(rng.choice(BUDGET_MIX, p=BUDGET_P)), max_new)
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                plen).astype(np.int32),
            max_new=budget))
    kwargs = dict(capacity=capacity, chunk=chunk, cache_len=cache_len,
                  cache="paged", page_size=page_size)

    ref = ServingScheduler(model, params, **kwargs).run(list(reqs))
    ref_toks = {r.request_id: r.tokens for r in ref.results}

    crashed = False
    with tempfile.TemporaryDirectory() as td:
        dur = Durability(td, snapshot_every=snapshot_every)
        plan = FaultPlan().at(crash_step, "crash")
        sched = ServingScheduler(model, params, durability=dur,
                                 fault_plan=plan, **kwargs)
        try:
            sched.run(list(reqs))
        except SchedulerCrash:
            crashed = True
        dur.close()

        dur2 = Durability(td, snapshot_every=snapshot_every)
        sched2 = ServingScheduler(model, params, durability=dur2,
                                  **kwargs)
        info = recover_into(sched2)
        rec = finish_recovered(sched2, info)
        dur2.close()

    got = {r.request_id: r.tokens for r in rec.run.results}
    mismatches = sum(
        0 if (rid in got and np.array_equal(got[rid], toks)) else 1
        for rid, toks in ref_toks.items())
    row = {
        "requests": n_requests,
        "crash_step": crash_step,
        "snapshot_every": snapshot_every,
        "crashed": crashed,
        "snapshot_tag": info.snapshot_tag,
        "restored": len(info.restored),
        "recomputed": len(info.recomputed),
        "requeued": len(info.requeued),
        "recovery_s": round(info.recover_s, 4),
        "replayed_tokens": rec.replayed,
        "replay_mismatches": rec.mismatches,
        "token_mismatches": mismatches,
        "results": len(rec.run.results),
    }
    emit("serving/recovery/time", info.recover_s * 1e6,
         f"{info.recover_s*1e3:.1f}ms to recover {len(info.restored)} "
         f"slots + {len(info.requeued)} queued, {rec.replayed} tokens "
         f"replayed, {mismatches} mismatches")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="optional eos token (default: budget-driven)")
    ap.add_argument("--compressed", action="store_true",
                    help="also benchmark MPIFA-PIFA compressed params")
    ap.add_argument("--paged-gate-only", action="store_true",
                    help="run only the paged capacity sweep + hard gate "
                         "(the CI paged smoke)")
    ap.add_argument("--preempt-gate-only", action="store_true",
                    help="run only the preemption-under-burst sweep + "
                         "hard gate (the CI fault-injection smoke)")
    ap.add_argument("--recovery-gate-only", action="store_true",
                    help="run only the crash-recovery sweep + zero-token-"
                         "loss hard gate (the CI crash-recovery smoke)")
    ap.add_argument("--prefix-gate-only", action="store_true",
                    help="run only the shared-prefix sweep + hard gate "
                         "(the CI prefix-cache smoke)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--capacity-gate", type=float, default=1.3,
                    help="minimum paged/contiguous concurrency ratio at "
                         "equal cache HBM")
    ap.add_argument("--prefix-capacity-gate", type=float, default=4.0,
                    help="minimum warm-drain concurrency ratio vs "
                         "contiguous under the prefix-heavy burst")
    ap.add_argument("--ttft-gate", type=float, default=5.0,
                    help="minimum cold/warm TTFT ratio for cache-hit "
                         "prompts")
    ap.add_argument("--preempt-gate", type=float, default=1.2,
                    help="minimum high-priority p99 latency improvement "
                         "(no-preempt / preempt) under burst")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))

    def run_paged_gate(report):
        row = paged_capacity_sweep(model, params, page_size=args.page_size,
                                   seed=args.seed)
        report["paged_capacity"] = row
        ok = (row["capacity_ratio"] >= args.capacity_gate
              and row["token_mismatches"] == 0)
        if not ok:
            print(f"[serving_bench] PAGED GATE FAILED: ratio "
                  f"{row['capacity_ratio']} < {args.capacity_gate} or "
                  f"{row['token_mismatches']} token mismatches",
                  flush=True)
        return ok

    def run_preempt_gate(report):
        row = preemption_sweep(model, params, page_size=args.page_size,
                               seed=args.seed)
        report["preemption"] = row
        ok = (row["latency_ratio"] >= args.preempt_gate
              and row["token_mismatches"] == 0
              and row["preemptions"] >= 1 and row["resumes"] >= 1)
        if not ok:
            print(f"[serving_bench] PREEMPT GATE FAILED: ratio "
                  f"{row['latency_ratio']} < {args.preempt_gate}, "
                  f"{row['token_mismatches']} token mismatches, "
                  f"{row['preemptions']} preempts / "
                  f"{row['resumes']} resumes", flush=True)
        return ok

    def run_prefix_gate(report):
        row = prefix_sweep(model, params, page_size=args.page_size,
                           seed=args.seed)
        report["prefix_cache"] = row
        ok = (row["capacity_ratio"] >= args.prefix_capacity_gate
              and row["ttft_ratio"] >= args.ttft_gate
              and row["token_mismatches"] == 0
              and row["prefix_hits_warm"] >= 1)
        if not ok:
            print(f"[serving_bench] PREFIX GATE FAILED: capacity "
                  f"{row['capacity_ratio']} < {args.prefix_capacity_gate} "
                  f"or TTFT {row['ttft_ratio']} < {args.ttft_gate}, "
                  f"{row['token_mismatches']} token mismatches, "
                  f"{row['prefix_hits_warm']} warm hits", flush=True)
        return ok

    def run_recovery_gate(report):
        row = recovery_sweep(model, params, page_size=args.page_size,
                             seed=args.seed)
        report["recovery"] = row
        # zero token loss is the whole contract: the crash must have
        # fired, every journaled token must replay identically, and the
        # merged results must cover every request bit-identically
        ok = (row["crashed"] and row["replay_mismatches"] == 0
              and row["token_mismatches"] == 0
              and row["results"] == row["requests"])
        if not ok:
            print(f"[serving_bench] RECOVERY GATE FAILED: crashed="
                  f"{row['crashed']}, {row['replay_mismatches']} replay "
                  f"mismatches, {row['token_mismatches']} token "
                  f"mismatches, {row['results']}/{row['requests']} "
                  "results", flush=True)
        return ok

    if (args.paged_gate_only or args.preempt_gate_only
            or args.recovery_gate_only or args.prefix_gate_only):
        report = {"config": {"model": BENCH_CFG.name,
                             "page_size": args.page_size,
                             "backend": jax.default_backend(),
                             "timestamp": time.strftime(
                                 "%Y-%m-%dT%H:%M:%S")}}
        if args.paged_gate_only:
            ok = run_paged_gate(report)
            print(json.dumps(report["paged_capacity"], indent=2),
                  flush=True)
        elif args.preempt_gate_only:
            ok = run_preempt_gate(report)
            print(json.dumps(report["preemption"], indent=2), flush=True)
        elif args.prefix_gate_only:
            ok = run_prefix_gate(report)
            print(json.dumps(report["prefix_cache"], indent=2),
                  flush=True)
        else:
            ok = run_recovery_gate(report)
            print(json.dumps(report["recovery"], indent=2), flush=True)
        return 0 if ok else 1
    requests = make_requests(args.requests, args.rate, BENCH_CFG.vocab_size,
                             args.seed, max(BUDGET_MIX))
    # warm set covers EVERY prompt bucket so no admit fn compiles
    # mid-measurement; arrivals at 0 so warming is fast
    rng = np.random.default_rng(args.seed + 1)
    warm = [Request(request_id=1000 + i,
                    prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                        plen).astype(np.int32),
                    max_new=int(min(BUDGET_MIX)))
            for i, plen in enumerate(PROMPT_MIX)]

    report = {
        "config": {
            "model": BENCH_CFG.name,
            "requests": args.requests,
            "capacity": args.capacity,
            "chunk": args.chunk,
            "rate_req_per_s": args.rate,
            "budget_mix": list(BUDGET_MIX),
            "prompt_mix": list(PROMPT_MIX),
            "seed": args.seed,
            "backend": jax.default_backend(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "dense": {},
    }

    variants = [("dense", params)]
    if args.compressed:
        from repro.core.mpifa import MpifaConfig, compress_transformer
        calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                    BENCH_CFG.vocab_size) for i in range(4)]
        cparams = compress_transformer(model, params, calib,
                                       MpifaConfig(density=0.55))
        variants.append(("pifa", cparams))

    for label, p in variants:
        rows = run_modes(model, p, requests, capacity=args.capacity,
                         chunk=args.chunk, eos_id=args.eos_id,
                         warm_requests=warm)
        for mode in ("drain", "continuous"):
            emit(f"serving/{label}/{mode}",
                 rows[mode]["elapsed_s"] * 1e6,
                 f"{rows[mode]['tokens_per_sec']} tok/s "
                 f"p50 {rows[mode]['latency_p50_s']}s "
                 f"p99 {rows[mode]['latency_p99_s']}s "
                 f"occ {rows[mode]['mean_occupancy']}")
        speedup = (rows["continuous"]["tokens_per_sec"]
                   / max(rows["drain"]["tokens_per_sec"], 1e-9))
        rows["speedup"] = round(speedup, 2)
        report[label] = rows
        emit(f"serving/{label}/speedup", 0.0, f"{speedup:.2f}x")

    gate_ok = run_paged_gate(report)
    gate_ok = run_prefix_gate(report) and gate_ok
    gate_ok = run_preempt_gate(report) and gate_ok
    gate_ok = run_recovery_gate(report) and gate_ok

    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[serving_bench] wrote {out}", flush=True)
    return 0 if gate_ok else 1


if __name__ == "__main__":
    sys.exit(main())
