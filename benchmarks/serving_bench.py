"""Serving benchmark: continuous batching vs run-to-completion.

Poisson arrivals with mixed prompt/output lengths through the
slot-allocated scheduler (runtime/scheduler.py), against the *same*
machinery restricted to run-to-completion admission ("drain": slots
only refill when the whole batch finished — what the engine's fixed
batches do).  Both modes share jitted chunk/prefill functions shapes,
so the comparison isolates the admission policy: freed rows idling
behind the slowest request of their batch.

Reports aggregate tokens/s, p50/p99 per-request latency and mean slot
occupancy, and writes machine-readable ``BENCH_serving.json`` so the
perf trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/serving_bench.py [--compressed]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import BENCH_CFG, emit  # noqa: E402

from repro.models.model import build_model  # noqa: E402
from repro.runtime.scheduler import Request, ServingScheduler  # noqa: E402

# budget mix: mostly short answers, a heavy tail — the regime where
# run-to-completion batching wastes the most slot-time (a batch of 8
# carries at least one long request w.p. ~0.73, which then holds all
# 8 slots while the short ones idle)
BUDGET_MIX = (4, 8, 16, 128)
BUDGET_P = (0.35, 0.30, 0.20, 0.15)
PROMPT_MIX = (8, 16, 24, 32)


def make_requests(n: int, rate: float, vocab: int, seed: int,
                  max_new_cap: int):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    reqs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_MIX))
        budget = min(int(rng.choice(BUDGET_MIX, p=BUDGET_P)), max_new_cap)
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new=budget,
            arrival_time=float(arrivals[i])))
    return reqs


def run_modes(model, params, requests, *, capacity: int, chunk: int,
              eos_id, warm_requests, repeats: int = 3) -> dict:
    """Both admission modes, repeats interleaved (D C D C ...), best-of
    per mode: container CPU throughput is noisy, chunk counts are
    deterministic — interleaving keeps machine drift from landing on
    one mode's measurement window."""
    scheds = {}
    for mode in ("drain", "continuous"):
        scheds[mode] = ServingScheduler(
            model, params, capacity=capacity, chunk=chunk, eos_id=eos_id,
            admission=mode,
            cache_len=max(PROMPT_MIX) + max(BUDGET_MIX) + 1)
        scheds[mode].run(list(warm_requests))   # compile chunk + admits
    best = {}
    for _ in range(repeats):
        for mode, sched in scheds.items():
            run = sched.run(list(requests))
            if (mode not in best
                    or run.tokens_per_sec > best[mode].tokens_per_sec):
                best[mode] = run
    rows = {}
    for mode, run in best.items():
        lat = run.latencies()
        rows[mode] = {
            "tokens_per_sec": round(run.tokens_per_sec, 1),
            "generated": run.generated,
            "elapsed_s": round(run.elapsed, 4),
            "chunks": run.chunks,
            "mean_occupancy": round(run.mean_occupancy, 3),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
            "latency_p99_s": round(float(np.percentile(lat, 99)), 4),
            "requests": len(run.results),
        }
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="optional eos token (default: budget-driven)")
    ap.add_argument("--compressed", action="store_true",
                    help="also benchmark MPIFA-PIFA compressed params")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))
    requests = make_requests(args.requests, args.rate, BENCH_CFG.vocab_size,
                             args.seed, max(BUDGET_MIX))
    # warm set covers EVERY prompt bucket so no admit fn compiles
    # mid-measurement; arrivals at 0 so warming is fast
    rng = np.random.default_rng(args.seed + 1)
    warm = [Request(request_id=1000 + i,
                    prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                        plen).astype(np.int32),
                    max_new=int(min(BUDGET_MIX)))
            for i, plen in enumerate(PROMPT_MIX)]

    report = {
        "config": {
            "model": BENCH_CFG.name,
            "requests": args.requests,
            "capacity": args.capacity,
            "chunk": args.chunk,
            "rate_req_per_s": args.rate,
            "budget_mix": list(BUDGET_MIX),
            "prompt_mix": list(PROMPT_MIX),
            "seed": args.seed,
            "backend": jax.default_backend(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "dense": {},
    }

    variants = [("dense", params)]
    if args.compressed:
        from repro.core.mpifa import MpifaConfig, compress_transformer
        calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                    BENCH_CFG.vocab_size) for i in range(4)]
        cparams = compress_transformer(model, params, calib,
                                       MpifaConfig(density=0.55))
        variants.append(("pifa", cparams))

    for label, p in variants:
        rows = run_modes(model, p, requests, capacity=args.capacity,
                         chunk=args.chunk, eos_id=args.eos_id,
                         warm_requests=warm)
        for mode in ("drain", "continuous"):
            emit(f"serving/{label}/{mode}",
                 rows[mode]["elapsed_s"] * 1e6,
                 f"{rows[mode]['tokens_per_sec']} tok/s "
                 f"p50 {rows[mode]['latency_p50_s']}s "
                 f"p99 {rows[mode]['latency_p99_s']}s "
                 f"occ {rows[mode]['mean_occupancy']}")
        speedup = (rows["continuous"]["tokens_per_sec"]
                   / max(rows["drain"]["tokens_per_sec"], 1e-9))
        rows["speedup"] = round(speedup, 2)
        report[label] = rows
        emit(f"serving/{label}/speedup", 0.0, f"{speedup:.2f}x")

    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[serving_bench] wrote {out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
