"""Serving benchmark: continuous batching vs run-to-completion, the
paged-KV capacity sweep, and the preemption-under-burst sweep.

Poisson arrivals with mixed prompt/output lengths through the
slot-allocated scheduler (runtime/scheduler.py), against the *same*
machinery restricted to run-to-completion admission ("drain": slots
only refill when the whole batch finished — what the engine's fixed
batches do).  Both modes share jitted chunk/prefill functions shapes,
so the comparison isolates the admission policy: freed rows idling
behind the slowest request of their batch.

The **capacity-at-equal-HBM sweep** pits the paged block-table cache
(``cache="paged"``, runtime/paging.py) against contiguous slots under
a simultaneous burst of mixed prompt/budget requests, holding the KV
pool to the SAME token count the contiguous cache allocates.  Because
contiguous slots each cost a full worst-case ``cache_len`` row while
paged slots reserve only their own prompt+budget pages, the paged
scheduler sustains more concurrent requests in the same memory.  The
sweep HARD-GATES: peak paged concurrency must be >= 1.3x contiguous
(and every request's tokens must match the contiguous run exactly) or
the benchmark exits non-zero — CI runs it.

The **preemption-under-burst sweep** (ISSUE 6) saturates every slot
with low-priority long requests and lands short high-priority
latecomers mid-run, measuring their p99 latency with preemption OFF
(they queue behind a long completion) vs ``preemption="save_restore"``
(they evict a victim at the next chunk boundary; the victim resumes
from its saved pages).  HARD GATE: the no-preempt/preempt latency
ratio must be >= 1.2 with zero token mismatches across the two runs —
preemption must cut tail latency without touching a single stream.

Reports aggregate tokens/s, p50/p99 per-request latency and mean slot
occupancy, and writes machine-readable ``BENCH_serving.json`` so the
perf trajectory is tracked across PRs.

  PYTHONPATH=src python benchmarks/serving_bench.py [--compressed]
  PYTHONPATH=src python benchmarks/serving_bench.py --paged-gate-only
  PYTHONPATH=src python benchmarks/serving_bench.py --preempt-gate-only
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import jax
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent))

from common import BENCH_CFG, emit  # noqa: E402

from repro.models.model import build_model  # noqa: E402
from repro.runtime.scheduler import Request, ServingScheduler  # noqa: E402

# budget mix: mostly short answers, a heavy tail — the regime where
# run-to-completion batching wastes the most slot-time (a batch of 8
# carries at least one long request w.p. ~0.73, which then holds all
# 8 slots while the short ones idle)
BUDGET_MIX = (4, 8, 16, 128)
BUDGET_P = (0.35, 0.30, 0.20, 0.15)
PROMPT_MIX = (8, 16, 24, 32)


def make_requests(n: int, rate: float, vocab: int, seed: int,
                  max_new_cap: int):
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    arrivals = np.concatenate([[0.0], np.cumsum(gaps)[:-1]])
    reqs = []
    for i in range(n):
        plen = int(rng.choice(PROMPT_MIX))
        budget = min(int(rng.choice(BUDGET_MIX, p=BUDGET_P)), max_new_cap)
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, vocab, plen).astype(np.int32),
            max_new=budget,
            arrival_time=float(arrivals[i])))
    return reqs


def run_modes(model, params, requests, *, capacity: int, chunk: int,
              eos_id, warm_requests, repeats: int = 3) -> dict:
    """Both admission modes, repeats interleaved (D C D C ...), best-of
    per mode: container CPU throughput is noisy, chunk counts are
    deterministic — interleaving keeps machine drift from landing on
    one mode's measurement window."""
    scheds = {}
    for mode in ("drain", "continuous"):
        scheds[mode] = ServingScheduler(
            model, params, capacity=capacity, chunk=chunk, eos_id=eos_id,
            admission=mode,
            cache_len=max(PROMPT_MIX) + max(BUDGET_MIX) + 1)
        scheds[mode].run(list(warm_requests))   # compile chunk + admits
    best = {}
    for _ in range(repeats):
        for mode, sched in scheds.items():
            run = sched.run(list(requests))
            if (mode not in best
                    or run.tokens_per_sec > best[mode].tokens_per_sec):
                best[mode] = run
    rows = {}
    for mode, run in best.items():
        lat = run.latencies()
        rows[mode] = {
            "tokens_per_sec": round(run.tokens_per_sec, 1),
            "generated": run.generated,
            "elapsed_s": round(run.elapsed, 4),
            "chunks": run.chunks,
            "mean_occupancy": round(run.mean_occupancy, 3),
            "latency_p50_s": round(float(np.percentile(lat, 50)), 4),
            "latency_p99_s": round(float(np.percentile(lat, 99)), 4),
            "requests": len(run.results),
        }
    return rows


def paged_capacity_sweep(model, params, *, contig_capacity: int = 6,
                         page_size: int = 16, burst: int = 32,
                         chunk: int = 4, seed: int = 0) -> dict:
    """Concurrent-request capacity at EQUAL cache HBM, mixed lengths.

    Contiguous: ``contig_capacity`` slots of ``cache_len`` rows.
    Paged: one slot per burst request, but the page pool holds exactly
    the contiguous cache's token count (num_pages * page_size + one
    sentinel page == contig_capacity * cache_len) — concurrency is
    limited by page reservations alone.  Capacity metric: peak slot
    occupancy over the drain.  Hard correctness check: every request's
    tokens match the contiguous run bit-for-bit.
    """
    from repro.runtime.paging import pages_for
    cache_len = max(PROMPT_MIX) + max(BUDGET_MIX) + 1
    cache_len += (-cache_len) % page_size            # page-aligned
    n_logical = pages_for(cache_len, page_size)
    # equal HBM including the sentinel page
    num_pages = contig_capacity * n_logical - 1
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(burst):                            # simultaneous burst
        plen = int(rng.choice(PROMPT_MIX))
        budget = int(rng.choice(BUDGET_MIX, p=BUDGET_P))
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                plen).astype(np.int32),
            max_new=budget))

    def peak(run):
        return max(occ for _, occ in run.occupancy)

    contig = ServingScheduler(model, params, capacity=contig_capacity,
                              chunk=chunk, cache_len=cache_len)
    run_c = contig.run([Request(r.request_id, r.prompt, r.max_new)
                        for r in reqs])
    paged = ServingScheduler(model, params, capacity=burst, chunk=chunk,
                             cache_len=cache_len, cache="paged",
                             page_size=page_size, num_pages=num_pages)
    run_p = paged.run([Request(r.request_id, r.prompt, r.max_new)
                       for r in reqs])
    assert paged._alloc.free_pages == num_pages, "pages leaked"

    toks_c = {r.request_id: r.tokens for r in run_c.results}
    mismatches = sum(
        0 if np.array_equal(r.tokens, toks_c[r.request_id]) else 1
        for r in run_p.results)
    ratio = peak(run_p) / max(peak(run_c), 1)
    row = {
        "cache_len": cache_len,
        "page_size": page_size,
        "pool_tokens": (num_pages + 1) * page_size,
        "contiguous_tokens": contig_capacity * cache_len,
        "burst_requests": burst,
        "peak_concurrency_contiguous": peak(run_c),
        "peak_concurrency_paged": peak(run_p),
        "capacity_ratio": round(ratio, 2),
        "paged_deferrals": dict(run_p.deferrals),
        "token_mismatches": mismatches,
    }
    emit("serving/paged/capacity_at_equal_hbm", 0.0,
         f"{row['peak_concurrency_paged']} vs "
         f"{row['peak_concurrency_contiguous']} concurrent "
         f"({ratio:.2f}x, {row['pool_tokens']} pool tokens)")
    return row


def preemption_sweep(model, params, *, capacity: int = 4, chunk: int = 4,
                     page_size: int = 16, n_high: int = 3,
                     low_budget: int = 128, high_budget: int = 8,
                     prompt_len: int = 16, seed: int = 0) -> dict:
    """Preemption under burst: high-priority latency with and without
    eviction.

    ``capacity`` low-priority long requests saturate every slot; a few
    short high-priority requests arrive mid-run.  Without preemption
    they wait for the first low completion; with ``save_restore`` they
    evict a victim at the next chunk boundary and the victim resumes
    later.  Metrics: high-priority p99 latency in both modes (the gate
    ratio), preempt/resume counts, and a hard correctness bar — every
    request's tokens identical across the two runs (preemption must be
    invisible in every stream, including the victims')."""
    cache_len = prompt_len + low_budget + 1
    cache_len += (-cache_len) % page_size
    rng = np.random.default_rng(seed)
    high_ids = list(range(100, 100 + n_high))

    def mk(arrivals_live: bool):
        reqs = [Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                prompt_len).astype(np.int32),
            max_new=low_budget) for i in range(capacity)]
        for j, rid in enumerate(high_ids):
            reqs.append(Request(
                request_id=rid,
                prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                    prompt_len).astype(np.int32),
                max_new=high_budget,
                arrival_time=(0.1 + 0.05 * j) if arrivals_live else 0.0,
                priority=1))
        return reqs

    rng_state = rng.bit_generator.state
    runs = {}
    for label, mode in (("no_preempt", "off"), ("preempt", "save_restore")):
        sched = ServingScheduler(model, params, capacity=capacity,
                                 chunk=chunk, cache_len=cache_len,
                                 cache="paged", page_size=page_size,
                                 prompt_buckets=(prompt_len,),
                                 preemption=mode)
        # warm with LIVE arrivals so the evict/restore device
        # gathers/scatters compile before the measured run
        rng.bit_generator.state = rng_state
        sched.run(mk(arrivals_live=True))
        rng.bit_generator.state = rng_state
        runs[label] = sched.run(mk(arrivals_live=True))
        assert sched._alloc.free_pages == sched._alloc.num_pages, (
            "pages leaked")

    def hi_lat(run):
        lats = [r.finished_at - r.arrival_time for r in run.results
                if r.request_id in high_ids]
        return float(np.percentile(lats, 99))

    toks_off = {r.request_id: r.tokens for r in runs["no_preempt"].results}
    mismatches = sum(
        0 if np.array_equal(r.tokens, toks_off[r.request_id]) else 1
        for r in runs["preempt"].results)
    p99_off, p99_on = hi_lat(runs["no_preempt"]), hi_lat(runs["preempt"])
    ratio = p99_off / max(p99_on, 1e-9)
    row = {
        "capacity": capacity,
        "low_budget": low_budget,
        "high_budget": high_budget,
        "high_requests": n_high,
        "high_p99_latency_no_preempt_s": round(p99_off, 4),
        "high_p99_latency_preempt_s": round(p99_on, 4),
        "latency_ratio": round(ratio, 2),
        "preemptions": runs["preempt"].preemptions,
        "resumes": runs["preempt"].resumes,
        "rejected": len(runs["preempt"].rejected),
        "token_mismatches": mismatches,
    }
    emit("serving/preempt/high_priority_p99", p99_on * 1e6,
         f"{p99_on:.3f}s vs {p99_off:.3f}s unpreempted ({ratio:.2f}x, "
         f"{row['preemptions']} preempts, {row['resumes']} resumes)")
    return row


def recovery_sweep(model, params, *, capacity: int = 4, chunk: int = 4,
                   page_size: int = 16, n_requests: int = 10,
                   crash_step: int = 3, snapshot_every: int = 2,
                   seed: int = 0) -> dict:
    """Crash recovery: wall-clock recovery time + zero token loss.

    One request mix, three runs: an uninterrupted reference, a journaled
    run killed by an injected ``SchedulerCrash`` at ``crash_step``, and
    a recovery (fresh scheduler <- journal + latest snapshot) that
    drains to completion.  Metrics: recovery time (journal replay +
    snapshot load + slot restore, before the first resumed dispatch)
    and the two zero-token-loss bars — every journaled pre-crash token
    re-emitted identically, and every merged stream bit-equal to the
    reference.  Both must be zero-mismatch; CI hard-gates on it."""
    import tempfile

    from repro.runtime.durability import (Durability, finish_recovered,
                                          recover_into)
    from repro.runtime.fault_tolerance import FaultPlan, SchedulerCrash

    prompt_len = max(PROMPT_MIX)
    max_new = 16
    cache_len = prompt_len + max_new + 1
    cache_len += (-cache_len) % page_size
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.choice(PROMPT_MIX))
        budget = min(int(rng.choice(BUDGET_MIX, p=BUDGET_P)), max_new)
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                plen).astype(np.int32),
            max_new=budget))
    kwargs = dict(capacity=capacity, chunk=chunk, cache_len=cache_len,
                  cache="paged", page_size=page_size)

    ref = ServingScheduler(model, params, **kwargs).run(list(reqs))
    ref_toks = {r.request_id: r.tokens for r in ref.results}

    crashed = False
    with tempfile.TemporaryDirectory() as td:
        dur = Durability(td, snapshot_every=snapshot_every)
        plan = FaultPlan().at(crash_step, "crash")
        sched = ServingScheduler(model, params, durability=dur,
                                 fault_plan=plan, **kwargs)
        try:
            sched.run(list(reqs))
        except SchedulerCrash:
            crashed = True
        dur.close()

        dur2 = Durability(td, snapshot_every=snapshot_every)
        sched2 = ServingScheduler(model, params, durability=dur2,
                                  **kwargs)
        info = recover_into(sched2)
        rec = finish_recovered(sched2, info)
        dur2.close()

    got = {r.request_id: r.tokens for r in rec.run.results}
    mismatches = sum(
        0 if (rid in got and np.array_equal(got[rid], toks)) else 1
        for rid, toks in ref_toks.items())
    row = {
        "requests": n_requests,
        "crash_step": crash_step,
        "snapshot_every": snapshot_every,
        "crashed": crashed,
        "snapshot_tag": info.snapshot_tag,
        "restored": len(info.restored),
        "recomputed": len(info.recomputed),
        "requeued": len(info.requeued),
        "recovery_s": round(info.recover_s, 4),
        "replayed_tokens": rec.replayed,
        "replay_mismatches": rec.mismatches,
        "token_mismatches": mismatches,
        "results": len(rec.run.results),
    }
    emit("serving/recovery/time", info.recover_s * 1e6,
         f"{info.recover_s*1e3:.1f}ms to recover {len(info.restored)} "
         f"slots + {len(info.requeued)} queued, {rec.replayed} tokens "
         f"replayed, {mismatches} mismatches")
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--capacity", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate (req/s)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--eos-id", type=int, default=None,
                    help="optional eos token (default: budget-driven)")
    ap.add_argument("--compressed", action="store_true",
                    help="also benchmark MPIFA-PIFA compressed params")
    ap.add_argument("--paged-gate-only", action="store_true",
                    help="run only the paged capacity sweep + hard gate "
                         "(the CI paged smoke)")
    ap.add_argument("--preempt-gate-only", action="store_true",
                    help="run only the preemption-under-burst sweep + "
                         "hard gate (the CI fault-injection smoke)")
    ap.add_argument("--recovery-gate-only", action="store_true",
                    help="run only the crash-recovery sweep + zero-token-"
                         "loss hard gate (the CI crash-recovery smoke)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--capacity-gate", type=float, default=1.3,
                    help="minimum paged/contiguous concurrency ratio at "
                         "equal cache HBM")
    ap.add_argument("--preempt-gate", type=float, default=1.2,
                    help="minimum high-priority p99 latency improvement "
                         "(no-preempt / preempt) under burst")
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)

    model = build_model(BENCH_CFG)
    params = model.init(jax.random.PRNGKey(0))

    def run_paged_gate(report):
        row = paged_capacity_sweep(model, params, page_size=args.page_size,
                                   seed=args.seed)
        report["paged_capacity"] = row
        ok = (row["capacity_ratio"] >= args.capacity_gate
              and row["token_mismatches"] == 0)
        if not ok:
            print(f"[serving_bench] PAGED GATE FAILED: ratio "
                  f"{row['capacity_ratio']} < {args.capacity_gate} or "
                  f"{row['token_mismatches']} token mismatches",
                  flush=True)
        return ok

    def run_preempt_gate(report):
        row = preemption_sweep(model, params, page_size=args.page_size,
                               seed=args.seed)
        report["preemption"] = row
        ok = (row["latency_ratio"] >= args.preempt_gate
              and row["token_mismatches"] == 0
              and row["preemptions"] >= 1 and row["resumes"] >= 1)
        if not ok:
            print(f"[serving_bench] PREEMPT GATE FAILED: ratio "
                  f"{row['latency_ratio']} < {args.preempt_gate}, "
                  f"{row['token_mismatches']} token mismatches, "
                  f"{row['preemptions']} preempts / "
                  f"{row['resumes']} resumes", flush=True)
        return ok

    def run_recovery_gate(report):
        row = recovery_sweep(model, params, page_size=args.page_size,
                             seed=args.seed)
        report["recovery"] = row
        # zero token loss is the whole contract: the crash must have
        # fired, every journaled token must replay identically, and the
        # merged results must cover every request bit-identically
        ok = (row["crashed"] and row["replay_mismatches"] == 0
              and row["token_mismatches"] == 0
              and row["results"] == row["requests"])
        if not ok:
            print(f"[serving_bench] RECOVERY GATE FAILED: crashed="
                  f"{row['crashed']}, {row['replay_mismatches']} replay "
                  f"mismatches, {row['token_mismatches']} token "
                  f"mismatches, {row['results']}/{row['requests']} "
                  "results", flush=True)
        return ok

    if (args.paged_gate_only or args.preempt_gate_only
            or args.recovery_gate_only):
        report = {"config": {"model": BENCH_CFG.name,
                             "page_size": args.page_size,
                             "backend": jax.default_backend(),
                             "timestamp": time.strftime(
                                 "%Y-%m-%dT%H:%M:%S")}}
        if args.paged_gate_only:
            ok = run_paged_gate(report)
            print(json.dumps(report["paged_capacity"], indent=2),
                  flush=True)
        elif args.preempt_gate_only:
            ok = run_preempt_gate(report)
            print(json.dumps(report["preemption"], indent=2), flush=True)
        else:
            ok = run_recovery_gate(report)
            print(json.dumps(report["recovery"], indent=2), flush=True)
        return 0 if ok else 1
    requests = make_requests(args.requests, args.rate, BENCH_CFG.vocab_size,
                             args.seed, max(BUDGET_MIX))
    # warm set covers EVERY prompt bucket so no admit fn compiles
    # mid-measurement; arrivals at 0 so warming is fast
    rng = np.random.default_rng(args.seed + 1)
    warm = [Request(request_id=1000 + i,
                    prompt=rng.integers(0, BENCH_CFG.vocab_size,
                                        plen).astype(np.int32),
                    max_new=int(min(BUDGET_MIX)))
            for i, plen in enumerate(PROMPT_MIX)]

    report = {
        "config": {
            "model": BENCH_CFG.name,
            "requests": args.requests,
            "capacity": args.capacity,
            "chunk": args.chunk,
            "rate_req_per_s": args.rate,
            "budget_mix": list(BUDGET_MIX),
            "prompt_mix": list(PROMPT_MIX),
            "seed": args.seed,
            "backend": jax.default_backend(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        },
        "dense": {},
    }

    variants = [("dense", params)]
    if args.compressed:
        from repro.core.mpifa import MpifaConfig, compress_transformer
        calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                    BENCH_CFG.vocab_size) for i in range(4)]
        cparams = compress_transformer(model, params, calib,
                                       MpifaConfig(density=0.55))
        variants.append(("pifa", cparams))

    for label, p in variants:
        rows = run_modes(model, p, requests, capacity=args.capacity,
                         chunk=args.chunk, eos_id=args.eos_id,
                         warm_requests=warm)
        for mode in ("drain", "continuous"):
            emit(f"serving/{label}/{mode}",
                 rows[mode]["elapsed_s"] * 1e6,
                 f"{rows[mode]['tokens_per_sec']} tok/s "
                 f"p50 {rows[mode]['latency_p50_s']}s "
                 f"p99 {rows[mode]['latency_p99_s']}s "
                 f"occ {rows[mode]['mean_occupancy']}")
        speedup = (rows["continuous"]["tokens_per_sec"]
                   / max(rows["drain"]["tokens_per_sec"], 1e-9))
        rows["speedup"] = round(speedup, 2)
        report[label] = rows
        emit(f"serving/{label}/speedup", 0.0, f"{speedup:.2f}x")

    gate_ok = run_paged_gate(report)
    gate_ok = run_preempt_gate(report) and gate_ok
    gate_ok = run_recovery_gate(report) and gate_ok

    out = Path(args.out)
    out.write_text(json.dumps(report, indent=2) + "\n")
    print(f"[serving_bench] wrote {out}", flush=True)
    return 0 if gate_ok else 1


if __name__ == "__main__":
    sys.exit(main())
