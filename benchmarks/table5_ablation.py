"""Table 5: ablation  W | W+U | W+M | W+M+PIFA  across densities."""
from repro.core.mpifa import MpifaConfig, compress_transformer
from benchmarks.common import calib_tokens, emit, eval_ppl, trained_tiny


def run():
    model, params = trained_tiny()
    calib = calib_tokens(8)
    variants = {
        "W": dict(prune="whiten", reconstruct="none", final_repr="lowrank"),
        "W+U": dict(prune="whiten", reconstruct="fullbatch",
                    final_repr="lowrank"),
        "W+M": dict(prune="whiten", reconstruct="m", final_repr="lowrank"),
        "W+M+PIFA": dict(prune="whiten", reconstruct="m", final_repr="pifa"),
    }
    for density in (0.7, 0.5):
        for name, kw in variants.items():
            cp = compress_transformer(model, params, calib,
                                      MpifaConfig(density=density, **kw))
            ppl = eval_ppl(model, cp, unstacked=True)
            emit(f"table5.d{density:g}.{name}", 0.0, f"{ppl:.3f}")


if __name__ == "__main__":
    run()
