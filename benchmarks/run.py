"""Benchmark orchestrator: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes the aggregate to bench_results.csv.
"""
import importlib
import sys
import time

from benchmarks import common

MODULES = [
    "benchmarks.fig1_params",
    "benchmarks.kernel_bench",
    "benchmarks.table6_layer_efficiency",
    "benchmarks.table2_lowrank_ppl",
    "benchmarks.table5_ablation",
    "benchmarks.table3_semistructured",
    "benchmarks.table4_finetune",
    "benchmarks.fig5_mix_ratio",
    "benchmarks.fig6_calibration",
    "benchmarks.table7_e2e",
]


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    for name in MODULES:
        mod = importlib.import_module(name)
        print(f"# --- {name} ---", flush=True)
        mod.run()
    with open("bench_results.csv", "w") as f:
        f.write("name,us_per_call,derived\n")
        f.write("\n".join(common.ROWS) + "\n")
    print(f"# total {time.time()-t0:.1f}s, {len(common.ROWS)} rows")


if __name__ == '__main__':
    main()
