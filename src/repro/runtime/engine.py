"""Single-dispatch generation engine: prefill + the whole decode loop
as ONE jitted program.

The legacy serving loop (`launch/serve.generate`) re-enters Python and
re-dispatches a jitted step per token, so at small model sizes the
paper's 24.6%-faster PIFA layer vanishes under dispatch overhead — the
exact failure mode that makes low-rank methods look slower than
structured-pruning baselines end-to-end.  Here the decode loop is a
``jax.lax.scan`` *inside* the jitted function: one dispatch per
generation call, O(1) HLO in sequence length, and the KV cache never
round-trips the host.

Sampling: greedy (``temperature=0``) or temperature softmax with
optional top-k truncation, one PRNG key per step.  Early stop: an
``eos_id`` arms a per-sequence done mask — finished rows keep emitting
``eos_id`` (the scan's trip count is static; finished rows are masked,
and the result reports real generated-token counts for honest
tokens/s accounting).

Compressed models reach the scan path through the model zoo's restack
hooks: uniform-rank MPIFA restacks directly; heterogeneous-rank
MPIFA_NS is zero-padded to per-bucket uniform ranks
(`core/mpifa.pad_blocks_bucketed` — exact) instead of falling back to
the O(T^2) full-recompute loop.

This engine runs one batch to completion; for staggered arrivals use
the continuous-batching scheduler on top (`runtime/scheduler.py`),
which shares the restack/prefill/decode surface and admits new
requests into freed KV-cache slots mid-flight.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["GenerationEngine", "GenerationResult", "sample_logits"]


@dataclasses.dataclass(frozen=True)
class GenerationResult:
    """One generation call: prompt+generated tokens and throughput."""

    tokens: jax.Array          # (b, prompt_len + max_new) int32
    tokens_per_sec: float      # generated tokens / wall-clock (post-warmup)
    generated: int             # real (pre-eos) generated token count
    compile_time: float        # first-call tracing+compile seconds (0 if warm)


def sample_logits(logits: jax.Array, key: Optional[jax.Array],
                  temperature: float, top_k: int) -> jax.Array:
    """logits (b, V) -> token (b, 1) int32; greedy when temperature==0."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    tok = jax.random.categorical(key, logits / temperature, axis=-1)
    return tok.astype(jnp.int32)[:, None]


class GenerationEngine:
    """Scanned prefill+decode for any model in the zoo.

    One engine per model; jitted generation functions are cached per
    (max_new, sampling-config, shape) signature, so steady-state serving
    pays exactly one XLA dispatch per generate() call.
    """

    def __init__(self, model, *, max_buckets: int = 4,
                 cache_dtype: Any = jnp.float32):
        self.model = model
        self.max_buckets = max_buckets
        self.cache_dtype = cache_dtype
        self._fns: Dict[Tuple, Any] = {}
        self._spec = None          # lazy SpeculativeEngine (shares restacks)
        # (source-params-object, restacked) pairs; identity-keyed so
        # repeated generate() calls with the same compressed params
        # skip the pad+stack walk (the held reference keeps ids live)
        self._restacked: list = []

    # ------------------------------------------------------------ params
    def prepare_params(self, params: Pytree) -> Pytree:
        """Route list-form (compressed) params back to the scan path.

        Uniform blocks restack directly; heterogeneous ranks (MPIFA_NS)
        are zero-padded to per-bucket uniform ranks.  Raises if the
        blocks cannot be unified — the engine never silently runs the
        O(T^2) unstacked fallback; callers wanting that use the legacy
        loop explicitly.
        """
        if not self._needs_restack(params):
            return params
        for src, restacked in self._restacked:
            if src is params:
                return restacked
        restacked = self.model.restack_blocks(params, pad=True,
                                              max_buckets=self.max_buckets)
        if restacked is None:
            raise ValueError(
                "engine: blocks cannot be re-stacked (mixed representations"
                " at one path); use the legacy unstacked loop instead")
        self._restacked.append((params, restacked))
        if len(self._restacked) > 4:  # bound held params copies
            self._restacked.pop(0)
        return restacked

    def _needs_restack(self, params: Pytree) -> bool:
        if not hasattr(self.model, "restack_blocks"):
            return False
        for key in ("blocks", "mamba", "enc_blocks", "dec_blocks"):
            if key in params and isinstance(params[key], list):
                return True
        return False

    # ---------------------------------------------------------- generate
    def _build(self, max_new: int, temperature: float, top_k: int,
               eos_id: Optional[int]):
        model = self.model

        def run(params, prompts, pf_in, cache, key):
            if temperature > 0.0:
                all_keys = jax.random.split(key, max_new)   # (max_new, 2)
                key0, step_keys = all_keys[0], all_keys[1:]
            else:
                key0 = None
                step_keys = jnp.zeros((max_new - 1, 2), jnp.uint32)
            logits, cache = model.prefill(params, pf_in, cache)
            tok = sample_logits(logits[:, -1, :], key0, temperature, top_k)
            b = prompts.shape[0]
            done = (jnp.zeros((b,), jnp.bool_) if eos_id is None
                    else (tok[:, 0] == eos_id))

            def body(carry, k_t):
                cur, c, d = carry
                lg, c = model.decode_step(params, cur, c)
                nxt = sample_logits(lg[:, -1, :],
                                    k_t if temperature > 0.0 else None,
                                    temperature, top_k)
                if eos_id is not None:
                    nxt = jnp.where(d[:, None], jnp.int32(eos_id), nxt)
                    d = d | (nxt[:, 0] == eos_id)
                return (nxt, c, d), nxt[:, 0]

            (tok_last, cache, done), rest = jax.lax.scan(
                body, (tok, cache, done), step_keys)
            gen = jnp.concatenate([tok, rest.T], axis=1)   # (b, max_new)
            if eos_id is not None:
                n_real = jnp.sum(
                    jnp.cumprod((gen != eos_id).astype(jnp.int32), axis=1))
            else:
                n_real = jnp.int32(gen.size)
            return jnp.concatenate([prompts, gen], axis=1), n_real

        return jax.jit(run)

    def generate(self, params: Pytree, prompts: jax.Array, max_new: int,
                 cache_len: Optional[int] = None, *,
                 temperature: float = 0.0, top_k: int = 0,
                 eos_id: Optional[int] = None,
                 key: Optional[jax.Array] = None,
                 prefill_inputs: Optional[Pytree] = None
                 ) -> GenerationResult:
        """Generate ``max_new`` tokens after ``prompts`` (b, s) int32.

        ``prefill_inputs`` substitutes for ``prompts`` in the prefill
        call for families with richer prefill batches (enc-dec frames).
        """
        assert max_new >= 1
        params = self.prepare_params(params)
        b, s = prompts.shape[0], prompts.shape[1]
        if cache_len is None:
            cache_len = s + max_new + 1
        from repro.models.linear import _PIFA_KERNEL
        if _PIFA_KERNEL:
            # pin per-bucket kernel block sizes for this decode batch
            # BEFORE tracing: bucket ranks are known post-restack, and
            # the registry is read at trace time (kernels/pifa_matmul/
            # autotune.py) — entries registered later would not retrace
            # an already-cached generate fn.
            from repro.kernels.pifa_matmul.autotune import tune_pifa_params
            tune_pifa_params(params, b)
        # the kernel-routing flag is read at trace time inside
        # apply_linear, so it must be part of the jit-cache key or a
        # toggle would silently keep serving the stale path; params
        # structure/shapes/dtypes are part of the key so the cold/warm
        # distinction below matches jit's actual retrace conditions
        # (dense vs pifa params under one engine must not alias)
        leaves, treedef = jax.tree_util.tree_flatten(params)
        pf_sig = None
        if prefill_inputs is not None:
            pfl, pft = jax.tree_util.tree_flatten(prefill_inputs)
            pf_sig = (pft, tuple((l.shape, str(l.dtype)) for l in pfl))
        sig = (max_new, float(temperature), int(top_k), eos_id, b, s,
               cache_len, _PIFA_KERNEL, treedef,
               tuple((l.shape, str(l.dtype)) for l in leaves), pf_sig)
        cold = sig not in self._fns
        if cold:
            self._fns[sig] = self._build(max_new, float(temperature),
                                         int(top_k), eos_id)
        fn = self._fns[sig]
        cache = self.model.init_cache(b, cache_len, dtype=self.cache_dtype)
        if key is None:
            key = jax.random.PRNGKey(0)
        pf_in = prompts if prefill_inputs is None else prefill_inputs

        t0 = time.perf_counter()
        tokens, n_real = fn(params, prompts, pf_in, cache, key)
        jax.block_until_ready(tokens)
        dt = time.perf_counter() - t0
        compile_time = 0.0
        if cold:
            # one warm re-run so tokens_per_sec is the steady-state
            # number (the first call paid tracing+compile); warm calls
            # run exactly once
            t_first = dt
            cache = self.model.init_cache(b, cache_len,
                                          dtype=self.cache_dtype)
            t0 = time.perf_counter()
            tokens, n_real = fn(params, prompts, pf_in, cache, key)
            jax.block_until_ready(tokens)
            dt = time.perf_counter() - t0
            compile_time = max(0.0, t_first - dt)
        n = int(n_real)
        return GenerationResult(tokens=tokens,
                                tokens_per_sec=n / max(dt, 1e-9),
                                generated=n,
                                compile_time=compile_time)

    # ------------------------------------------------------- speculative
    def generate_speculative(self, params: Pytree, draft_params: Pytree,
                             prompts: jax.Array, max_new: int,
                             cache_len: Optional[int] = None, *,
                             spec_k: int = 4, temperature: float = 0.0,
                             top_k: int = 0, eos_id: Optional[int] = None,
                             key: Optional[jax.Array] = None,
                             prefill_inputs: Optional[Pytree] = None):
        """Draft-then-verify generation: ``draft_params`` (a more
        aggressively compressed model of the same architecture)
        proposes ``spec_k`` tokens per round, ``params`` verifies all
        k+1 positions in one dispatch.  Greedy output is bit-identical
        to :meth:`generate` for every family (SSM/ring caches verify
        through per-step state checkpoints); sampled output draws from
        the same distribution with per-row keyed streams.  See
        runtime/speculative.py for the accept / rollback machinery and
        accounting; ``prefill_inputs`` as in :meth:`generate`.
        """
        if self._spec is None:
            from repro.runtime.speculative import SpeculativeEngine
            self._spec = SpeculativeEngine(
                self.model, max_buckets=self.max_buckets,
                cache_dtype=self.cache_dtype, restacker=self)
        return self._spec.generate(
            params, draft_params, prompts, max_new, cache_len,
            spec_k=spec_k, temperature=temperature, top_k=top_k,
            eos_id=eos_id, key=key, prefill_inputs=prefill_inputs)
