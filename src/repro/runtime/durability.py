"""Durable serving: write-ahead request journal + scheduler snapshots
with crash recovery and bit-identical resume.

PR 6 made the continuous-batching scheduler preemptible: an active slot
can be parked host-side as a :class:`~repro.runtime.scheduler._SavedSlot`
(page payloads, per-slot device rows, PRNG key, speculative round
counter, emitted tokens) and re-admitted later with a BIT-IDENTICAL
continuation.  That machinery only lived in process memory — process
death lost every in-flight request.  This module puts it on disk:

  * :class:`RequestJournal` — an append-only write-ahead log.  Every
    scheduler event (submit / emit-chunk / finalize / cancel / reject,
    plus one ``config`` record pinning the resolved geometry) is a
    CRC32-framed JSON record, fsync'd per append, so the journal on disk
    is always a consistent prefix of the run.  A torn tail (partial or
    CRC-failing record at EOF — the crash landed mid-write) is truncated
    on open; everything before it is intact by induction.
  * :class:`SnapshotStore` — periodic scheduler snapshots: one ``.npz``
    per active slot (its save_restore payload) plus ``meta.json``
    (scalars, the queue, per-file CRCs), written on a background thread
    and committed with the checkpointer's atomic-rename protocol
    (``.tmp`` dir -> fsync -> ``os.replace`` — see
    ``checkpoint/checkpointer.py``).  Snapshots are named by the journal
    LSN at capture time, so recency ordering survives restarts.
  * :func:`recover_into` — opens the latest committed snapshot, injects
    each saved slot into a FRESH scheduler's preempted-parking map
    (restore onto fresh physical pages rides the existing re-admission
    path), re-queues the snapshot queue plus every journaled submit the
    snapshot predates, and re-applies unhonoured cancels.  Finished
    requests are reconstructed from their finalize records.
  * :func:`finish_recovered` — drains the recovered scheduler, merges
    with the pre-crash results, and verifies every journaled token
    prefix was re-emitted bitwise identically (the zero-token-loss
    contract: ``mismatches`` must be 0).

Why recovery is bit-identical: a restored slot resumes through PR 6's
save_restore path (same pages, same rows, same key/round scalars — the
preemption tests already pin this), and a request re-queued from
scratch regenerates its exact stream because per-request PRNG keys are
``fold_in(scheduler_key, request_id)`` — placement-, order- and
boundary-invariant by construction.  Replayed prefixes therefore agree
token for token with what the crashed run already emitted, for greedy
AND sampled, plain AND speculative slots.

Graceful degradation, outermost first:

  * snapshot ``meta.json`` unreadable / CRC-torn -> try the previous
    snapshot; none left -> journal-only recovery (everything re-queued
    from scratch — slower, still bit-identical);
  * one slot's ``.npz`` fails its CRC -> only that slot degrades to
    recompute-from-journaled-prefix (``_SavedSlot.mode="recompute"``:
    re-prefill prompt + emitted tokens, scalars from the snapshot
    meta); the other slots still restore from their payloads;
  * a stale snapshot (older than some finalizes) is safe: slots and
    queue entries whose request already finalized per the journal are
    skipped;
  * dispatch errors during the resumed drain ride the scheduler's
    existing ``RestartPolicy`` retry loop, exactly as before the crash.

Crash injection for tests: ``FaultPlan().at(step, "crash")`` raises
:class:`~repro.runtime.fault_tolerance.SchedulerCrash` at that chunk
boundary with no cleanup — the journal is already fsync'd record by
record, so disk state is exactly what a SIGKILL would leave.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import re
import struct
import threading
import time
import zlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.checkpoint.checkpointer import commit_dir, crc32_file
from repro.runtime.scheduler import (CancelReason, Rejected, Request,
                                     RequestResult, SchedulerRun,
                                     ServingScheduler, _request_meta,
                                     _SavedSlot)

__all__ = ["RequestJournal", "SnapshotStore", "Durability", "RecoveryInfo",
           "RecoveredRun", "CorruptSnapshot", "recover_into",
           "finish_recovered"]

# record framing: u32 payload length + u32 CRC32(payload), then the
# JSON payload — fixed-width header so a torn tail is detectable by
# length alone even before the CRC check
_HDR = struct.Struct("<II")


class CorruptSnapshot(RuntimeError):
    """A snapshot's ``meta.json`` is unreadable — the whole snapshot is
    unusable and recovery falls back to an older one (per-SLOT payload
    corruption degrades more gently; see :meth:`SnapshotStore.load`)."""


# --------------------------------------------------------------- journal
class RequestJournal:
    """Append-only fsync'd write-ahead log of scheduler events.

    ``lsn`` (log sequence number) is the byte offset past the last
    committed record — snapshots stamp it so recovery knows which
    journal suffix postdates them.  Opening truncates any torn tail
    (``truncated_bytes`` reports how much); :meth:`read` replays without
    opening for append.
    """

    def __init__(self, path, fsync: bool = True):
        self.path = pathlib.Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self.fsync = bool(fsync)
        self.truncated_bytes = self._truncate_torn_tail()
        self._fh = open(self.path, "ab")
        self.lsn = self.path.stat().st_size

    def _truncate_torn_tail(self) -> int:
        if not self.path.exists():
            return 0
        data = self.path.read_bytes()
        off = 0
        while off + _HDR.size <= len(data):
            n, crc = _HDR.unpack_from(data, off)
            end = off + _HDR.size + n
            if end > len(data):
                break                      # partial record at EOF
            if zlib.crc32(data[off + _HDR.size:end]) & 0xFFFFFFFF != crc:
                break                      # bit rot / torn write
            off = end
        torn = len(data) - off
        if torn:
            with open(self.path, "r+b") as fh:
                fh.truncate(off)
                fh.flush()
                os.fsync(fh.fileno())
        return torn

    def append(self, kind: str, **fields) -> int:
        """Append one record and fsync; returns the new LSN."""
        payload = json.dumps({"kind": kind, **fields},
                             separators=(",", ":")).encode("utf-8")
        self._fh.write(_HDR.pack(len(payload),
                                 zlib.crc32(payload) & 0xFFFFFFFF))
        self._fh.write(payload)
        self._fh.flush()
        if self.fsync:
            os.fsync(self._fh.fileno())
        self.lsn += _HDR.size + len(payload)
        return self.lsn

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    @classmethod
    def read(cls, path) -> Tuple[List[Dict[str, Any]], int]:
        """Committed records + torn-tail byte count, read-only (no
        truncation — safe while another handle appends)."""
        path = pathlib.Path(path)
        if not path.exists():
            return [], 0
        data = path.read_bytes()
        out: List[Dict[str, Any]] = []
        off = 0
        while off + _HDR.size <= len(data):
            n, crc = _HDR.unpack_from(data, off)
            end = off + _HDR.size + n
            if end > len(data):
                break
            payload = data[off + _HDR.size:end]
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                break
            out.append(json.loads(payload.decode("utf-8")))
            off = end
        return out, len(data) - off


# ------------------------------------------------------------- snapshots
class SnapshotStore:
    """Atomic, async scheduler snapshots under ``<dir>/snap_<lsn>/``.

    One ``slot_NNN.npz`` per active slot (save_restore payload: rows /
    draft rows / page payloads) plus ``meta.json`` carrying scalars,
    the queue, the config fingerprint and a per-file CRC32.  The write
    runs on a background thread and commits via the checkpointer's
    atomic-rename protocol, so a crash mid-snapshot leaves the previous
    snapshot untouched and the torn ``.tmp`` invisible.
    """

    def __init__(self, directory, keep: int = 2):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = int(keep)
        self._thread: Optional[threading.Thread] = None

    def save(self, tag: int, slot_arrays: Dict[int, Dict[str, np.ndarray]],
             meta: Dict[str, Any], blocking: bool = False) -> None:
        """Write snapshot ``tag`` (the journal LSN) asynchronously."""
        self.wait()

        def _write():
            tmp = self.dir / f"snap_{int(tag):012d}.tmp"
            final = self.dir / f"snap_{int(tag):012d}"
            if final.exists():             # idempotent re-save
                return
            tmp.mkdir(parents=True, exist_ok=True)
            files = {}
            for slot, arrays in slot_arrays.items():
                f = tmp / f"slot_{int(slot):03d}.npz"
                np.savez(f, **arrays)
                files[str(int(slot))] = {"file": f.name,
                                         "crc": crc32_file(f)}
            m = dict(meta)
            m["files"] = files
            (tmp / "meta.json").write_text(json.dumps(m))
            commit_dir(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        for tag in self.tags()[:-self.keep] if self.keep else []:
            d = self.dir / f"snap_{tag:012d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    def tags(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"snap_(\d+)", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def load(self, tag: int) -> Tuple[Dict[str, Any],
                                      Dict[int, Optional[Dict[str, Any]]],
                                      List[int]]:
        """-> (meta, per-slot arrays, corrupt slot ids).

        A slot whose ``.npz`` fails its CRC (or cannot be read) maps to
        ``None`` and lands in the corrupt list — the caller degrades
        that slot to recompute-from-journaled-prefix instead of losing
        the snapshot.  An unreadable ``meta.json`` raises
        :class:`CorruptSnapshot` (fall back to an older snapshot, then
        to journal-only recovery)."""
        d = self.dir / f"snap_{int(tag):012d}"
        try:
            meta = json.loads((d / "meta.json").read_text())
        except Exception as e:
            raise CorruptSnapshot(f"{d / 'meta.json'} unreadable: {e}")
        arrays: Dict[int, Optional[Dict[str, Any]]] = {}
        corrupt: List[int] = []
        for slot_s, ent in meta.get("files", {}).items():
            slot = int(slot_s)
            f = d / ent["file"]
            try:
                if crc32_file(f) != int(ent["crc"]):
                    raise OSError("CRC32 mismatch")
                with np.load(f) as z:
                    arrays[slot] = {k: z[k] for k in z.files}
            except Exception:
                arrays[slot] = None
                corrupt.append(slot)
        return meta, arrays, sorted(corrupt)


class Durability:
    """One serving run's durable state: journal + snapshot store.

    Pass to the scheduler (``ServingScheduler(..., durability=...)``) to
    journal every event and snapshot every ``snapshot_every`` chunk
    dispatches.  After a crash, construct a fresh ``Durability`` over
    the same directory and hand it to a fresh scheduler, then call
    :func:`recover_into` / :func:`finish_recovered`.
    """

    def __init__(self, directory, *, snapshot_every: int = 8,
                 keep: int = 2, fsync: bool = True):
        self.dir = pathlib.Path(directory)
        self.journal = RequestJournal(self.dir / "journal.wal", fsync=fsync)
        self.store = SnapshotStore(self.dir / "snapshots", keep=keep)
        self.snapshot_every = int(snapshot_every)

    def wait(self) -> None:
        self.store.wait()

    def close(self) -> None:
        self.store.wait()
        self.journal.close()


# -------------------------------------------------------------- recovery
def _request_from_meta(m: Dict[str, Any]) -> Request:
    return Request(
        request_id=int(m["rid"]),
        prompt=np.asarray(m["prompt"], np.int32),
        max_new=int(m["max_new"]),
        arrival_time=float(m["arrival_time"]),
        speculative=bool(m["speculative"]),
        priority=int(m["priority"]),
        deadline_s=(None if m["deadline_s"] is None
                    else float(m["deadline_s"])))


@dataclasses.dataclass
class _JournalState:
    """Folded view of the journal: first submit / latest emit state /
    last finalize per request, plus rejects and cancels in order."""

    config: Optional[Dict[str, Any]] = None
    submits: Dict[int, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    emits: Dict[int, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    finals: Dict[int, Dict[str, Any]] = dataclasses.field(
        default_factory=dict)
    rejects: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    cancels: List[int] = dataclasses.field(default_factory=list)


def _replay(records: List[Dict[str, Any]]) -> _JournalState:
    st = _JournalState()
    for r in records:
        kind = r.get("kind")
        if kind == "config":
            if st.config is None:
                st.config = r
        elif kind == "submit":
            # first submission wins: recovery re-journals submits, so
            # later duplicates are expected and identical
            st.submits.setdefault(int(r["rid"]), r)
        elif kind == "emit":
            e = st.emits.setdefault(int(r["rid"]), {"toks": []})
            toks: List[int] = e["toks"]
            at = int(r["at"])
            if at > len(toks):
                continue                   # gap: unreachable by protocol
            toks[at:at + len(r["toks"])] = [int(t) for t in r["toks"]]
            e.update(tok=int(r["tok"]), keys=list(r["keys"]),
                     acc=r.get("acc"), drafted=r.get("drafted"),
                     rounds=r.get("rounds"))
        elif kind == "finalize":
            st.finals[int(r["rid"])] = r
        elif kind == "reject":
            st.rejects.append(r)
        elif kind == "cancel":
            st.cancels.append(int(r["rid"]))
    return st


@dataclasses.dataclass
class RecoveryInfo:
    """What recovery reconstructed, and how."""

    prior_results: List[RequestResult]    # finalized before the crash
    prior_rejected: List[Rejected]
    replay: Dict[int, List[int]]          # rid -> journaled token prefix
    snapshot_tag: Optional[int]           # LSN of the snapshot used
    restored: List[int]                   # rids restored from payloads
    recomputed: List[int]                 # rids degraded to recompute
    requeued: List[int]                   # rids re-queued from scratch
    corrupt_slots: List[int]              # snapshot slots failing CRC
    truncated_bytes: int                  # torn journal tail dropped
    recover_s: float                      # wall-clock recovery time


@dataclasses.dataclass
class RecoveredRun:
    """A drained recovery: merged results + the zero-token-loss audit."""

    run: SchedulerRun                     # prior + resumed, merged
    resumed: SchedulerRun                 # the post-crash drain alone
    info: RecoveryInfo
    replayed: int                         # journaled tokens re-verified
    mismatches: int                       # MUST be 0 (bit-identity)


def _saved_from_snapshot(sched: ServingScheduler, sm: Dict[str, Any],
                         arr: Optional[Dict[str, Any]]) -> _SavedSlot:
    """Rebuild a ``_SavedSlot`` from snapshot meta + (maybe) payloads.

    With intact payload arrays the slot restores at save_restore depth
    (bit-identical continuation); a CRC-corrupt payload degrades to
    ``mode="recompute"`` — the meta scalars alone are enough to
    re-prefill prompt + emitted prefix and continue the exact stream
    scalars (tok / PRNG key / round counter)."""
    saved = _SavedSlot(
        tokens=[int(t) for t in sm["tokens"]],
        count=int(sm["count"]), pos=int(sm["pos"]),
        tok=np.asarray([int(sm["tok"])], np.int32),
        keys=np.asarray(sm["keys"], np.uint32),
        admitted_at=float(sm["admitted_at"]),
        n_preempts=int(sm["n_preempts"]),
        mode="recompute")
    if sched.speculative:
        saved.spec = bool(sm["spec"])
        saved.acc = int(sm["acc"])
        saved.drafted = int(sm["drafted"])
        saved.rounds = int(sm["rounds"])
    if arr is None:
        return saved
    rows = {k[len("rows__"):]: arr[k] for k in arr
            if k.startswith("rows__")}
    drows = {k[len("drows__"):]: arr[k] for k in arr
             if k.startswith("drows__")}
    pages = {k[len("pages__"):]: arr[k] for k in arr
             if k.startswith("pages__")}
    dpages = {k[len("dpages__"):]: arr[k] for k in arr
              if k.startswith("dpages__")}
    saved.rows = rows
    saved.drows = drows or None
    saved.pages = pages or None
    saved.dpages = dpages or None
    saved.mode = "save_restore"
    return saved


def recover_into(sched: ServingScheduler,
                 durability: Optional[Durability] = None) -> RecoveryInfo:
    """Load journal + latest committed snapshot into a FRESH scheduler.

    The scheduler must be constructed exactly as the crashed one was
    (same model/params/config — the journal's ``config`` record is
    checked and a mismatch raises, because resumed streams would not be
    bit-identical).  Active slots land in the preempted-parking map and
    re-admit through the existing restore path onto fresh physical
    pages; everything else is re-queued.  With ``prefix_cache=True``
    each restore also re-seeds the prefix index from its private
    prompt pages (the crashed process's index was host-side state), so
    sharing resumes organically and recovered streams stay
    bit-identical — shared pages hold the same values at different
    addresses.  Call :func:`finish_recovered` (or ``sched.run()``)
    afterwards to drain.
    """
    dur = durability if durability is not None else sched._durability
    if dur is None:
        raise ValueError(
            "recover_into needs a Durability (pass one, or construct the "
            "scheduler with durability=...)")
    t0 = time.perf_counter()
    records, torn = RequestJournal.read(dur.journal.path)
    state = _replay(records)

    # pin the resolved geometry from the journal BEFORE _ensure_state
    # derives defaults from the (empty) queue
    cfg = state.config
    if cfg is not None:
        if sched._cache_len is None:
            sched._cache_len = int(cfg["cache_len"])
        if sched.num_pages is None and cfg.get("num_pages") is not None:
            sched.num_pages = int(cfg["num_pages"])
    sched._ensure_state()
    if cfg is not None:
        mine = sched._durability_config()
        diffs = {k: (cfg[k], mine[k]) for k in mine
                 if k in cfg and cfg[k] != mine[k]}
        if diffs:
            raise ValueError(
                "journal/scheduler config mismatch — a resumed stream "
                f"would not be bit-identical: {diffs}")

    # finished work, reconstructed from finalize (+ submit) records
    prior_results: List[RequestResult] = []
    for rid in sorted(state.finals):
        f = state.finals[rid]
        sub = state.submits.get(rid)
        if sub is None:
            continue                       # unreachable: submit precedes
        prompt = np.asarray(sub["prompt"], np.int32)
        prior_results.append(RequestResult(
            request_id=rid,
            tokens=np.concatenate(
                [prompt, np.asarray(f["toks"], np.int32)]),
            generated=int(f["generated"]),
            prompt_len=int(f["prompt_len"]),
            slot=int(f.get("slot", -1)),
            arrival_time=float(f["arrival"]),
            admitted_at=float(f["admitted"]),
            finished_at=float(f["finished"]),
            accepted=f.get("accepted"),
            drafted=f.get("drafted"),
            cancel_reason=(CancelReason(f["reason"])
                           if f.get("reason") else None),
            preemptions=int(f.get("preemptions", 0))))
    prior_rejected = [Rejected(request_id=int(r["rid"]),
                               reason=str(r["reason"]),
                               attempts=int(r["attempts"]),
                               rejected_at=float(r["at_s"]))
                      for r in state.rejects]
    done_rids = set(state.finals) | {r.request_id for r in prior_rejected}

    # newest usable snapshot; meta corruption falls back to older ones,
    # and with none left recovery is journal-only (slower, still exact)
    dur.store.wait()
    snap_tag = None
    meta: Optional[Dict[str, Any]] = None
    arrays: Dict[int, Optional[Dict[str, Any]]] = {}
    corrupt: List[int] = []
    for tag in reversed(dur.store.tags()):
        try:
            meta, arrays, corrupt = dur.store.load(tag)
            snap_tag = tag
            break
        except CorruptSnapshot:
            continue

    restored: List[int] = []
    recomputed: List[int] = []
    requeued: List[int] = []
    queued: set = set()
    submitted: List[Request] = []
    if meta is not None:
        for slot_s, sm in meta.get("slots", {}).items():
            rid = int(sm["request"]["rid"])
            if rid in done_rids or rid in queued:
                continue                   # stale snapshot: already done
            saved = _saved_from_snapshot(sched, sm, arrays.get(int(slot_s)))
            (restored if saved.mode == "save_restore"
             else recomputed).append(rid)
            sched._preempted[rid] = saved
            submitted.append(_request_from_meta(sm["request"]))
            queued.add(rid)
        for qm in meta.get("queue", []):
            rid = int(qm["rid"])
            if rid in done_rids or rid in queued:
                continue
            submitted.append(_request_from_meta(qm))
            queued.add(rid)
    # journal suffix: submits the snapshot predates (or journal-only
    # recovery: every unfinished submit) re-queue from scratch — their
    # fold_in(key, rid) streams regenerate the journaled prefix exactly
    for rid in sorted(state.submits):
        if rid in done_rids or rid in queued:
            continue
        submitted.append(_request_from_meta(state.submits[rid]))
        queued.add(rid)
        requeued.append(rid)

    for req in sorted(submitted, key=ServingScheduler._qkey):
        sched.submit(req)
    # journaled-but-unhonoured cancels apply at the first boundary
    for rid in state.cancels:
        if rid not in done_rids:
            sched.cancel(rid)

    replay = {rid: list(e["toks"]) for rid, e in state.emits.items()
              if rid not in done_rids and e["toks"]}
    return RecoveryInfo(
        prior_results=prior_results, prior_rejected=prior_rejected,
        replay=replay, snapshot_tag=snap_tag, restored=sorted(restored),
        recomputed=sorted(recomputed), requeued=requeued,
        corrupt_slots=corrupt, truncated_bytes=torn,
        recover_s=time.perf_counter() - t0)


def finish_recovered(sched: ServingScheduler, info: RecoveryInfo
                     ) -> RecoveredRun:
    """Drain the recovered scheduler and audit zero token loss.

    Every journaled prefix must be re-emitted bitwise identically —
    ``mismatches`` counts requests whose resumed stream diverged from
    (or fell short of) what the crashed run already produced, and MUST
    be 0.  ``run`` merges pre-crash results with the resumed drain, so
    callers see one complete ``SchedulerRun`` for the logical serving
    run."""
    resumed = sched.run()
    by_rid = {r.request_id: r for r in resumed.results}
    replayed = 0
    mismatches = 0
    for rid, prefix in info.replay.items():
        r = by_rid.get(rid)
        if r is None:
            continue                       # rejected on resume
        gen = [int(t) for t in r.tokens[r.prompt_len:]]
        n = min(len(prefix), len(gen))
        replayed += n
        if gen[:n] != prefix[:n]:
            mismatches += 1
        elif r.cancel_reason is None and len(gen) < len(prefix):
            mismatches += 1                # lost already-emitted tokens
    results = info.prior_results + resumed.results
    merged = SchedulerRun(
        results=results,
        elapsed=resumed.elapsed,
        generated=sum(r.generated for r in results),
        chunks=resumed.chunks,
        occupancy=resumed.occupancy,
        accepted=sum(r.accepted for r in results
                     if r.accepted is not None),
        drafted=sum(r.drafted for r in results
                    if r.drafted is not None),
        deferrals=resumed.deferrals,
        rejected=info.prior_rejected + resumed.rejected,
        preemptions=resumed.preemptions,
        resumes=resumed.resumes,
        slow_chunks=resumed.slow_chunks,
        page_high_water=resumed.page_high_water,
        prefix_hits=resumed.prefix_hits,
        prefix_misses=resumed.prefix_misses,
        cow_copies=resumed.cow_copies,
        swap_ins=resumed.swap_ins,
        swap_outs=resumed.swap_outs)
    return RecoveredRun(run=merged, resumed=resumed, info=info,
                        replayed=replayed, mismatches=mismatches)
