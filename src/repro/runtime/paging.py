"""Paged (block-table) KV cache: pool layout, host allocator, helpers.

The continuous-batching scheduler originally gave every slot a
contiguous ``cache_len``-long cache row, so HBM capacity was set by the
LONGEST request any slot might see — a mixed-length request mix wastes
most of it.  Paged mode replaces the per-slot rows with one fixed pool
of ``page_size``-token blocks shared by all slots:

  * the ``k``/``v`` cache leaves become **pools**
    ``(layers, num_pages + 1, page_size, kv_heads, head_dim)``; page 0
    is a reserved sentinel (never allocated — unmapped block-table
    entries point at it, so frozen-slot junk writes land there and
    gathers of unmapped pages read garbage that the causal/``kv_len``
    mask excludes exactly);
  * each slot owns a **block table** row ``bt[slot, j] = physical page
    holding logical positions [j*P, (j+1)*P)``; decode writes scatter at
    ``(bt[pos // P], pos % P)`` and reads gather ``pool[bt]`` back into
    a position-ordered logical view, then run the UNCHANGED attention
    computation — same values, different addressing, which is why paged
    output is bit-identical to contiguous mode;
  * a host-side :class:`PageAllocator` hands pages out at admission
    (prompt pages) and at chunk boundaries (on-demand append for the
    next chunk's writes), and takes them back on finalize.  Exhaustion
    REFUSES (raises :class:`PoolExhausted`) — it never evicts or
    silently overwrites a live page.

Reservation accounting makes mid-flight exhaustion impossible by
construction: admission reserves each request's worst-case page count
(prompt bucket + generation budget + speculative margin) without
allocating it, and only admits while ``free - outstanding_reservations``
covers the newcomer.  Chunk-boundary extension never exceeds a slot's
reservation, so an admitted request can always finish.  Capacity still
beats contiguous slots because the reservation is the REQUEST's worst
case, not the global ``cache_len``.

Pages are REFCOUNTED so full prompt-prefix pages can be shared across
slots: :class:`PrefixIndex` keys each full page of a prompt by the
blake2b chain digest of ``(params_fingerprint, token prefix)``, and an
admission whose prompt prefix is already resident maps the shared pages
into its block table (refcount + 1 per page) and prefills only the
uncached tail.  Shared pages are read-only by construction — the tail
prefill starts past the shared region, and ``cow`` (copy-on-write)
detaches the one page a writer would touch (a full-page-aligned hit
re-prefills its last token for logits, so that page is detached before
the write).  ``free`` decrements; a page returns to the free list only
at refcount 0.  Under admission pressure the index SPILLS its coldest
index-only pages to host memory (LRU order) instead of deferring with
``no_pages``, swapping them back in on the next hit.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

__all__ = ["PoolExhausted", "PageAllocator", "PrefixIndex", "PAGED_KEYS",
           "pages_for", "paged_cache_spec", "make_paged_cache",
           "paginate_cache", "logical_view", "scatter_prompt_pages",
           "copy_page", "extract_page", "inject_page", "params_fingerprint"]

# cache leaves that hold positional KV entries and therefore page;
# every other leaf (pos, conv/ssm state, encdec cross-KV, ring kl/vl)
# keeps its per-slot layout
PAGED_KEYS = ("k", "v")


class PoolExhausted(RuntimeError):
    """The page pool cannot satisfy an allocation.  Raised instead of
    evicting or silently overwriting a live page."""


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` cache entries."""
    return -(-int(tokens) // int(page_size))


def scatter_prompt_pages(pool: jnp.ndarray, sm: jnp.ndarray,
                         pages: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """Land contiguously-prefilled KV rows into physical pool pages.

    ``sm`` is ``(L, kb, length, ...)`` — ``kb`` rows of a scratch prefill —
    and ``pages`` is ``(kb, npg)`` physical page ids.  The row tail is
    page-padded (pad entries stay causally masked: the write pointer and
    attention length both stop at the true position), split into
    ``npg`` pages of ``page_size``, and scattered into
    ``pool (L, num_pages+1, page_size, ...)``.  A migration/test helper:
    the scheduler's admission and resume paths prefill NATIVELY through
    the block table (models/layers.py) and never take this detour.
    """
    kb, length = int(sm.shape[1]), int(sm.shape[2])
    npg = int(pages.shape[-1])
    pad = npg * int(page_size) - length
    if pad:
        sm = jnp.pad(sm, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (sm.ndim - 3))
    sm = sm.reshape(sm.shape[:2] + (npg, int(page_size)) + sm.shape[3:])
    return pool.at[:, pages].set(sm.astype(pool.dtype))


class PageAllocator:
    """Host-side block-table allocator over ``num_pages`` usable pages.

    Physical page ids run 1..num_pages (page 0 is the sentinel and is
    never handed out).  ``table`` is the (capacity, n_logical) int32
    block table mirrored to the device before each chunk dispatch;
    unmapped entries are 0.

    Every live page carries a REFCOUNT: 1 for each slot mapping it plus
    1 for each prefix-index pin.  ``admit`` can map already-resident
    shared pages (refcount + 1 each) ahead of its private allocations;
    ``cow`` detaches a slot from a shared page before a divergent write;
    ``free``/``unpin`` decrement and only return a page to the free list
    at refcount 0.

    Invariants (property-tested in tests/test_paged.py):
      * a page's refcount equals the number of slot mappings plus pins,
        and a page is never mapped twice by ONE slot;
      * a page with refcount > 1 is never written (writers must ``cow``
        first — the scheduler's tail prefill starts past shared pages);
      * the sentinel is never allocated;
      * after every slot frees and every pin drops,
        ``free_pages == num_pages`` (no leaks);
      * allocation beyond the pool raises :class:`PoolExhausted` —
        nothing is evicted.
    """

    def __init__(self, num_pages: int, page_size: int, capacity: int,
                 n_logical: int):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.capacity = int(capacity)
        self.n_logical = int(n_logical)
        # LIFO free list keeps recently-freed (still-warm) pages hot
        self._free: List[int] = list(range(self.num_pages, 0, -1))
        self._pages: List[List[int]] = [[] for _ in range(self.capacity)]
        self._reserved: List[int] = [0] * self.capacity
        self._refcnt: Dict[int, int] = {}    # live page -> references
        self._pins: Dict[int, int] = {}      # live page -> index pins
        self.table = np.zeros((self.capacity, self.n_logical), np.int32)
        self._fail_next = 0              # armed injected faults (tests)
        self.high_water = 0              # peak pages in use (pool - free)
        self.cow_copies = 0              # pages detached by cow()

    # ------------------------------------------------------------- state
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.num_pages - len(self._free)

    def slot_pages(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._pages[slot])

    def refcount(self, page: int) -> int:
        return self._refcnt.get(int(page), 0)

    def pin_count(self, page: int) -> int:
        return self._pins.get(int(page), 0)

    def pinned_pages(self) -> int:
        """Pages currently held (at least in part) by prefix-index pins."""
        return len(self._pins)

    def shared_pages(self) -> int:
        """Live pages referenced more than once (slot or pin)."""
        return sum(1 for n in self._refcnt.values() if n > 1)

    def outstanding(self) -> int:
        """Reserved-but-not-yet-allocated pages across live slots."""
        return sum(max(0, r - len(p))
                   for r, p in zip(self._reserved, self._pages))

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    def headroom(self) -> int:
        """Pages allocatable right now without touching a reservation."""
        return len(self._free) - self.outstanding()

    def accounting(self) -> str:
        """One-line reservation-accounting snapshot for capacity
        incidents: free / outstanding / reserved / refcounted pages."""
        return (f"free={len(self._free)}/{self.num_pages} "
                f"outstanding={self.outstanding()} "
                f"reserved={sum(self._reserved)} "
                f"refcounted={self.shared_pages()} "
                f"pinned={self.pinned_pages()} "
                f"high_water={self.high_water}")

    # ---------------------------------------------------- fault injection
    def inject_fault(self, n: int = 1) -> None:
        """Arm the allocator to raise :class:`PoolExhausted` on its next
        ``n`` admit/extend calls (even ones that would succeed).  Used by
        the scheduler's FaultPlan harness to prove admission is atomic
        and chunk-boundary extension is retryable."""
        self._fail_next += int(n)

    def _maybe_fail(self, op: str) -> None:
        if self._fail_next > 0:
            self._fail_next -= 1
            raise PoolExhausted(
                f"injected allocator fault during {op} [{self.accounting()}]")

    # -------------------------------------------------------- operations
    def can_admit(self, reserve_tokens: int, shared_pages: int = 0) -> bool:
        """True when a request reserving ``reserve_tokens`` worst-case
        cache entries — ``shared_pages`` of them already resident via the
        prefix index — can be admitted WITHOUT ever exhausting the pool
        mid-flight (its future extends stay within the reservation)."""
        need = max(0, self.pages_for(reserve_tokens) - int(shared_pages))
        return need <= self.headroom()

    def admit(self, slot: int, tokens_now: int,
              reserve_tokens: Optional[int] = None,
              shared: Tuple[int, ...] = ()) -> List[int]:
        """Allocate pages covering ``tokens_now`` entries for an empty
        slot, reserving ``reserve_tokens`` (>= tokens_now) worst case.

        ``shared`` pages (already resident, found via the prefix index)
        are mapped as the slot's leading logical pages — refcount + 1
        each, no allocation — and only the remainder is drawn from the
        free list.  Returns the newly-allocated private pages."""
        if self._pages[slot]:
            raise ValueError(f"slot {slot} still holds pages — free first")
        self._maybe_fail("admit")
        need = self.pages_for(tokens_now)
        reserve = max(need, self.pages_for(reserve_tokens)
                      if reserve_tokens is not None else need)
        shared = tuple(int(p) for p in shared)
        if len(shared) > need:
            raise ValueError(
                f"slot {slot}: {len(shared)} shared pages exceed the "
                f"{need}-page prompt mapping")
        if reserve - len(shared) > self.headroom():
            raise PoolExhausted(
                f"page pool exhausted: slot {slot} needs "
                f"{reserve - len(shared)} new pages (reservation of "
                f"{reserve}, {len(shared)} shared) [{self.accounting()}]")
        self._reserved[slot] = reserve
        for pg in shared:
            if self._refcnt.get(pg, 0) < 1:
                raise ValueError(f"shared page {pg} is not live")
            if pg in self._pages[slot]:
                raise ValueError(f"page {pg} mapped twice by slot {slot}")
            self._refcnt[pg] += 1
            self._pages[slot].append(pg)
            self.table[slot, len(self._pages[slot]) - 1] = pg
        return self._grow(slot, need - len(shared))

    def extend(self, slot: int, tokens: int) -> List[int]:
        """Grow the slot's mapping to cover ``tokens`` entries (no-op if
        already covered).  Raises :class:`PoolExhausted` on shortfall —
        never steals a live page."""
        self._maybe_fail("extend")
        need = self.pages_for(tokens)
        if need > self.n_logical:
            raise ValueError(
                f"slot {slot}: {tokens} tokens need {need} pages but the "
                f"block table has {self.n_logical} logical slots")
        have = len(self._pages[slot])
        if need <= have:
            return []
        return self._grow(slot, need - have)

    def cow(self, slot: int, logical: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write: detach the slot from the shared page at
        logical index ``logical`` before a divergent write.

        Returns ``(old_page, new_page)`` — the caller must device-copy
        old -> new before writing — or ``None`` when the page is already
        private (refcount 1: no copy needed, writes are safe).  The new
        page comes from the free list; refuses (:class:`PoolExhausted`)
        rather than dip below outstanding reservations."""
        pages = self._pages[slot]
        if not 0 <= logical < len(pages):
            raise ValueError(f"slot {slot} has no logical page {logical}")
        old = pages[logical]
        if self._refcnt[old] == 1:
            return None
        if self.headroom() < 1:
            raise PoolExhausted(
                f"page pool exhausted: cannot copy-on-write slot {slot} "
                f"logical page {logical} [{self.accounting()}]")
        new = self._take_free()
        pages[logical] = new
        self.table[slot, logical] = new
        self._refcnt[old] -= 1
        self.cow_copies += 1
        return old, new

    def pin(self, page: int) -> None:
        """Add a prefix-index reference to a live page (refcount + 1)."""
        page = int(page)
        if self._refcnt.get(page, 0) < 1:
            raise ValueError(f"cannot pin dead page {page}")
        self._refcnt[page] += 1
        self._pins[page] = self._pins.get(page, 0) + 1

    def unpin(self, page: int) -> bool:
        """Drop a prefix-index reference; frees the page at refcount 0.
        Returns True when the page went back to the free list."""
        page = int(page)
        if self._pins.get(page, 0) < 1:
            raise ValueError(f"page {page} is not pinned")
        self._pins[page] -= 1
        if not self._pins[page]:
            del self._pins[page]
        return self._release(page)

    def alloc_pinned(self) -> Optional[int]:
        """Allocate a fresh page held only by an index pin (swap-in
        target).  Returns ``None`` instead of raising when allocation
        would dip below outstanding reservations."""
        if self.headroom() < 1:
            return None
        pg = self._take_free()
        self._pins[pg] = 1
        return pg

    def _take_free(self) -> int:
        pg = self._free.pop()
        self._refcnt[pg] = 1
        self.high_water = max(self.high_water, self.used_pages)
        return pg

    def _release(self, page: int) -> bool:
        self._refcnt[page] -= 1
        if self._refcnt[page]:
            return False
        del self._refcnt[page]
        self._free.append(page)
        return True

    def _grow(self, slot: int, n: int) -> List[int]:
        # all-or-nothing: a partial grow would leave the slot holding
        # pages its caller does not know about
        if n > len(self._free):
            raise PoolExhausted(
                f"page pool exhausted growing slot {slot} by {n}: only "
                f"{len(self._free)} of {self.num_pages} pages free — "
                f"refusing to evict [{self.accounting()}]")
        got: List[int] = []
        for _ in range(n):
            pg = self._take_free()
            self._pages[slot].append(pg)
            self.table[slot, len(self._pages[slot]) - 1] = pg
            got.append(pg)
        return got

    def free(self, slot: int) -> int:
        """Drop the slot's reference on every page it maps; clears its
        block-table row (back to the sentinel) and reservation.  Pages
        still referenced elsewhere (another slot or an index pin) stay
        live.  Returns the number of pages actually returned to the
        free list."""
        pages = self._pages[slot]
        self._pages[slot] = []
        self._reserved[slot] = 0
        self.table[slot, :] = 0
        return sum(1 for pg in pages if self._release(pg))

    # ------------------------------------------------------- diagnostics
    def check_invariants(self) -> None:
        """Raise AssertionError on refcount / aliasing / sentinel / leak
        bugs."""
        refs: Dict[int, int] = dict(self._pins)
        for slot, pages in enumerate(self._pages):
            assert len(set(pages)) == len(pages), (
                f"slot {slot} maps a page twice")
            for pg in pages:
                refs[pg] = refs.get(pg, 0) + 1
        live = set(self._refcnt)
        assert 0 not in live, "sentinel page allocated"
        assert 0 not in self._free, "sentinel page on the free list"
        assert refs == self._refcnt, (
            f"refcount drift: counted {refs} != tracked {self._refcnt}")
        assert all(n >= 1 for n in self._refcnt.values()), (
            "live page with refcount < 1")
        assert not (live & set(self._free)), "live page on free list"
        assert len(live) + len(self._free) == self.num_pages, "page leak"
        for slot, pages in enumerate(self._pages):
            got = list(self.table[slot, :len(pages)])
            assert got == pages, f"slot {slot} table/page-list mismatch"
            assert not self.table[slot, len(pages):].any(), (
                f"slot {slot} table maps pages beyond its allocation")


# ---------------------------------------------------------------------------
# Device page helpers (COW copies, host swap)
# ---------------------------------------------------------------------------

def copy_page(cache: Dict[str, jax.Array], paged_keys: Tuple[str, ...],
              src: int, dst: int) -> Dict[str, jax.Array]:
    """Device-copy one physical page (all layers, all paged leaves)."""
    out = dict(cache)
    for key in paged_keys:
        out[key] = out[key].at[:, int(dst)].set(out[key][:, int(src)])
    return out


def extract_page(cache: Dict[str, jax.Array], paged_keys: Tuple[str, ...],
                 page: int) -> Dict[str, np.ndarray]:
    """Pull one physical page to host memory (swap-out payload)."""
    return {key: np.asarray(cache[key][:, int(page)]) for key in paged_keys}


def inject_page(cache: Dict[str, jax.Array], paged_keys: Tuple[str, ...],
                page: int, payload: Dict[str, np.ndarray]
                ) -> Dict[str, jax.Array]:
    """Write a host payload back into a physical page (swap-in)."""
    out = dict(cache)
    for key in paged_keys:
        out[key] = out[key].at[:, int(page)].set(
            jnp.asarray(payload[key], out[key].dtype))
    return out


def params_fingerprint(params: Pytree) -> bytes:
    """Cheap params digest for prefix-index keying.

    Shared pages hold MODEL OUTPUTS (k/v projections), so a prefix entry
    is only reusable under the exact params that produced it — the
    fingerprint (per-leaf shape/dtype plus a device-side abs-sum) is
    mixed into every chain digest, making entries from other checkpoints
    unreachable rather than subtly wrong."""
    h = hashlib.blake2b(digest_size=16)
    for leaf in jax.tree_util.tree_leaves(params):
        leaf = jnp.asarray(leaf)
        h.update(str(leaf.shape).encode())
        h.update(str(leaf.dtype).encode())
        h.update(np.asarray(jnp.sum(jnp.abs(leaf)), np.float64).tobytes())
    return h.digest()


class _PrefixEntry:
    """One full prompt page: device-resident (``page``) or host-swapped
    (``payload``)."""
    __slots__ = ("page", "payload")

    def __init__(self, page: Optional[int],
                 payload: Optional[Dict[str, np.ndarray]] = None):
        self.page = page
        self.payload = payload


class PrefixIndex:
    """Content-hash LRU index of full prompt-prefix pages.

    Each entry maps the blake2b chain digest of
    ``(params_fingerprint, prompt[: (j + 1) * page_size])`` to the
    physical page holding those ``page_size`` k/v entries.  Entries pin
    their page (refcount + 1), so a prefix stays warm after the slot
    that produced it frees — that is what makes repeat prompts hit.

    Under admission pressure :meth:`spill` walks entries coldest-first
    and swaps index-only pages (refcount == pin count) to host memory;
    :meth:`ensure_resident` swaps them back on the next hit.  Spilling
    never touches a page a live slot maps — those are not reclaimable.
    """

    def __init__(self, alloc: PageAllocator, paged_keys: Tuple[str, ...],
                 fingerprint: bytes):
        self._alloc = alloc
        self._keys = tuple(paged_keys)
        self._fp = bytes(fingerprint)
        self._entries: "OrderedDict[bytes, _PrefixEntry]" = OrderedDict()
        self.hits = 0            # admissions that mapped >= 1 shared page
        self.misses = 0          # admissions that found no usable prefix
        self.swap_ins = 0
        self.swap_outs = 0

    # ------------------------------------------------------------- state
    def __len__(self) -> int:
        return len(self._entries)

    @property
    def page_size(self) -> int:
        return self._alloc.page_size

    def resident_pages(self) -> int:
        """Entries currently holding a device page (== index pins)."""
        return sum(1 for e in self._entries.values() if e.page is not None)

    def swapped_pages(self) -> int:
        return sum(1 for e in self._entries.values() if e.page is None)

    def _digest(self, prompt: np.ndarray, j: int) -> bytes:
        h = hashlib.blake2b(self._fp, digest_size=16)
        h.update(prompt[:(j + 1) * self.page_size].tobytes())
        return h.digest()

    # --------------------------------------------------------- operations
    def lookup(self, prompt: np.ndarray) -> List[_PrefixEntry]:
        """Longest chain of indexed full pages covering the prompt.

        Returns the entries for pages ``0..k-1`` (possibly host-swapped —
        run :meth:`ensure_resident` before mapping them), touching each
        as most-recently-used.  The chain stops one page short of the
        full prompt's coverage ceiling only at the CALLER's discretion —
        this walks as far as the index reaches."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        chain: List[_PrefixEntry] = []
        j = 0
        while (j + 1) * self.page_size <= len(prompt):
            entry = self._entries.get(self._digest(prompt, j))
            if entry is None:
                break
            chain.append(entry)
            j += 1
        for key in [self._digest(prompt, i) for i in range(len(chain))]:
            self._entries.move_to_end(key)
        return chain

    def ensure_resident(self, cache: Dict[str, jax.Array],
                        chain: List[_PrefixEntry]
                        ) -> Tuple[Dict[str, jax.Array], List[int]]:
        """Swap host-swapped chain entries back onto device pages.

        Returns the (possibly updated) cache and the physical page ids
        of the resident prefix.  The chain truncates at the first entry
        that cannot be made resident (no allocatable page) — a shorter
        shared prefix, never a failure."""
        pages: List[int] = []
        for entry in chain:
            if entry.page is None:
                pg = self._alloc.alloc_pinned()
                if pg is None:
                    break
                cache = inject_page(cache, self._keys, pg, entry.payload)
                entry.page = pg
                entry.payload = None
                self.swap_ins += 1
            pages.append(entry.page)
        return cache, pages

    def insert(self, prompt: np.ndarray, n_tokens: int,
               pages: Tuple[int, ...]) -> int:
        """Index every FULL page of an admitted prompt (partial trailing
        pages are decode-written later and never shareable).  Pins each
        newly-indexed page; already-indexed prefixes are touched, not
        duplicated.  Returns the number of new entries."""
        prompt = np.ascontiguousarray(prompt, np.int32)
        added = 0
        j = 0
        while (j + 1) * self.page_size <= int(n_tokens) and j < len(pages):
            key = self._digest(prompt, j)
            entry = self._entries.get(key)
            if entry is None:
                self._alloc.pin(pages[j])
                self._entries[key] = _PrefixEntry(int(pages[j]))
                added += 1
            else:
                self._entries.move_to_end(key)
            j += 1
        return added

    def spill(self, cache: Dict[str, jax.Array], need: int,
              exclude: Optional[set] = None
              ) -> Tuple[Dict[str, jax.Array], int]:
        """Swap up to ``need`` cold index-only pages to host memory
        (LRU order).  Pages a live slot still maps are skipped — they
        are not reclaimable — as are pages in ``exclude`` (the chain an
        in-flight admission is about to map).  Returns the updated
        cache and the number of pages actually freed."""
        freed = 0
        exclude = exclude or set()
        for entry in list(self._entries.values()):
            if freed >= need:
                break
            pg = entry.page
            if pg is None or pg in exclude:
                continue
            # index-only: every reference is ours
            if self._alloc.refcount(pg) != self._alloc.pin_count(pg):
                continue
            entry.payload = extract_page(cache, self._keys, pg)
            entry.page = None
            self._alloc.unpin(pg)
            self.swap_outs += 1
            freed += 1
        return cache, freed

    def drop(self) -> int:
        """Unpin every resident entry and clear the index (full
        reclaim — lets pool-clean assertions see ``free_pages ==
        num_pages`` again).  Returns the number of pages released."""
        released = 0
        for entry in self._entries.values():
            if entry.page is not None:
                released += bool(self._alloc.unpin(entry.page))
        self._entries.clear()
        return released

    def reset_counters(self) -> None:
        self.hits = self.misses = 0
        self.swap_ins = self.swap_outs = 0


# ---------------------------------------------------------------------------
# Paged device-cache construction
# ---------------------------------------------------------------------------

def paged_cache_spec(model, capacity: int, cache_len: int,
                     dtype=jnp.float32) -> Tuple[Dict[str, Any], Tuple[str, ...]]:
    """Abstract contiguous cache structure + the keys that page.

    Ring caches (``kl``/``vl`` circular buffers) cannot page: their
    writes already overwrite live history in place and the slot formula
    assumes a windowed contiguous buffer — callers must refuse loudly.
    """
    spec = jax.eval_shape(lambda: model.init_cache(capacity, cache_len,
                                                   dtype=dtype))
    if "kl" in spec:
        raise ValueError(
            "ring-cache (local:global) archs keep windowed per-slot "
            "buffers; the paged block-table cache does not apply — use "
            'cache="contiguous"')
    return spec, tuple(k for k in PAGED_KEYS if k in spec)


def make_paged_cache(model, capacity: int, cache_len: int, *,
                     num_pages: int, page_size: int, dtype=jnp.float32
                     ) -> Tuple[Dict[str, jax.Array], Tuple[str, ...], int]:
    """Build the paged device cache for ``model``.

    Returns (cache, paged_keys, n_logical).  ``k``/``v`` leaves become
    pools ``(layers, num_pages + 1, page_size, heads, head_dim)`` (+1:
    sentinel page 0); every other leaf keeps its contiguous per-slot
    shape; a zeroed block table ``bt`` (capacity, n_logical) is added.
    Families without positional KV (pure SSM) return an unchanged
    contiguous cache and an empty ``paged_keys`` — paging is a no-op
    for constant-size state by design.
    """
    spec, paged_keys = paged_cache_spec(model, capacity, cache_len,
                                        dtype=dtype)
    if not paged_keys:
        return (model.init_cache(capacity, cache_len, dtype=dtype),
                paged_keys, 0)
    n_logical = pages_for(cache_len, page_size)
    cache: Dict[str, jax.Array] = {}
    for key, leaf in spec.items():
        if key in paged_keys:
            # (L, B, max_len, h, d) -> (L, pages, page_size, h, d)
            pool_shape = ((leaf.shape[0], num_pages + 1, page_size)
                          + leaf.shape[3:])
            cache[key] = jnp.zeros(pool_shape, leaf.dtype)
        else:
            cache[key] = jnp.zeros(leaf.shape, leaf.dtype)
    cache["bt"] = jnp.zeros((capacity, n_logical), jnp.int32)
    return cache, paged_keys, n_logical


# ---------------------------------------------------------------------------
# Contiguous <-> paged conversion (tests, cache migration)
# ---------------------------------------------------------------------------

def paginate_cache(cache: Dict[str, jax.Array], page_size: int,
                   num_pages: Optional[int] = None) -> Dict[str, jax.Array]:
    """Contiguous cache -> equivalent paged cache (sequential tables).

    Row ``r`` of a (L, B, max_len, h, d) leaf lands on physical pages
    ``r*n_logical + 1 .. (r+1)*n_logical`` in logical order, so
    ``logical_view(paginate_cache(c)) == c`` up to page-pad columns.
    Mainly a test/migration helper — the scheduler builds pools
    directly and scatters prompt pages at admission.
    """
    keys = tuple(k for k in PAGED_KEYS if k in cache)
    if not keys:
        return dict(cache)
    b, max_len = cache[keys[0]].shape[1], cache[keys[0]].shape[2]
    n_logical = pages_for(max_len, page_size)
    if num_pages is None:
        num_pages = b * n_logical
    if num_pages < b * n_logical:
        raise PoolExhausted(
            f"{b} rows of {n_logical} pages exceed num_pages={num_pages}")
    out = dict(cache)
    bt = 1 + (np.arange(b)[:, None] * n_logical
              + np.arange(n_logical)[None, :]).astype(np.int32)
    for key in keys:
        leaf = cache[key]
        pad = n_logical * page_size - max_len
        leafp = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad)) + ((0, 0),)
                        * (leaf.ndim - 3))
        pages = leafp.reshape(
            (leaf.shape[0], b * n_logical, page_size) + leaf.shape[3:])
        sentinel = jnp.zeros_like(pages[:, :1])
        pool = jnp.concatenate([sentinel, pages], axis=1)
        if num_pages > b * n_logical:
            extra = jnp.zeros(
                (pool.shape[0], num_pages - b * n_logical) + pool.shape[2:],
                pool.dtype)
            pool = jnp.concatenate([pool, extra], axis=1)
        out[key] = pool
    out["bt"] = jnp.asarray(bt)
    return out


def logical_view(cache: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Gather a paged cache back into contiguous per-slot layout
    (length ``n_logical * page_size``; entries past each row's write
    pointer are junk exactly as in contiguous mode)."""
    if "bt" not in cache:
        return dict(cache)
    bt = cache["bt"]
    out = {}
    for key, leaf in cache.items():
        if key == "bt":
            continue
        if key in PAGED_KEYS:
            g = jnp.take(leaf, bt, axis=1)   # (L, B, n_logical, P, h, d)
            out[key] = g.reshape((leaf.shape[0], bt.shape[0],
                                  bt.shape[1] * leaf.shape[2])
                                 + leaf.shape[3:])
        else:
            out[key] = leaf
    return out
