"""Paged (block-table) KV cache: pool layout, host allocator, helpers.

The continuous-batching scheduler originally gave every slot a
contiguous ``cache_len``-long cache row, so HBM capacity was set by the
LONGEST request any slot might see — a mixed-length request mix wastes
most of it.  Paged mode replaces the per-slot rows with one fixed pool
of ``page_size``-token blocks shared by all slots:

  * the ``k``/``v`` cache leaves become **pools**
    ``(layers, num_pages + 1, page_size, kv_heads, head_dim)``; page 0
    is a reserved sentinel (never allocated — unmapped block-table
    entries point at it, so frozen-slot junk writes land there and
    gathers of unmapped pages read garbage that the causal/``kv_len``
    mask excludes exactly);
  * each slot owns a **block table** row ``bt[slot, j] = physical page
    holding logical positions [j*P, (j+1)*P)``; decode writes scatter at
    ``(bt[pos // P], pos % P)`` and reads gather ``pool[bt]`` back into
    a position-ordered logical view, then run the UNCHANGED attention
    computation — same values, different addressing, which is why paged
    output is bit-identical to contiguous mode;
  * a host-side :class:`PageAllocator` hands pages out at admission
    (prompt pages) and at chunk boundaries (on-demand append for the
    next chunk's writes), and takes them back on finalize.  Exhaustion
    REFUSES (raises :class:`PoolExhausted`) — it never evicts or
    silently overwrites a live page.

Reservation accounting makes mid-flight exhaustion impossible by
construction: admission reserves each request's worst-case page count
(prompt bucket + generation budget + speculative margin) without
allocating it, and only admits while ``free - outstanding_reservations``
covers the newcomer.  Chunk-boundary extension never exceeds a slot's
reservation, so an admitted request can always finish.  Capacity still
beats contiguous slots because the reservation is the REQUEST's worst
case, not the global ``cache_len``.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

__all__ = ["PoolExhausted", "PageAllocator", "PAGED_KEYS", "pages_for",
           "paged_cache_spec", "make_paged_cache", "paginate_cache",
           "logical_view", "scatter_prompt_pages"]

# cache leaves that hold positional KV entries and therefore page;
# every other leaf (pos, conv/ssm state, encdec cross-KV, ring kl/vl)
# keeps its per-slot layout
PAGED_KEYS = ("k", "v")


class PoolExhausted(RuntimeError):
    """The page pool cannot satisfy an allocation.  Raised instead of
    evicting or silently overwriting a live page."""


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` cache entries."""
    return -(-int(tokens) // int(page_size))


def scatter_prompt_pages(pool: jnp.ndarray, sm: jnp.ndarray,
                         pages: jnp.ndarray, page_size: int) -> jnp.ndarray:
    """Land contiguously-prefilled KV rows into physical pool pages.

    ``sm`` is ``(L, kb, length, ...)`` — ``kb`` rows of a scratch prefill —
    and ``pages`` is ``(kb, npg)`` physical page ids.  The row tail is
    page-padded (pad entries stay causally masked: the write pointer and
    attention length both stop at the true position), split into
    ``npg`` pages of ``page_size``, and scattered into
    ``pool (L, num_pages+1, page_size, ...)``.  Shared by the scheduler's
    batch-k admission fns and the crash-recovery recompute resume path,
    so both land bitwise-identical page payloads.
    """
    kb, length = int(sm.shape[1]), int(sm.shape[2])
    npg = int(pages.shape[-1])
    pad = npg * int(page_size) - length
    if pad:
        sm = jnp.pad(sm, ((0, 0), (0, 0), (0, pad))
                     + ((0, 0),) * (sm.ndim - 3))
    sm = sm.reshape(sm.shape[:2] + (npg, int(page_size)) + sm.shape[3:])
    return pool.at[:, pages].set(sm.astype(pool.dtype))


class PageAllocator:
    """Host-side block-table allocator over ``num_pages`` usable pages.

    Physical page ids run 1..num_pages (page 0 is the sentinel and is
    never handed out).  ``table`` is the (capacity, n_logical) int32
    block table mirrored to the device before each chunk dispatch;
    unmapped entries are 0.

    Invariants (property-tested in tests/test_paged.py):
      * a live page belongs to exactly one slot;
      * the sentinel is never allocated;
      * after every slot frees, ``free_pages == num_pages`` (no leaks);
      * allocation beyond the pool raises :class:`PoolExhausted` —
        nothing is evicted.
    """

    def __init__(self, num_pages: int, page_size: int, capacity: int,
                 n_logical: int):
        if num_pages < 1:
            raise ValueError("num_pages must be >= 1")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.capacity = int(capacity)
        self.n_logical = int(n_logical)
        # LIFO free list keeps recently-freed (still-warm) pages hot
        self._free: List[int] = list(range(self.num_pages, 0, -1))
        self._pages: List[List[int]] = [[] for _ in range(self.capacity)]
        self._reserved: List[int] = [0] * self.capacity
        self.table = np.zeros((self.capacity, self.n_logical), np.int32)
        self._fail_next = 0              # armed injected faults (tests)

    # ------------------------------------------------------------- state
    @property
    def free_pages(self) -> int:
        return len(self._free)

    def slot_pages(self, slot: int) -> Tuple[int, ...]:
        return tuple(self._pages[slot])

    def outstanding(self) -> int:
        """Reserved-but-not-yet-allocated pages across live slots."""
        return sum(max(0, r - len(p))
                   for r, p in zip(self._reserved, self._pages))

    def pages_for(self, tokens: int) -> int:
        return pages_for(tokens, self.page_size)

    # ---------------------------------------------------- fault injection
    def inject_fault(self, n: int = 1) -> None:
        """Arm the allocator to raise :class:`PoolExhausted` on its next
        ``n`` admit/extend calls (even ones that would succeed).  Used by
        the scheduler's FaultPlan harness to prove admission is atomic
        and chunk-boundary extension is retryable."""
        self._fail_next += int(n)

    def _maybe_fail(self, op: str) -> None:
        if self._fail_next > 0:
            self._fail_next -= 1
            raise PoolExhausted(f"injected allocator fault during {op}")

    # -------------------------------------------------------- operations
    def can_admit(self, reserve_tokens: int) -> bool:
        """True when a request reserving ``reserve_tokens`` worst-case
        cache entries can be admitted WITHOUT ever exhausting the pool
        mid-flight (its future extends stay within the reservation)."""
        return (self.pages_for(reserve_tokens)
                <= len(self._free) - self.outstanding())

    def admit(self, slot: int, tokens_now: int,
              reserve_tokens: Optional[int] = None) -> List[int]:
        """Allocate pages covering ``tokens_now`` entries for an empty
        slot, reserving ``reserve_tokens`` (>= tokens_now) worst case."""
        if self._pages[slot]:
            raise ValueError(f"slot {slot} still holds pages — free first")
        self._maybe_fail("admit")
        need = self.pages_for(tokens_now)
        reserve = max(need, self.pages_for(reserve_tokens)
                      if reserve_tokens is not None else need)
        if reserve > len(self._free) - self.outstanding():
            raise PoolExhausted(
                f"page pool exhausted: slot {slot} needs {reserve} pages "
                f"(reservation) but only {len(self._free)} free minus "
                f"{self.outstanding()} outstanding reservations")
        self._reserved[slot] = reserve
        return self._grow(slot, need)

    def extend(self, slot: int, tokens: int) -> List[int]:
        """Grow the slot's mapping to cover ``tokens`` entries (no-op if
        already covered).  Raises :class:`PoolExhausted` on shortfall —
        never steals a live page."""
        self._maybe_fail("extend")
        need = self.pages_for(tokens)
        if need > self.n_logical:
            raise ValueError(
                f"slot {slot}: {tokens} tokens need {need} pages but the "
                f"block table has {self.n_logical} logical slots")
        have = len(self._pages[slot])
        if need <= have:
            return []
        return self._grow(slot, need - have)

    def _grow(self, slot: int, n: int) -> List[int]:
        # all-or-nothing: a partial grow would leave the slot holding
        # pages its caller does not know about
        if n > len(self._free):
            raise PoolExhausted(
                f"page pool exhausted growing slot {slot} by {n}: only "
                f"{len(self._free)} of {self.num_pages} pages free — "
                "refusing to evict")
        got: List[int] = []
        for _ in range(n):
            pg = self._free.pop()
            self._pages[slot].append(pg)
            self.table[slot, len(self._pages[slot]) - 1] = pg
            got.append(pg)
        return got

    def free(self, slot: int) -> int:
        """Return every page the slot holds to the pool; clears its
        block-table row (back to the sentinel) and reservation."""
        pages = self._pages[slot]
        n = len(pages)
        self._free.extend(pages)
        self._pages[slot] = []
        self._reserved[slot] = 0
        self.table[slot, :] = 0
        return n

    # ------------------------------------------------------- diagnostics
    def check_invariants(self) -> None:
        """Raise AssertionError on aliasing / sentinel / leak bugs."""
        live = [pg for pages in self._pages for pg in pages]
        assert 0 not in live, "sentinel page allocated"
        assert 0 not in self._free, "sentinel page on the free list"
        assert len(set(live)) == len(live), "page aliased to two slots"
        assert not (set(live) & set(self._free)), "live page on free list"
        assert len(live) + len(self._free) == self.num_pages, "page leak"
        for slot, pages in enumerate(self._pages):
            got = list(self.table[slot, :len(pages)])
            assert got == pages, f"slot {slot} table/page-list mismatch"
            assert not self.table[slot, len(pages):].any(), (
                f"slot {slot} table maps pages beyond its allocation")


# ---------------------------------------------------------------------------
# Paged device-cache construction
# ---------------------------------------------------------------------------

def paged_cache_spec(model, capacity: int, cache_len: int,
                     dtype=jnp.float32) -> Tuple[Dict[str, Any], Tuple[str, ...]]:
    """Abstract contiguous cache structure + the keys that page.

    Ring caches (``kl``/``vl`` circular buffers) cannot page: their
    writes already overwrite live history in place and the slot formula
    assumes a windowed contiguous buffer — callers must refuse loudly.
    """
    spec = jax.eval_shape(lambda: model.init_cache(capacity, cache_len,
                                                   dtype=dtype))
    if "kl" in spec:
        raise ValueError(
            "ring-cache (local:global) archs keep windowed per-slot "
            "buffers; the paged block-table cache does not apply — use "
            'cache="contiguous"')
    return spec, tuple(k for k in PAGED_KEYS if k in spec)


def make_paged_cache(model, capacity: int, cache_len: int, *,
                     num_pages: int, page_size: int, dtype=jnp.float32
                     ) -> Tuple[Dict[str, jax.Array], Tuple[str, ...], int]:
    """Build the paged device cache for ``model``.

    Returns (cache, paged_keys, n_logical).  ``k``/``v`` leaves become
    pools ``(layers, num_pages + 1, page_size, heads, head_dim)`` (+1:
    sentinel page 0); every other leaf keeps its contiguous per-slot
    shape; a zeroed block table ``bt`` (capacity, n_logical) is added.
    Families without positional KV (pure SSM) return an unchanged
    contiguous cache and an empty ``paged_keys`` — paging is a no-op
    for constant-size state by design.
    """
    spec, paged_keys = paged_cache_spec(model, capacity, cache_len,
                                        dtype=dtype)
    if not paged_keys:
        return (model.init_cache(capacity, cache_len, dtype=dtype),
                paged_keys, 0)
    n_logical = pages_for(cache_len, page_size)
    cache: Dict[str, jax.Array] = {}
    for key, leaf in spec.items():
        if key in paged_keys:
            # (L, B, max_len, h, d) -> (L, pages, page_size, h, d)
            pool_shape = ((leaf.shape[0], num_pages + 1, page_size)
                          + leaf.shape[3:])
            cache[key] = jnp.zeros(pool_shape, leaf.dtype)
        else:
            cache[key] = jnp.zeros(leaf.shape, leaf.dtype)
    cache["bt"] = jnp.zeros((capacity, n_logical), jnp.int32)
    return cache, paged_keys, n_logical


# ---------------------------------------------------------------------------
# Contiguous <-> paged conversion (tests, cache migration)
# ---------------------------------------------------------------------------

def paginate_cache(cache: Dict[str, jax.Array], page_size: int,
                   num_pages: Optional[int] = None) -> Dict[str, jax.Array]:
    """Contiguous cache -> equivalent paged cache (sequential tables).

    Row ``r`` of a (L, B, max_len, h, d) leaf lands on physical pages
    ``r*n_logical + 1 .. (r+1)*n_logical`` in logical order, so
    ``logical_view(paginate_cache(c)) == c`` up to page-pad columns.
    Mainly a test/migration helper — the scheduler builds pools
    directly and scatters prompt pages at admission.
    """
    keys = tuple(k for k in PAGED_KEYS if k in cache)
    if not keys:
        return dict(cache)
    b, max_len = cache[keys[0]].shape[1], cache[keys[0]].shape[2]
    n_logical = pages_for(max_len, page_size)
    if num_pages is None:
        num_pages = b * n_logical
    if num_pages < b * n_logical:
        raise PoolExhausted(
            f"{b} rows of {n_logical} pages exceed num_pages={num_pages}")
    out = dict(cache)
    bt = 1 + (np.arange(b)[:, None] * n_logical
              + np.arange(n_logical)[None, :]).astype(np.int32)
    for key in keys:
        leaf = cache[key]
        pad = n_logical * page_size - max_len
        leafp = jnp.pad(leaf, ((0, 0), (0, 0), (0, pad)) + ((0, 0),)
                        * (leaf.ndim - 3))
        pages = leafp.reshape(
            (leaf.shape[0], b * n_logical, page_size) + leaf.shape[3:])
        sentinel = jnp.zeros_like(pages[:, :1])
        pool = jnp.concatenate([sentinel, pages], axis=1)
        if num_pages > b * n_logical:
            extra = jnp.zeros(
                (pool.shape[0], num_pages - b * n_logical) + pool.shape[2:],
                pool.dtype)
            pool = jnp.concatenate([pool, extra], axis=1)
        out[key] = pool
    out["bt"] = jnp.asarray(bt)
    return out


def logical_view(cache: Dict[str, jax.Array]) -> Dict[str, jax.Array]:
    """Gather a paged cache back into contiguous per-slot layout
    (length ``n_logical * page_size``; entries past each row's write
    pointer are junk exactly as in contiguous mode)."""
    if "bt" not in cache:
        return dict(cache)
    bt = cache["bt"]
    out = {}
    for key, leaf in cache.items():
        if key == "bt":
            continue
        if key in PAGED_KEYS:
            g = jnp.take(leaf, bt, axis=1)   # (L, B, n_logical, P, h, d)
            out[key] = g.reshape((leaf.shape[0], bt.shape[0],
                                  bt.shape[1] * leaf.shape[2])
                                 + leaf.shape[3:])
        else:
            out[key] = leaf
    return out
