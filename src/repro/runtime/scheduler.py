"""Continuous-batching serving scheduler: slot-allocated KV cache with
mid-flight admission.

The generation engine (runtime/engine.py) fixed per-token dispatch
overhead, but it still runs one batch to completion: under staggered
arrivals every finished row idles until the slowest request drains —
the end-to-end overhead that makes low-rank serving look slower than it
is at the layer level.  This scheduler closes that gap:

  * a **slot allocator** over a fixed-capacity KV cache: each of the
    ``capacity`` cache rows is a slot with its own ``pos`` (cache write
    pointer), ``done`` flag, generation count and token budget, all
    living on device;
  * a **chunked scan** hot loop: one jitted dispatch scans ``chunk``
    decode steps over all slots (finished/free rows are frozen — their
    ``pos`` stops advancing and they emit fill tokens), so admission
    control costs O(1) dispatches per chunk instead of per token;
  * **mid-flight admission**: at each chunk boundary, freed slots are
    refilled from a host-side arrival queue.  An admitted request's
    prompt is right-padded to a static bucket length and prefilled
    batch-1 into a scratch cache, whose rows are then scattered into
    the assigned slot — in-flight rows are never touched.

Exactness: right padding keeps every real token at its true position
(rope + causal mask are position-exact, pad columns are masked to
exactly zero probability), and the per-row write pointer starts at the
*unpadded* prompt length so the first generated token overwrites the
first pad entry — junk beyond each row's write pointer is causally
masked until overwritten.  Greedy decoding is therefore bit-identical
to a single-request ``GenerationEngine.generate`` of the same prompt
(tests/test_scheduler.py asserts this token-for-token).

SSM families (mamba2/hybrid) integrate state over every input token,
and ring-cache (local:global) archs fold the trailing window of the
*padded* prompt into their circular buffers — both get exact-length
slot prefills (``prompt_buckets=None`` is forced); plain attention
families use buckets to bound prefill compiles.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any

__all__ = ["Request", "RequestResult", "SchedulerRun", "ServingScheduler"]


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request; ``arrival_time`` is seconds after run start
    (0 = already queued)."""

    request_id: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    arrival_time: float = 0.0


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: np.ndarray            # prompt + generated tokens
    generated: int                # real generated count (pre-eos)
    prompt_len: int
    slot: int
    arrival_time: float
    admitted_at: float            # seconds after run start
    finished_at: float

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival_time


@dataclasses.dataclass
class SchedulerRun:
    """One scheduler drain: per-request results + aggregate accounting."""

    results: List[RequestResult]
    elapsed: float                # wall-clock seconds for the drain
    generated: int                # total real generated tokens
    chunks: int                   # chunk dispatches
    occupancy: List[Tuple[float, int]]   # (t, active slots) per chunk

    @property
    def tokens_per_sec(self) -> float:
        return self.generated / max(self.elapsed, 1e-9)

    @property
    def mean_occupancy(self) -> float:
        if not self.occupancy:
            return 0.0
        return float(np.mean([o for _, o in self.occupancy]))

    def latencies(self) -> np.ndarray:
        return np.asarray(sorted(r.latency for r in self.results))


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    count: int = 0                # generated so far (device n_gen mirror)
    admitted_at: float = 0.0


class ServingScheduler:
    """Continuous-batching scheduler over any zoo model's cache surface.

    One scheduler per (model, params, capacity); jitted chunk/admit
    functions are cached, so steady-state serving pays one dispatch per
    ``chunk`` decode steps plus one per admission.
    """

    def __init__(self, model, params: Pytree, *, capacity: int = 8,
                 chunk: int = 8, cache_len: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 prompt_buckets: Optional[Sequence[int]] = (16, 32, 64, 128),
                 pad_id: Optional[int] = None, max_buckets: int = 4,
                 cache_dtype: Any = jnp.float32,
                 admission: str = "continuous"):
        if admission not in ("continuous", "drain"):
            raise ValueError("admission: 'continuous' or 'drain'")
        family = getattr(getattr(model, "cfg", None), "family", "dense")
        if family == "encdec":
            raise ValueError("scheduler serves token-prompt families; "
                             "enc-dec prefill needs frames")
        if family in ("ssm", "hybrid"):
            # SSM state integrates pad tokens: exact-length prefills only
            prompt_buckets = None
        cfg = getattr(model, "cfg", None)
        if (cfg is not None and getattr(cfg, "sliding_window", 0)
                and getattr(cfg, "local_global_ratio", 0)):
            # ring-capable archs: ring prefill folds the TRAILING window
            # into the circular buffer, so a right-padded prompt would
            # plant pad k/v at slots the decode position formula treats
            # as real past positions — exact-length prefills only
            prompt_buckets = None
        self.model = model
        self.capacity = int(capacity)
        self.chunk = int(chunk)
        self.eos_id = eos_id
        self.pad_id = int(pad_id if pad_id is not None
                          else (eos_id if eos_id is not None else 0))
        self.prompt_buckets = (tuple(sorted(prompt_buckets))
                               if prompt_buckets else None)
        # "continuous": refill freed slots at every chunk boundary.
        # "drain": run-to-completion batching — only admit when ALL
        # slots are free.  Same compute machinery either way, so the
        # serving benchmark's comparison isolates the admission policy.
        self.admission = admission
        self.cache_dtype = cache_dtype
        self._cache_len = cache_len
        # restack list-form (compressed) params onto the scan path; the
        # engine's identity-keyed cache logic is reused via a private
        # engine instance (also keeps restacks shared if callers use
        # both surfaces on one model)
        from repro.runtime.engine import GenerationEngine
        self._restacker = GenerationEngine(model, max_buckets=max_buckets,
                                           cache_dtype=cache_dtype)
        self.params = self._restacker.prepare_params(params)
        from repro.models.linear import _PIFA_KERNEL
        if _PIFA_KERNEL:
            # per-bucket decode kernels: bucket ranks are known now, the
            # decode batch is `capacity` — pin block sizes before any
            # trace reads the registry
            from repro.kernels.pifa_matmul.autotune import tune_pifa_params
            tune_pifa_params(self.params, self.capacity)

        # host-side state
        self._slots: List[_Slot] = [_Slot() for _ in range(self.capacity)]
        self._free: List[int] = list(range(self.capacity))[::-1]
        self._queue: Deque[Request] = collections.deque()
        self._chunk_fn = None
        self._admit_fns: Dict[int, Any] = {}
        self._slot_axes = None
        self._dev = None              # (cache, tok, done, n_gen, budget)

    # ------------------------------------------------------------- queue
    def submit(self, request: Request) -> None:
        self._queue.append(request)

    # ------------------------------------------------------- device state
    def _bucket_for(self, n: int) -> int:
        if self.prompt_buckets is None:
            return n
        for b in self.prompt_buckets:
            if n <= b:
                return b
        b = self.prompt_buckets[-1]
        while b < n:
            b *= 2
        return b

    def _required_cache_len(self) -> int:
        longest = max((self._bucket_for(len(r.prompt)) + r.max_new
                       for r in self._queue), default=32)
        return longest + 1

    def _slot_axis_tree(self, cache_len: int):
        """Per-leaf batch axis of the cache pytree, discovered by
        comparing abstract cache shapes at two batch sizes — works for
        every family (k/v at axis 1, ring kl/vl at axis 1, mamba
        conv/ssm at axis 1, pos at axis 0) with no per-family tables."""
        c1 = jax.eval_shape(lambda: self.model.init_cache(
            1, cache_len, dtype=self.cache_dtype))
        c2 = jax.eval_shape(lambda: self.model.init_cache(
            2, cache_len, dtype=self.cache_dtype))

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            raise ValueError(f"cache leaf {a.shape} has no batch axis")

        return jax.tree.map(axis, c1, c2)

    def _ensure_state(self) -> None:
        if self._dev is not None:
            return
        if self._cache_len is None:
            self._cache_len = self._required_cache_len()
        cache = self.model.init_cache(self.capacity, self._cache_len,
                                      dtype=self.cache_dtype)
        # ring caches change *structure* with max_len: scratch prefill
        # caches must then match the big cache's length exactly
        self._ring = isinstance(cache, dict) and "kl" in cache
        self._slot_axes = self._slot_axis_tree(self._cache_len)
        b = self.capacity
        self._dev = (cache,
                     jnp.zeros((b, 1), jnp.int32),        # next input token
                     jnp.ones((b,), jnp.bool_),           # done (free=done)
                     jnp.zeros((b,), jnp.int32),          # n_gen
                     jnp.zeros((b,), jnp.int32))          # budget

    # --------------------------------------------------------- jitted fns
    def _build_chunk_fn(self):
        model = self.model
        eos_id = self.eos_id
        fill = jnp.int32(eos_id if eos_id is not None else self.pad_id)
        chunk = self.chunk

        def run(params, cache, tok, done, n_gen, budget):
            def body(carry, _):
                tok, cache, done, n_gen = carry
                logits, cache2 = model.decode_step(params, tok, cache)
                nxt = jnp.argmax(logits[:, -1, :], axis=-1
                                 ).astype(jnp.int32)[:, None]
                nxt = jnp.where(done[:, None], fill, nxt)
                n_gen2 = jnp.where(done, n_gen, n_gen + 1)
                d2 = done
                if eos_id is not None:
                    d2 = d2 | (nxt[:, 0] == eos_id)
                d2 = d2 | (n_gen2 >= budget)
                # freeze finished/free rows: their write pointer stops
                # one past the last real entry, so junk writes land on a
                # sentinel index forever (never read, never out of
                # bounds) and the row state is untouched until re-admission
                cache2 = {**cache2,
                          "pos": jnp.where(done, cache["pos"], cache2["pos"])}
                return (nxt, cache2, d2, n_gen2), nxt[:, 0]

            (tok, cache, done, n_gen), toks = jax.lax.scan(
                body, (tok, cache, done, n_gen), None, length=chunk)
            return cache, tok, done, n_gen, toks.T   # toks (B, chunk)

        return jax.jit(run, donate_argnums=(1, 2, 3, 4))

    def _build_admit_fn(self, bucket: int):
        model = self.model
        eos_id = self.eos_id
        # scratch caches only need the prompt bucket's length: the
        # scatter below writes a sub-slab (dynamic_update_slice accepts
        # updates smaller than the target), and everything past each
        # row's write pointer is masked until overwritten.  Ring caches
        # are the exception — their *structure* depends on length.
        cache_len = self._cache_len if self._ring else bucket
        cache_dtype = self.cache_dtype
        axes = self._slot_axes

        def run(params, prompt, plen, max_new, slot,
                cache, tok, done, n_gen, budget):
            # batch-1 prefill into a scratch cache; the padded tail is
            # causally masked, logits read at the true last token
            small = model.init_cache(1, cache_len, dtype=cache_dtype)
            logits, small = model.prefill(
                params, prompt, small,
                last_idx=jnp.reshape(plen, (1,)) - 1)
            first = jnp.argmax(logits[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None]   # (1, 1)
            # write pointer starts at the UNPADDED length: generated
            # tokens overwrite the pad tail entry by entry, and junk
            # beyond the pointer stays causally masked (exactness note
            # in the module docstring)
            small = {**small,
                     "pos": jnp.reshape(plen, (1,)).astype(jnp.int32)}

            def scatter(big, sm, ax):
                starts = [jnp.int32(0)] * big.ndim
                starts[ax] = slot
                return jax.lax.dynamic_update_slice(
                    big, sm.astype(big.dtype), tuple(starts))

            cache = jax.tree.map(scatter, cache, small, axes)
            first_done = jnp.asarray(max_new <= 1)
            if eos_id is not None:
                first_done = first_done | (first[0, 0] == eos_id)
            tok = jax.lax.dynamic_update_slice_in_dim(tok, first, slot, 0)
            done = done.at[slot].set(first_done)
            n_gen = n_gen.at[slot].set(1)
            budget = budget.at[slot].set(max_new)
            return cache, tok, done, n_gen, budget, first[0, 0]

        return jax.jit(run, donate_argnums=(5, 6, 7, 8, 9))

    # ---------------------------------------------------------- admission
    def _admit(self, req: Request, now: float) -> None:
        plen = len(req.prompt)
        bucket = self._bucket_for(plen)
        if bucket + req.max_new + 1 > self._cache_len:
            # out-of-bounds cache writes would be silently dropped by
            # the scatter; refuse instead
            raise ValueError(
                f"request {req.request_id}: prompt bucket {bucket} + "
                f"max_new {req.max_new} exceeds cache_len "
                f"{self._cache_len}")
        slot = self._free.pop()
        padded = np.full((1, bucket), self.pad_id, np.int32)
        padded[0, :plen] = np.asarray(req.prompt, np.int32)
        fn = self._admit_fns.get(bucket)
        if fn is None:
            fn = self._admit_fns[bucket] = self._build_admit_fn(bucket)
        cache, tok, done, n_gen, budget = self._dev
        cache, tok, done, n_gen, budget, first = fn(
            self.params, jnp.asarray(padded), jnp.int32(plen),
            jnp.int32(req.max_new), jnp.int32(slot),
            cache, tok, done, n_gen, budget)
        self._dev = (cache, tok, done, n_gen, budget)
        st = self._slots[slot]
        st.request = req
        # keep the first token as a device scalar: int() here would
        # block the host on the prefill dispatch; finalize converts
        st.tokens = [first]
        st.count = 1
        st.admitted_at = now

    def _finalize(self, slot: int, now: float,
                  results: List[RequestResult]) -> None:
        st = self._slots[slot]
        req = st.request
        results.append(RequestResult(
            request_id=req.request_id,
            tokens=np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray([int(t) for t in st.tokens],
                                              np.int32)]),
            generated=st.count,
            prompt_len=len(req.prompt),
            slot=slot,
            arrival_time=req.arrival_time,
            admitted_at=st.admitted_at,
            finished_at=now,
        ))
        st.request = None
        st.tokens = []
        st.count = 0
        self._free.append(slot)

    # --------------------------------------------------------------- run
    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> SchedulerRun:
        """Drain ``requests`` (plus anything already submitted).

        Arrivals are honoured against the wall clock: a request with
        ``arrival_time=t`` becomes admissible ``t`` seconds after the
        drain starts.  Admission happens at chunk boundaries; the hot
        loop is one jitted chunk dispatch per ``chunk`` decode steps.
        """
        for r in requests or ():
            self.submit(r)
        self._queue = collections.deque(
            sorted(self._queue, key=lambda r: r.arrival_time))
        self._ensure_state()
        if self._chunk_fn is None:
            self._chunk_fn = self._build_chunk_fn()

        results: List[RequestResult] = []
        occupancy: List[Tuple[float, int]] = []
        chunks = 0
        t0 = time.perf_counter()

        def now() -> float:
            return time.perf_counter() - t0

        while self._queue or len(self._free) < self.capacity:
            # admission: continuous refills freed slots every chunk
            # boundary; drain is textbook static batching — it waits
            # for ALL slots to free, then for a full batch's worth of
            # arrivals (or the queue tail), and admits them at once
            if self.admission == "continuous":
                while (self._free and self._queue
                       and self._queue[0].arrival_time <= now()):
                    self._admit(self._queue.popleft(), now())
            elif len(self._free) == self.capacity and self._queue:
                need = min(self.capacity, len(self._queue))
                nth_arrival = list(self._queue)[need - 1].arrival_time
                if nth_arrival <= now():
                    for _ in range(need):
                        self._admit(self._queue.popleft(), now())
            active = self.capacity - len(self._free)
            if active == 0:
                # idle: sleep up to the next admissible arrival
                if self.admission == "continuous":
                    target = self._queue[0].arrival_time
                else:
                    need = min(self.capacity, len(self._queue))
                    target = list(self._queue)[need - 1].arrival_time
                wait = target - now()
                if wait > 0:
                    time.sleep(min(wait, 0.01))
                continue
            occupancy.append((now(), active))
            budget = self._dev[4]            # not donated: unchanged
            cache, tok, done, n_gen, toks = self._chunk_fn(
                self.params, *self._dev)
            self._dev = (cache, tok, done, n_gen, budget)
            chunks += 1
            done_h = np.asarray(done)
            ngen_h = np.asarray(n_gen)
            toks_h = np.asarray(toks)
            tnow = now()
            for slot in range(self.capacity):
                st = self._slots[slot]
                if st.request is None:
                    continue
                # a slot's real tokens are the first (n_gen - seen)
                # entries of its chunk row: once done it emits fill
                new = int(ngen_h[slot]) - st.count
                if new > 0:
                    st.tokens.extend(int(t) for t in toks_h[slot, :new])
                    st.count += new
                if done_h[slot]:
                    self._finalize(slot, tnow, results)

        elapsed = now()
        gen = sum(r.generated for r in results)
        return SchedulerRun(results=results, elapsed=elapsed, generated=gen,
                            chunks=chunks, occupancy=occupancy)
