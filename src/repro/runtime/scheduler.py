"""Continuous-batching serving scheduler: slot-allocated KV cache with
mid-flight admission, batched ramp-up, sampling, and speculative slots.

The generation engine (runtime/engine.py) fixed per-token dispatch
overhead, but it still runs one batch to completion: under staggered
arrivals every finished row idles until the slowest request drains —
the end-to-end overhead that makes low-rank serving look slower than it
is at the layer level.  This scheduler closes that gap:

  * a **slot allocator** over a fixed-capacity KV cache: each of the
    ``capacity`` cache rows is a slot with its own ``pos`` (cache write
    pointer), ``done`` flag, generation count and token budget, all
    living on device;
  * a **chunked scan** hot loop: one jitted dispatch scans ``chunk``
    decode steps over all slots (finished/free rows are frozen — their
    ``pos`` stops advancing and they emit fill tokens), so admission
    control costs O(1) dispatches per chunk instead of per token;
  * **batched mid-flight admission**: at each chunk boundary, freed
    slots are refilled from a host-side arrival queue.  Same-bucket
    admissions are grouped into ONE batch-k prefill dispatch
    (k ∈ ``ADMIT_BATCH``, capping the jit-cache key space over
    (bucket, k) pairs) instead of one batch-1 dispatch per request —
    bursty ramp-up pays one compile+dispatch per group;
  * **per-slot sampling**: temperature / top-k decode draws from a
    per-slot PRNG key derived as ``fold_in(scheduler key, request_id)``
    at admission and threaded through the chunk scan, so slot
    placement, chunk boundaries AND admission order never change a
    request's sample stream.  Configs a path cannot honor (top-k
    truncation on the greedy path) raise instead of silently decoding
    greedily;
  * **speculative slots** (``draft_params`` + ``spec_k``): each slot
    owns a draft cache alongside the target cache.  A chunk iteration
    becomes one draft+verify ROUND — the draft proposes ``spec_k``
    tokens via the scanned decode surface, the target scores all k+1
    positions in one multi-token cached dispatch
    (``model.verify_step``), and accepted runs advance ``pos`` by
    1..k+1 while rejected suffixes roll back both caches through the
    per-cache-type contract in ``models/layers.py`` — a ``pos`` reset
    for positional KV (junk stays causally masked), per-step state
    checkpoints for SSM recurrences, saved-slot restores for ring
    buffers.  Slots carry accept/reject counters; requests with
    ``speculative=False`` share the batch with acceptance forced to
    zero, which reduces exactly to plain decode (mixing costs draft
    compute for those rows, never correctness — their accept/drafted
    counters report n/a instead of polluting aggregate stats);
  * **sampled speculative slots**: temperature/top-k speculative
    decode does full per-row rejection sampling with residual fixup.
    Each request's stream derives from
    ``fold_in(scheduler key, request_id)`` exactly as a batch-1
    ``engine.generate_speculative`` call with that key
    (``spec_request_key``): admission draws the first token from the
    same split, and every round's draft/accept/correction draws flow
    through the shared per-row helpers in ``runtime/speculative.py``
    keyed by a per-slot round counter — so slot placement, chunk
    boundaries and batch composition never perturb a request's stream.

Exactness: right padding keeps every real token at its true position
(rope + causal mask are position-exact, pad columns are masked to
exactly zero probability), and the per-row write pointer starts at the
*unpadded* prompt length so the first generated token overwrites the
first pad entry — junk beyond each row's write pointer is causally
masked until overwritten.  Greedy decoding — plain AND speculative —
is therefore bit-identical to a single-request
``GenerationEngine.generate`` of the same prompt, for every family
(tests/test_scheduler.py, tests/test_speculative.py and
tests/test_conformance.py assert this token-for-token).

SSM families (mamba2/hybrid) integrate state over every input token,
and ring-cache (local:global) archs fold the trailing window of the
*padded* prompt into their circular buffers — both get exact-length
slot prefills (``prompt_buckets=None`` is forced); plain attention
families use buckets to bound prefill compiles.  Speculative slots
serve every family: SSM and ring caches verify through the per-step
checkpoint machinery (ring needs ``spec_k + 1 <= window`` so each
verify step overwrites a distinct slot — checked loudly).

**Paged KV cache** (``cache="paged"``): instead of one contiguous
``cache_len`` row per slot, the k/v leaves become a fixed pool of
``page_size``-token blocks shared by all slots, with a per-slot block
table (``runtime/paging.py``).  Admission prefills the prompt NATIVELY
through the block table — the models' paged scatter writes each prompt
token at ``(bt[pos // P], pos % P)`` in the same dispatch that computes
its k/v, so there is exactly one prefill path per cache mode (the old
contiguous scratch-prefill + page-scatter detour is gone) — chunk
boundaries append pages on demand for the next chunk's writes, and
every page-freeing exit (eos/cancel/deadline/preempt/crash) goes
through refcount decrement.  Reservation accounting admits a request
only when its WORST-CASE page count fits alongside live reservations,
so pool exhaustion refuses admission (``no_pages`` deferral, or
:class:`~repro.runtime.paging.PoolExhausted` when nothing in flight
can free pages — both carry the allocator's accounting snapshot) and
never silently overwrites a live page.

**Shared-prefix admission** (``prefix_cache=True``, paged only): a
content-hash index over full prompt pages (``PrefixIndex``) lets a
request whose prompt shares a page-aligned prefix with an earlier one
MAP those physical pages into its block table at refcount + 1 and
prefill only the uncached tail (always >= 1 token, so last-token
logits are computed fresh).  A hit whose prefix coverage is
page-aligned copies its last shared page copy-on-write before the tail
write can diverge; cold pages pinned only by the index spill to host
memory under admission pressure and swap back on the next hit
(LRU, ``swap_ins``/``swap_outs``).  Sharing requires purely positional
KV state — families with per-slot recurrent state (mamba2/hybrid
SSM) always miss, and the speculative draft pool never shares (its
k/v come from different params).  Bit-identity to the contiguous
engine is preserved throughout: shared pages hold the same values at
different addresses, and the per-request sample stream never depends
on whether its prefix hit.
Output is bit-identical to contiguous mode — the attention math runs
on a position-ordered gather of the slot's pages, same values at a
different addressing.  Constant-size-state families (mamba2) have
nothing to page and run unchanged; ring-cache archs keep their
windowed slots and refuse ``cache="paged"`` loudly.  Deferred
admissions report WHY (``no_slot`` vs ``no_pages``) in
``SchedulerRun.deferrals``; a request whose prompt bucket can never
fit raises a ``bucket mismatch`` error instead of retrying forever.

**Robustness layer** (priority preemption, deadlines, cancellation,
backpressure, fault injection):

  * ``Request`` carries a ``priority`` class and an optional
    ``deadline_s``; admission walks the queue in (priority desc,
    arrival, id) order, and a blocked request blocks everything at or
    below its own priority — strict FIFO within a priority class, so a
    large request can never be starved by a stream of smaller later
    arrivals, while higher-priority latecomers may still overtake;
  * with ``preemption="save_restore"`` (paged cache only), a
    higher-priority admission that finds no slot/pages **preempts**
    the lowest-priority victim at the chunk boundary: the victim's
    page payloads (only the pages its write pointer has touched),
    per-slot device rows (pos/SSM state), scalars (next token, PRNG
    key, counters, spec round counter) and emitted tokens are saved
    host-side, its slot and pages freed; re-admission restores them
    and the resumed stream is BIT-IDENTICAL to an unpreempted run
    (greedy and sampled, plain and speculative — the saved key/round
    counter continue the exact sample stream).  The contiguous cache
    cannot save block tables; it must opt into
    ``preemption="recompute"`` (save the emitted prefix, re-prefill
    on resume) or construction refuses loudly;
  * ``cancel(request_id)`` and per-request deadlines are honoured at
    chunk boundaries: the slot and its pages are freed immediately and
    the result reports a :class:`CancelReason` (``cancelled`` /
    ``deadline`` / ``preempted_unresumed``);
  * deferred admissions consult a :class:`RestartPolicy` exponential
    backoff (injectable clock) when ``admit_retries``/``backoff_base_s``
    are set: a request whose retry budget exhausts becomes an explicit
    :class:`Rejected` entry instead of spinning at every boundary, and
    a preempted request that can never re-admit surfaces as
    ``preempted_unresumed`` with its partial tokens;
  * a :class:`~repro.runtime.fault_tolerance.FaultPlan` injects
    allocator exhaustion, dispatch errors (raised BEFORE buffers are
    donated, so the retry path reproduces identical tokens), clock
    skew, cancels and forced preemptions at chosen boundaries;
    :class:`StragglerDetector` watches per-chunk dispatch wall-times
    and flags persistent outliers in ``SchedulerRun.slow_chunks``.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault_tolerance import (FaultPlan, InjectedFault,
                                           RestartPolicy, SchedulerCrash,
                                           StragglerDetector)
from repro.runtime.paging import (PageAllocator, PoolExhausted, PrefixIndex,
                                  copy_page, make_paged_cache, pages_for,
                                  params_fingerprint)

Pytree = Any

__all__ = ["Request", "RequestResult", "SchedulerRun", "ServingScheduler",
           "ADMIT_BATCH", "PoolExhausted", "CancelReason", "Rejected",
           "FaultPlan", "InjectedFault", "SchedulerCrash"]

# Grouped-admission batch sizes, largest first.  Also the cap on the
# jit-cache key space: one compiled admit fn per (prompt bucket, k).
ADMIT_BATCH = (4, 2, 1)


@dataclasses.dataclass(frozen=True)
class Request:
    """One serving request; ``arrival_time`` is seconds after run start
    (0 = already queued).  ``speculative`` opts a request out of
    draft/verify on a speculative scheduler (ignored otherwise).
    ``priority`` is an int class (higher = more important — may preempt
    lower classes when the scheduler enables preemption); ``deadline_s``
    is seconds after ``arrival_time`` by which the request must finish,
    checked at chunk boundaries (expiry cancels with reason
    ``deadline``)."""

    request_id: int
    prompt: np.ndarray            # (len,) int32
    max_new: int
    arrival_time: float = 0.0
    speculative: bool = True
    priority: int = 0
    deadline_s: Optional[float] = None


def _request_meta(r: Request) -> Dict[str, Any]:
    """JSON-serializable view of a Request — the wire format shared by
    journal submit records and snapshot slot/queue entries (see
    ``runtime/durability.py``, which reconstructs Requests from it)."""
    return {"rid": int(r.request_id),
            "prompt": [int(t) for t in np.asarray(r.prompt)],
            "max_new": int(r.max_new),
            "arrival_time": float(r.arrival_time),
            "speculative": bool(r.speculative),
            "priority": int(r.priority),
            "deadline_s": (None if r.deadline_s is None
                           else float(r.deadline_s))}


class CancelReason(enum.Enum):
    """Why a request finished without draining its budget."""

    CANCELLED = "cancelled"                # explicit cancel(request_id)
    DEADLINE = "deadline"                  # arrival_time + deadline_s passed
    PREEMPTED_UNRESUMED = "preempted_unresumed"  # evicted, re-admission
    #                                        retry budget exhausted


@dataclasses.dataclass
class Rejected:
    """A request dropped at admission after its backoff retry budget
    exhausted (never ran — contrast ``preempted_unresumed``, which ran
    and carries partial tokens in a RequestResult)."""

    request_id: int
    reason: str                   # last deferral cause: no_slot/no_pages
    attempts: int                 # admission attempts before giving up
    rejected_at: float            # seconds after run start


@dataclasses.dataclass
class RequestResult:
    request_id: int
    tokens: np.ndarray            # prompt + generated tokens
    generated: int                # real generated count (pre-eos)
    prompt_len: int
    slot: int
    arrival_time: float
    admitted_at: float            # seconds after run start
    finished_at: float
    # accept/draft accounting only exists for requests that actually
    # ran draft/verify: plain slots (speculative=False, or any slot of
    # a non-speculative scheduler) report None ("n/a") so they never
    # pollute aggregate acceptance stats.
    accepted: Optional[int] = None   # draft tokens the target accepted
    drafted: Optional[int] = None    # draft tokens proposed for this slot
    cancel_reason: Optional[CancelReason] = None  # None = ran to eos/budget
    preemptions: int = 0             # times this request was evicted

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival_time


@dataclasses.dataclass
class SchedulerRun:
    """One scheduler drain: per-request results + aggregate accounting."""

    results: List[RequestResult]
    elapsed: float                # wall-clock seconds for the drain
    generated: int                # total real generated tokens
    chunks: int                   # chunk dispatches
    occupancy: List[Tuple[float, int]]   # (t, active slots) per chunk
    accepted: int = 0             # draft tokens accepted (spec slots only)
    drafted: int = 0              # draft tokens proposed (spec slots only)
    # WHY arrived requests were not admitted at a chunk boundary,
    # counted per (boundary, blocked request): "no_slot" (all slots
    # busy) or "no_pages" (paged pool cannot cover the request's
    # worst-case reservation).  A request that can NEVER fit raises a
    # "bucket mismatch" ValueError instead of deferring forever.
    deferrals: Dict[str, int] = dataclasses.field(default_factory=dict)
    # requests dropped after their admission retry budget exhausted
    # (backpressure: results + rejected partition the submitted set)
    rejected: List[Rejected] = dataclasses.field(default_factory=list)
    preemptions: int = 0          # slot evictions (priority or forced)
    resumes: int = 0              # preempted requests re-admitted
    # chunk indices whose dispatch wall-time the StragglerDetector
    # flagged as persistent outliers vs the run median
    slow_chunks: List[int] = dataclasses.field(default_factory=list)
    # paged-pool observability (all 0 for contiguous runs): peak pages
    # in use this run, prefix-cache admission hits/misses, pages
    # detached by copy-on-write, and host-swap traffic
    page_high_water: int = 0
    prefix_hits: int = 0
    prefix_misses: int = 0
    cow_copies: int = 0
    swap_ins: int = 0
    swap_outs: int = 0

    @property
    def tokens_per_sec(self) -> float:
        return self.generated / max(self.elapsed, 1e-9)

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.drafted, 1)

    @property
    def mean_occupancy(self) -> float:
        if not self.occupancy:
            return 0.0
        return float(np.mean([o for _, o in self.occupancy]))

    def latencies(self) -> np.ndarray:
        return np.asarray(sorted(r.latency for r in self.results))


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    tokens: List[int] = dataclasses.field(default_factory=list)
    count: int = 0                # generated so far (device n_gen mirror)
    admitted_at: float = 0.0
    seq: int = -1                 # admission order (victim tie-break)
    preempts: int = 0             # evictions this request has survived
    journaled: int = 0            # tokens already written to the WAL


@dataclasses.dataclass
class _SavedSlot:
    """Host-side snapshot of a preempted slot (see ``_evict``).

    ``save_restore`` keeps the full device row: every non-paged cache
    leaf's slot row plus the page payloads the write pointer has
    touched.  ``recompute`` keeps only the scalars — resume re-prefills
    the prompt + emitted prefix."""

    tokens: List[int]             # emitted tokens so far (host ints)
    count: int                    # == device n_gen at eviction
    pos: int                      # device write pointer (plen + count - 1)
    tok: np.ndarray               # (1,) next input token
    keys: np.ndarray              # (2,) per-slot PRNG key (sample stream)
    admitted_at: float            # first admission (latency accounting)
    n_preempts: int
    # speculative scalars (None on plain schedulers)
    spec: Optional[bool] = None
    acc: Optional[int] = None
    drafted: Optional[int] = None
    rounds: Optional[int] = None
    # save_restore payloads (None in recompute mode)
    rows: Optional[Dict[str, np.ndarray]] = None    # target non-paged rows
    drows: Optional[Dict[str, np.ndarray]] = None   # draft non-paged rows
    pages: Optional[Dict[str, np.ndarray]] = None   # target page payloads
    dpages: Optional[Dict[str, np.ndarray]] = None  # draft page payloads
    # restore depth for THIS saved slot — snapshots always capture at
    # save_restore depth (nothing is freed, so payloads exist even on a
    # contiguous cache), while a CRC-corrupt snapshot payload degrades
    # just that slot to recompute; the scheduler-wide ``preemption``
    # setting only governs live evictions
    mode: str = "save_restore"


class ServingScheduler:
    """Continuous-batching scheduler over any zoo model's cache surface.

    One scheduler per (model, params, capacity); jitted chunk/admit
    functions are cached, so steady-state serving pays one dispatch per
    ``chunk`` decode steps (or draft/verify rounds) plus one per
    admission group.
    """

    def __init__(self, model, params: Pytree, *, capacity: int = 8,
                 chunk: int = 8, cache_len: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 prompt_buckets: Optional[Sequence[int]] = (16, 32, 64, 128),
                 pad_id: Optional[int] = None, max_buckets: int = 4,
                 cache_dtype: Any = jnp.float32,
                 admission: str = "continuous",
                 temperature: float = 0.0, top_k: int = 0,
                 sample_seed: int = 0,
                 draft_params: Optional[Pytree] = None, spec_k: int = 4,
                 cache: str = "contiguous", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_cache: bool = False,
                 prefill_chunk: Optional[int] = None,
                 preemption: str = "off",
                 admit_retries: Optional[int] = None,
                 backoff_base_s: float = 0.0, backoff_max_s: float = 1.0,
                 dispatch_retries: int = 3,
                 clock: Optional[Callable[[], float]] = None,
                 sleep_fn: Optional[Callable[[float], None]] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 straggler_threshold: float = 4.0,
                 durability: Optional[Any] = None):
        if admission not in ("continuous", "drain"):
            raise ValueError("admission: 'continuous' or 'drain'")
        if cache not in ("contiguous", "paged"):
            raise ValueError("cache: 'contiguous' or 'paged'")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        if preemption not in ("off", "save_restore", "recompute"):
            raise ValueError(
                "preemption: 'off', 'save_restore' or 'recompute'")
        if preemption == "save_restore" and cache != "paged":
            raise ValueError(
                'preemption="save_restore" needs cache="paged": the '
                "contiguous cache has no block tables to save, so an "
                "evicted slot's KV cannot be parked host-side page by "
                'page — use preemption="recompute" (save the emitted '
                "prefix and re-prefill on resume, costing recompute "
                'instead of HBM) or switch to cache="paged"')
        if preemption == "recompute" and cache != "contiguous":
            raise ValueError(
                'preemption="recompute" is the contiguous-cache '
                'fallback; the paged cache preempts via '
                'preemption="save_restore" (block-table save/restore, '
                "zero recompute)")
        family = getattr(getattr(model, "cfg", None), "family", "dense")
        if family == "encdec":
            raise ValueError("scheduler serves token-prompt families; "
                             "enc-dec prefill needs frames")
        if family in ("ssm", "hybrid"):
            # SSM state integrates pad tokens: exact-length prefills only
            prompt_buckets = None
        if prefix_cache and cache != "paged":
            raise ValueError(
                'prefix_cache=True needs cache="paged": the contiguous '
                "cache has no shared physical pages for two slots to map")
        if prefill_chunk is not None:
            if int(prefill_chunk) < 1:
                raise ValueError("prefill_chunk must be >= 1")
            if cache != "paged":
                raise ValueError(
                    'prefill_chunk applies to cache="paged" prompt '
                    "prefill; the contiguous path prefills one slab")
            if family in ("ssm", "hybrid"):
                raise ValueError(
                    "prefill_chunk is attention-only: conv/SSM prompt "
                    "state does not thread across prefill chunk "
                    "boundaries — these families prefill in one call")
        cfg = getattr(model, "cfg", None)
        ring_capable = bool(
            cfg is not None and getattr(cfg, "sliding_window", 0)
            and getattr(cfg, "local_global_ratio", 0))
        if ring_capable:
            # ring-capable archs: ring prefill folds the TRAILING window
            # into the circular buffer, so a right-padded prompt would
            # plant pad k/v at slots the decode position formula treats
            # as real past positions — exact-length prefills only
            prompt_buckets = None
            if cache == "paged":
                raise ValueError(
                    "ring-cache (local:global) archs keep windowed "
                    'per-slot buffers and refuse cache="paged": their '
                    "circular writes already overwrite history in "
                    "place, so a block table has nothing to save — "
                    "use the contiguous cache")
        # ---- sampling config: honor it or refuse, never silently greedy
        if top_k and temperature == 0.0:
            raise ValueError(
                "top_k truncation is a sampling transform; it reaches the "
                "greedy chunk path (temperature=0) which cannot honor it — "
                "set temperature>0 or drop top_k")
        self.speculative = draft_params is not None
        if self.speculative and spec_k < 1:
            raise ValueError("spec_k must be >= 1 with draft_params")
        self.model = model
        self.capacity = int(capacity)
        self.chunk = int(chunk)
        self.eos_id = eos_id
        self.pad_id = int(pad_id if pad_id is not None
                          else (eos_id if eos_id is not None else 0))
        self.prompt_buckets = (tuple(sorted(prompt_buckets))
                               if prompt_buckets else None)
        # "continuous": refill freed slots at every chunk boundary.
        # "drain": run-to-completion batching — only admit when ALL
        # slots are free.  Same compute machinery either way, so the
        # serving benchmark's comparison isolates the admission policy.
        self.admission = admission
        self.cache_mode = cache
        self.page_size = int(page_size)
        self.num_pages = num_pages          # resolved at _ensure_state
        self.prefix_cache = bool(prefix_cache)
        self.prefill_chunk = (int(prefill_chunk)
                              if prefill_chunk is not None else None)
        self.preemption = preemption
        # backpressure: admission backoff is OFF by default (a deferred
        # request retries at every boundary forever, today's behavior);
        # setting admit_retries and/or backoff_base_s bounds it
        self._admit_retries = admit_retries
        self._backoff_base = float(backoff_base_s)
        self._backoff_max = float(backoff_max_s)
        self._dispatch_retries = int(dispatch_retries)
        self._clock = clock if clock is not None else time.perf_counter
        self._sleep = sleep_fn if sleep_fn is not None else time.sleep
        self._fault_plan = fault_plan
        self._straggler_threshold = float(straggler_threshold)
        # durability (runtime/durability.py — duck-typed so this module
        # never imports it): every queue event is journaled, and every
        # snapshot_every chunk dispatches the active slots are captured
        # at save_restore depth into the snapshot store
        self._durability = durability
        self._journal = getattr(durability, "journal", None)
        self._snap_store = getattr(durability, "store", None)
        self._snap_every = int(getattr(durability, "snapshot_every", 0)
                               or 0)
        self._journal_cfg = False      # config record written yet?
        self.cache_dtype = cache_dtype
        self.temperature = float(temperature)
        self.top_k = int(top_k)
        self.spec_k = int(spec_k)
        self._cache_len = cache_len
        self._sample_key = jax.random.PRNGKey(sample_seed)
        # restack list-form (compressed) params onto the scan path; the
        # engine's identity-keyed cache logic is reused via a private
        # engine instance (also keeps restacks shared if callers use
        # both surfaces on one model)
        from repro.runtime.engine import GenerationEngine
        self._restacker = GenerationEngine(model, max_buckets=max_buckets,
                                           cache_dtype=cache_dtype)
        self.params = self._restacker.prepare_params(params)
        self.draft_params = None
        if self.speculative:
            # the draft is restacked independently — its MPIFA rank
            # buckets may differ from the target's
            self.draft_params = self._restacker.prepare_params(draft_params)
        from repro.models.linear import _PIFA_KERNEL
        if _PIFA_KERNEL:
            # per-bucket decode kernels: bucket ranks are known now, the
            # decode batch is `capacity` — pin block sizes before any
            # trace reads the registry
            from repro.kernels.pifa_matmul.autotune import tune_pifa_params
            tune_pifa_params(self.params, self.capacity)
            if self.speculative:
                tune_pifa_params(self.draft_params, self.capacity)

        # host-side state
        self._slots: List[_Slot] = [_Slot() for _ in range(self.capacity)]
        self._free: List[int] = list(range(self.capacity))[::-1]
        self._queue: Deque[Request] = collections.deque()
        self._chunk_fn = None
        self._admit_fns: Dict[Tuple[int, int], Any] = {}
        self._slot_axes = None
        self._dev: Optional[Dict[str, Any]] = None
        # paged-mode state (populated by _ensure_state when the family
        # has positional KV leaves to page)
        self._paged_kv = False
        self._paged_keys: Tuple[str, ...] = ()
        self._n_logical = 0
        self._alloc: Optional[PageAllocator] = None
        self._dalloc: Optional[PageAllocator] = None
        # prefix sharing (populated by _ensure_state when enabled and
        # the family's cache is purely positional KV)
        self._prefix: Optional[PrefixIndex] = None
        # robustness state
        self._resume_fns: Dict[int, Any] = {}      # recompute re-prefills
        self._preempted: Dict[int, _SavedSlot] = {}
        self._cancelled: set = set()
        self._backoff: Dict[int, RestartPolicy] = {}
        self._retry_at: Dict[int, float] = {}
        self._seq = 0
        self._n_preempt = 0
        self._n_resume = 0
        self._last_block: Optional[str] = None

    # ------------------------------------------------------------- queue
    @staticmethod
    def _qkey(r: Request) -> Tuple[int, float, int]:
        """Admission order: priority class desc, then strict FIFO within
        the class (arrival, then id) — the starvation fix: a blocked
        request sets a ceiling no same-or-lower-priority later arrival
        can pass."""
        return (-r.priority, r.arrival_time, r.request_id)

    def submit(self, request: Request) -> None:
        if self._journal is not None:
            self._journal.append("submit", **_request_meta(request))
        self._queue.append(request)

    def cancel(self, request_id: int) -> None:
        """Cancel a request mid-flight: honoured at the next chunk
        boundary (a dispatch in progress cannot be interrupted), where
        the slot and its pages are freed immediately and the result
        carries ``CancelReason.CANCELLED`` with tokens emitted so far.
        Queued (or preempted-and-parked) requests are simply dropped
        with the same reason.  Unknown ids are ignored."""
        if self._journal is not None:
            self._journal.append("cancel", rid=int(request_id))
        self._cancelled.add(int(request_id))

    def spec_request_key(self, request_id: int) -> jax.Array:
        """The engine-equivalent PRNG key of a sampled speculative
        request: ``engine.generate_speculative(prompt[None], max_new,
        key=this, ...)`` with the scheduler's temperature/top_k/spec_k
        reproduces the slot's token stream exactly.  Keys are
        ``fold_in(scheduler key, request_id)`` — placement- and
        admission-order-invariant by construction."""
        return jax.random.fold_in(self._sample_key, request_id)

    def _durability_config(self) -> Dict[str, Any]:
        """The config fingerprint journaled once per run and stamped on
        snapshots: everything a resumed stream's bit-identity depends
        on.  Recovery refuses a scheduler whose fingerprint disagrees
        (see ``durability.recover_into``) — continuing with, say, a
        different temperature or spec_k would silently diverge."""
        return {
            "capacity": self.capacity, "chunk": self.chunk,
            "cache_len": (None if self._cache_len is None
                          else int(self._cache_len)),
            "cache": self.cache_mode, "page_size": self.page_size,
            "num_pages": (None if self.num_pages is None
                          else int(self.num_pages)),
            "prefix_cache": self.prefix_cache,
            "prefill_chunk": self.prefill_chunk,
            "temperature": self.temperature, "top_k": self.top_k,
            "speculative": self.speculative, "spec_k": self.spec_k,
            "eos_id": self.eos_id, "pad_id": self.pad_id,
            "admission": self.admission, "preemption": self.preemption,
            "prompt_buckets": (None if self.prompt_buckets is None
                               else list(self.prompt_buckets)),
            "sample_key": [int(k) for k in np.asarray(self._sample_key)],
        }

    # ------------------------------------------------------- device state
    def _bucket_for(self, n: int) -> int:
        if self.prompt_buckets is None:
            return n
        for b in self.prompt_buckets:
            if n <= b:
                return b
        b = self.prompt_buckets[-1]
        while b < n:
            b *= 2
        return b

    def _spec_margin(self) -> int:
        # speculation writes up to spec_k cache entries beyond the
        # final accepted position before rolling back
        return self.spec_k if self.speculative else 0

    def _required_cache_len(self) -> int:
        longest = max((self._bucket_for(len(r.prompt)) + r.max_new
                       for r in self._queue), default=32)
        return longest + self._spec_margin() + 1

    def _slot_axis_tree(self, cache_len: int):
        """Per-leaf batch axis of the cache pytree, discovered by
        comparing abstract cache shapes at two batch sizes — works for
        every family (k/v at axis 1, ring kl/vl at axis 1, mamba
        conv/ssm at axis 1, pos at axis 0) with no per-family tables."""
        c1 = jax.eval_shape(lambda: self.model.init_cache(
            1, cache_len, dtype=self.cache_dtype))
        c2 = jax.eval_shape(lambda: self.model.init_cache(
            2, cache_len, dtype=self.cache_dtype))

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            raise ValueError(f"cache leaf {a.shape} has no batch axis")

        return jax.tree.map(axis, c1, c2)

    def _ensure_state(self) -> None:
        if self._dev is not None:
            return
        if self._cache_len is None:
            self._cache_len = self._required_cache_len()
        if self.cache_mode == "paged":
            # round up to a whole number of pages: the paged logical
            # view is then exactly cache_len long, so attention reduces
            # over the same shapes as a contiguous cache of the same
            # length — bit-identity, not just fp-closeness
            self._cache_len = (pages_for(self._cache_len, self.page_size)
                               * self.page_size)
            n_logical = pages_for(self._cache_len, self.page_size)
            if self.num_pages is None:
                # default pool: same token count as the contiguous
                # cache would hold (capacity full-length rows)
                self.num_pages = self.capacity * n_logical
            cache, paged_keys, n_logical = make_paged_cache(
                self.model, self.capacity, self._cache_len,
                num_pages=int(self.num_pages), page_size=self.page_size,
                dtype=self.cache_dtype)
            self._paged_keys = paged_keys
            self._paged_kv = bool(paged_keys)
            if self._paged_kv:
                self._n_logical = n_logical
                self._alloc = PageAllocator(int(self.num_pages),
                                            self.page_size, self.capacity,
                                            n_logical)
                if self.speculative:
                    # the draft cache pages through its own pool/table
                    self._dalloc = PageAllocator(int(self.num_pages),
                                                 self.page_size,
                                                 self.capacity, n_logical)
                if (self.prefix_cache
                        and set(cache) - {"pos", "bt"} == set(paged_keys)):
                    # sharing needs a PURELY positional cache: a page of
                    # k/v at positions [jP, (j+1)P) depends only on the
                    # token prefix, so equal prefixes yield bit-equal
                    # pages.  Hybrid/SSM conv+ssm state integrates the
                    # whole prompt — their admissions always miss (the
                    # index stays None; paged decode is unaffected).
                    self._prefix = PrefixIndex(
                        self._alloc, paged_keys,
                        params_fingerprint(self.params))
        else:
            cache = self.model.init_cache(self.capacity, self._cache_len,
                                          dtype=self.cache_dtype)
        # ring caches change *structure* with max_len: scratch prefill
        # caches must then match the big cache's length exactly
        self._ring = isinstance(cache, dict) and "kl" in cache
        if self.speculative and self._ring:
            w = self.model.cfg.sliding_window
            if self.spec_k + 1 > w:
                raise ValueError(
                    f"ring verify rollback needs spec_k + 1 <= window: "
                    f"spec_k {self.spec_k} vs window {w} — each verify "
                    "step must overwrite a distinct ring slot")
        self._slot_axes = self._slot_axis_tree(self._cache_len)
        b = self.capacity
        dev = {
            "cache": cache,
            "tok": jnp.zeros((b, 1), jnp.int32),      # next input token
            "done": jnp.ones((b,), jnp.bool_),        # done (free=done)
            "n_gen": jnp.zeros((b,), jnp.int32),
            "budget": jnp.zeros((b,), jnp.int32),
            "keys": jnp.zeros((b, 2), jnp.uint32),    # per-slot PRNG
        }
        if self.speculative:
            if self._paged_kv:
                dev["dcache"], _, _ = make_paged_cache(
                    self.model, self.capacity, self._cache_len,
                    num_pages=int(self.num_pages),
                    page_size=self.page_size, dtype=self.cache_dtype)
            else:
                dev["dcache"] = self.model.init_cache(
                    self.capacity, self._cache_len, dtype=self.cache_dtype)
            dev["spec"] = jnp.zeros((b,), jnp.bool_)  # slot runs draft?
            dev["acc"] = jnp.zeros((b,), jnp.int32)   # accepted drafts
            dev["drafted"] = jnp.zeros((b,), jnp.int32)
            dev["rounds"] = jnp.zeros((b,), jnp.int32)  # per-slot rounds
        self._dev = dev

    # --------------------------------------------------------- jitted fns
    def _sample_tok(self, lg: jax.Array, step_keys: jax.Array) -> jax.Array:
        """lg (b, V) -> (b, 1) int32 via per-row keys (b, 2)."""
        if self.top_k > 0:
            kth = jax.lax.top_k(lg, self.top_k)[0][..., -1:]
            lg = jnp.where(lg < kth, -jnp.inf, lg)
        nxt = jax.vmap(jax.random.categorical)(step_keys,
                                               lg / self.temperature)
        return nxt.astype(jnp.int32)[:, None]

    def _build_chunk_fn(self):
        model = self.model
        eos_id = self.eos_id
        fill = jnp.int32(eos_id if eos_id is not None else self.pad_id)
        chunk = self.chunk
        temperature = self.temperature

        def run(params, cache, tok, done, n_gen, budget, keys):
            def body(carry, _):
                tok, cache, done, n_gen, keys = carry
                logits, cache2 = model.decode_step(params, tok, cache)
                lg = logits[:, -1, :]
                if temperature > 0.0:
                    # per-slot sample stream: split each row's key, use
                    # one half now, carry the other — slot placement and
                    # chunk boundaries never perturb a request's draws
                    split2 = jax.vmap(jax.random.split)(keys)  # (b, 2, 2)
                    nxt = self._sample_tok(lg, split2[:, 0])
                    keys = split2[:, 1]
                else:
                    nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
                nxt = jnp.where(done[:, None], fill, nxt)
                n_gen2 = jnp.where(done, n_gen, n_gen + 1)
                d2 = done
                if eos_id is not None:
                    d2 = d2 | (nxt[:, 0] == eos_id)
                d2 = d2 | (n_gen2 >= budget)
                # freeze finished/free rows: their write pointer stops
                # one past the last real entry, so junk writes land on a
                # sentinel index forever (never read, never out of
                # bounds) and the row state is untouched until re-admission
                cache2 = {**cache2,
                          "pos": jnp.where(done, cache["pos"], cache2["pos"])}
                return (nxt, cache2, d2, n_gen2, keys), nxt[:, 0]

            (tok, cache, done, n_gen, keys), toks = jax.lax.scan(
                body, (tok, cache, done, n_gen, keys), None, length=chunk)
            return cache, tok, done, n_gen, keys, toks.T  # toks (B, chunk)

        return jax.jit(run, donate_argnums=(1, 2, 3, 4, 6))

    def _build_spec_chunk_fn(self):
        """One scan iteration = one draft+verify ROUND: the draft
        proposes ``spec_k`` tokens (plus one seating step so the last
        proposal's cache entry survives an all-accept), the target
        verifies all k+1 positions in one dispatch, and each slot
        advances by 1..k+1 accepted tokens with both caches rolled
        back past the rejected suffix (``rollback_verify`` /
        ``restore_decode`` — pos reset, checkpoint selection, or
        saved-slot restore per cache type).  Greedy acceptance forces
        non-speculative slots to zero accepts, which reduces to plain
        greedy decode (the correction token IS the greedy next token);
        sampled rounds run per-row rejection sampling through the
        shared helpers in ``runtime/speculative.py``, keyed by the
        per-slot stream key and round counter so each request's stream
        matches a batch-1 ``engine.generate_speculative`` call."""
        model = self.model
        eos_id = self.eos_id
        fill = jnp.int32(eos_id if eos_id is not None else self.pad_id)
        chunk = self.chunk
        k = self.spec_k
        temperature = self.temperature
        top_k = self.top_k
        from repro.runtime.speculative import (accept_fixup_rows,
                                               sample_rows,
                                               spec_round_keys,
                                               truncated_probs)

        def run(params, dparams, cache, dcache, tok, done, n_gen, budget,
                spec, acc, drafted, keys, rounds):
            ar = jnp.arange(k + 1)[None, :]

            def body(carry, _):
                (tok, cache, dcache, done, n_gen, acc, drafted,
                 rounds) = carry
                pos0 = cache["pos"]
                if temperature > 0.0:
                    dkeys, ukeys, ckeys = spec_round_keys(keys, rounds, k)
                else:
                    dkeys = jnp.zeros((k + 1, tok.shape[0], 2),
                                      jnp.uint32)

                def dbody(c2, kt):
                    t, dc = c2
                    ck = model.ckpt_decode(dc)
                    lg, dc = model.decode_step(dparams, t, dc)
                    lgl = lg[:, -1, :]
                    if temperature > 0.0:
                        nxt = sample_rows(lgl, kt, temperature,
                                          top_k)[:, None]
                    else:
                        nxt = jnp.argmax(lgl, axis=-1
                                         ).astype(jnp.int32)[:, None]
                    return (nxt, dc), (nxt[:, 0], lgl, ck)

                (_, dcache2), (props, dlgs, dcks) = jax.lax.scan(
                    dbody, (tok, dcache), dkeys)
                drafts = props[:k].T                         # (b, k)
                vin = jnp.concatenate([tok, drafts], axis=1)
                tlogits, vcache = model.verify_step(params, vin, cache)
                if temperature == 0.0:
                    tgt = jnp.argmax(tlogits, axis=-1).astype(jnp.int32)
                    match = (drafts == tgt[:, :k]) & spec[:, None]
                    a = jnp.sum(jnp.cumprod(match.astype(jnp.int32),
                                            axis=1), axis=1)
                    emitted = tgt        # tgt[:, :a+1] = accepts + fixup
                else:
                    p_t = truncated_probs(tlogits, temperature, top_k)
                    p_d = truncated_probs(jnp.moveaxis(dlgs[:k], 0, 1),
                                          temperature, top_k)
                    # plain rows (use_residual=False) never accept and
                    # draw every correction from plain p_t — ordinary
                    # target sampling at 1 token/round
                    match, corr = accept_fixup_rows(
                        drafts, p_t, p_d, ukeys, ckeys,
                        use_residual=spec)
                    a = jnp.sum(jnp.cumprod(match.astype(jnp.int32),
                                            axis=1), axis=1)
                    drafts_pad = jnp.pad(drafts, ((0, 0), (0, 1)))
                    emitted = jnp.where(ar < a[:, None], drafts_pad,
                                        corr)
                cap = jnp.maximum(budget - n_gen, 0)
                emit_n = jnp.minimum(a + 1, cap)
                if eos_id is not None:
                    iseos = (emitted == eos_id) & (ar < emit_n[:, None])
                    has_eos = jnp.any(iseos, axis=1)
                    emit_n = jnp.where(has_eos,
                                       jnp.argmax(iseos, axis=1) + 1,
                                       emit_n)
                emit_n = jnp.where(done, 0, emit_n)
                n_gen2 = n_gen + emit_n
                d2 = done | (n_gen2 >= budget)
                if eos_id is not None:
                    d2 = d2 | (~done & has_eos)
                last = jnp.take_along_axis(
                    emitted, jnp.maximum(emit_n - 1, 0)[:, None], axis=1)
                tok2 = jnp.where(emit_n[:, None] > 0, last, tok)
                # rollback for BOTH caches; done/free rows (emit_n == 0)
                # restore their full pre-round state
                cache2 = model.rollback_verify(vcache, pos0, emit_n)
                dcache2 = model.restore_decode(dcache2, dcks, pos0,
                                               emit_n)
                acc2 = acc + jnp.where(done | ~spec, 0,
                                       jnp.minimum(a, emit_n))
                drafted2 = drafted + jnp.where(done | ~spec, 0, k)
                rounds2 = rounds + jnp.where(done, 0, 1)
                em = jnp.where(ar < emit_n[:, None], emitted, fill)
                return ((tok2, cache2, dcache2, d2, n_gen2, acc2,
                         drafted2, rounds2), (em, emit_n))

            ((tok, cache, dcache, done, n_gen, acc, drafted, rounds),
             (ems, ens)) = jax.lax.scan(
                body, (tok, cache, dcache, done, n_gen, acc, drafted,
                       rounds),
                None, length=chunk)
            # pack each slot's variable-advance rounds contiguously so
            # the host reads "first (n_gen - seen) entries" exactly as
            # in the plain chunk path
            em = jnp.moveaxis(ems, 0, 1)             # (B, chunk, k+1)
            en = ens.T                               # (B, chunk)
            off = jnp.cumsum(en, axis=1) - en        # exclusive prefix
            cap_len = chunk * (k + 1)
            idx = off[:, :, None] + ar[None, :, :]
            idx = jnp.where(ar[None, :, :] < en[:, :, None], idx, cap_len)
            b = en.shape[0]
            buf = jnp.full((b, cap_len), fill, jnp.int32)
            rows = jnp.arange(b)[:, None]
            buf = buf.at[rows, idx.reshape(b, -1)].set(
                em.reshape(b, -1), mode="drop")
            return (cache, dcache, tok, done, n_gen, acc, drafted,
                    rounds, buf)

        return jax.jit(run, donate_argnums=(2, 3, 4, 5, 6, 9, 10, 12))

    def _build_admit_fn(self, bucket: int, kb: int, sh: int = 0):
        """Batch-``kb`` grouped admission: ONE prefill dispatch for
        ``kb`` same-bucket prompts, rows scattered into their slots.

        Paged mode prefills NATIVELY through the page pool: each row's
        block table maps its (shared + private) physical pages, and the
        prompt's k/v scatter-write straight to ``(bt[pos//P], pos%P)``
        at their final addresses — there is no contiguous scratch cache
        and no post-hoc page scatter on this path.  ``sh`` is the
        group's static page-aligned shared-prefix length: those tokens'
        k/v are already resident in prefix-index pages mapped into each
        row's table, so the prefill covers only ``prompts[:, sh:]``
        (positions advance from ``sh`` — attention still sees the full
        logical view, and masking exactness keeps the result
        bit-identical to a cold full prefill).  Contiguous mode keeps
        its one path: a row-slab prefill scattered into slot rows.
        """
        model = self.model
        eos_id = self.eos_id
        # contiguous slab caches only need the prompt bucket's length:
        # the scatter below writes a sub-slab (dynamic_update_slice
        # accepts updates smaller than the target), and everything past
        # each row's write pointer is masked until overwritten.  Ring
        # caches are the exception — their *structure* depends on
        # length.
        cache_len = self._cache_len if self._ring else bucket
        cache_dtype = self.cache_dtype
        axes = self._slot_axes
        temperature = self.temperature
        speculative = self.speculative
        paged = self._paged_kv
        paged_keys = self._paged_keys
        if sh and not paged:
            raise ValueError("shared prefixes need the paged cache")
        pf_chunk = self.prefill_chunk if paged else None

        def scatter_rows(big, sm, ax, slots):
            for i in range(kb):
                row = jax.lax.dynamic_slice_in_dim(sm, i, 1, ax)
                starts = [jnp.int32(0)] * big.ndim
                starts[ax] = slots[i]
                big = jax.lax.dynamic_update_slice(
                    big, row.astype(big.dtype), tuple(starts))
            return big

        def scatter_cache(big, small, slots):
            """Land a finished prefill in the big cache: paged leaves
            were written IN the pool (replace wholesale), every other
            leaf (pos, SSM state) row-scatters into its slot."""
            out = dict(big)            # keeps "bt" (host-mirrored)
            for key, sm in small.items():
                if key == "bt":
                    continue
                if paged and key in paged_keys:
                    out[key] = sm
                else:
                    out[key] = scatter_rows(out[key], sm, axes[key], slots)
            return out

        def paged_prefill(params, prompts, plen, bts, cache, start):
            """Native paged prefill for the uncached prompt tail.

            Builds a kb-row cache VIEW over the shared pool: the paged
            leaves ARE the pool (writes land at final page addresses
            via each row's block table), non-positional leaves come
            from a fresh kb-row init.  Prefills ``prompts[:, start:]``
            (optionally in ``prefill_chunk``-token chunks — attention
            families only; per-query masking makes the chunking
            bit-invisible), accumulating each row's logits at its true
            last token.  Padded tails are causally masked; the write
            pointer then starts at the UNPADDED length so generated
            tokens overwrite pad entries one by one."""
            scratch = model.init_cache(kb, bucket, dtype=cache_dtype)
            small = {key: leaf for key, leaf in scratch.items()
                     if key not in paged_keys}
            for key in paged_keys:
                small[key] = cache[key]
            small["bt"] = bts
            small["pos"] = jnp.full((kb,), start, jnp.int32)
            starts = (list(range(start, bucket, pf_chunk)) if pf_chunk
                      else [start])
            lg = None
            for c0 in starts:
                c1 = min(c0 + pf_chunk, bucket) if pf_chunk else bucket
                li = jnp.clip(plen - 1 - c0, 0, c1 - c0 - 1)
                logits, small = model.prefill(params, prompts[:, c0:c1],
                                              small, last_idx=li)
                lg_c = logits[:, -1, :]
                if lg is None:
                    lg = lg_c
                else:
                    # start <= plen - 1 for every row (admission always
                    # re-prefills the last prompt token), so exactly one
                    # chunk holds each row's true last position
                    in_chunk = ((plen - 1) >= c0) & ((plen - 1) < c1)
                    lg = jnp.where(in_chunk[:, None], lg_c, lg)
            return {**small, "pos": plen.astype(jnp.int32)}, lg

        def row_prefill(params, prompts, plen):
            """Contiguous-mode batch-kb prefill: one slab per row,
            scattered into slot rows afterwards.  Padded tails are
            causally masked, logits read at each row's true last
            token."""
            small = model.init_cache(kb, cache_len, dtype=cache_dtype)
            logits, small = model.prefill(params, prompts, small,
                                          last_idx=plen - 1)
            return ({**small, "pos": plen.astype(jnp.int32)},
                    logits[:, -1, :])                          # (kb, V)

        def prefill(params, prompts, plen, bts, cache):
            if paged:
                return paged_prefill(params, prompts, plen, bts, cache,
                                     sh)
            return row_prefill(params, prompts, plen)

        def set_slot_state(first, max_new, slots, tok, done, n_gen, budget):
            first_done = max_new <= 1
            if eos_id is not None:
                first_done = first_done | (first == eos_id)
            tok = tok.at[slots].set(first[:, None])
            done = done.at[slots].set(first_done)
            n_gen = n_gen.at[slots].set(1)
            budget = budget.at[slots].set(max_new)
            return tok, done, n_gen, budget

        if not speculative:
            def run(params, prompts, plen, max_new, slots, admit_keys,
                    bts, cache, tok, done, n_gen, budget, keys):
                small, lg = prefill(params, prompts, plen, bts, cache)
                if temperature > 0.0:
                    # per-request sample stream starts here: one half of
                    # the admission key draws the first token, the other
                    # seeds the slot's chunk-scan stream
                    split2 = jax.vmap(jax.random.split)(admit_keys)
                    first = self._sample_tok(lg, split2[:, 0])[:, 0]
                    keys = keys.at[slots].set(split2[:, 1])
                else:
                    first = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                cache = scatter_cache(cache, small, slots)
                tok, done, n_gen, budget = set_slot_state(
                    first, max_new, slots, tok, done, n_gen, budget)
                return cache, tok, done, n_gen, budget, keys, first

            return jax.jit(run, donate_argnums=(7, 8, 9, 10, 11, 12))

        def run(params, dparams, prompts, plen, max_new, slots, spec_new,
                admit_keys, slot_keys, bts, dbts, cache, dcache, tok,
                done, n_gen, budget, spec, acc, drafted, keys, rounds):
            small, lg = prefill(params, prompts, plen, bts, cache)
            if temperature > 0.0:
                # first token from the per-request key's prefill half —
                # the same draw a batch-1 engine.generate_speculative
                # call makes (see spec_request_key)
                from repro.runtime.speculative import sample_rows
                first = sample_rows(lg, admit_keys, temperature,
                                    self.top_k)
            else:
                first = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            cache = scatter_cache(cache, small, slots)
            # the draft shares no pages (its k/v come from DIFFERENT
            # params): its own full-prompt prefill into its own pool
            if paged:
                dsmall, _ = paged_prefill(dparams, prompts, plen, dbts,
                                          dcache, 0)
            else:
                dsmall, _ = row_prefill(dparams, prompts, plen)
            dcache = scatter_cache(dcache, dsmall, slots)
            spec = spec.at[slots].set(spec_new)
            acc = acc.at[slots].set(0)
            drafted = drafted.at[slots].set(0)
            keys = keys.at[slots].set(slot_keys)
            rounds = rounds.at[slots].set(0)
            tok, done, n_gen, budget = set_slot_state(
                first, max_new, slots, tok, done, n_gen, budget)
            return (cache, dcache, tok, done, n_gen, budget, spec, acc,
                    drafted, keys, rounds, first)

        return jax.jit(run, donate_argnums=tuple(range(11, 22)))

    def _build_resume_fn(self, bucket: int):
        """Batch-1 re-prefill for ``preemption="recompute"``: prefill
        the saved prefix (prompt + emitted tokens minus the pending
        input token) into the victim's old slot row and set its write
        pointer to the true prefix length.  No token is drawn — the
        saved ``tok``/key scalars carry the stream, so the decode
        continuation picks up exactly where the victim stopped (modulo
        prefill-vs-decode fp association, which is why only
        save_restore promises bit-identity)."""
        model = self.model
        cache_len = self._cache_len if self._ring else bucket
        cache_dtype = self.cache_dtype
        axes = self._slot_axes
        speculative = self.speculative
        paged = self._paged_kv
        paged_keys = self._paged_keys

        def scatter1(big, sm, ax, slot):
            starts = [jnp.int32(0)] * big.ndim
            starts[ax] = slot
            return jax.lax.dynamic_update_slice(big, sm.astype(big.dtype),
                                                tuple(starts))

        def refill(params, prefix, plen, slot, bts, cache):
            if paged:
                # native paged re-prefill: the batch-1 block-table row
                # addresses the slot's fresh pages, prompt k/v scatter
                # straight to their final pool addresses (same one-path
                # prefill as admission)
                scratch = model.init_cache(1, bucket, dtype=cache_dtype)
                small = {key: leaf for key, leaf in scratch.items()
                         if key not in paged_keys}
                for key in paged_keys:
                    small[key] = cache[key]
                small["bt"] = bts
                small["pos"] = jnp.zeros((1,), jnp.int32)
            else:
                small = model.init_cache(1, cache_len, dtype=cache_dtype)
            _, small = model.prefill(params, prefix, small,
                                     last_idx=plen - 1)
            small = {**small, "pos": plen.astype(jnp.int32)}
            out = dict(cache)
            for key, sm in small.items():
                if key == "bt":
                    continue
                if paged and key in paged_keys:
                    out[key] = sm       # prefill wrote the pool in place
                else:
                    out[key] = scatter1(out[key], sm, axes[key], slot)
            return out

        if not speculative:
            if paged:
                def run(params, prefix, plen, slot, bts, cache):
                    return refill(params, prefix, plen, slot, bts,
                                  cache)
                return jax.jit(run, donate_argnums=(5,))

            def run(params, prefix, plen, slot, cache):
                return refill(params, prefix, plen, slot, None, cache)
            return jax.jit(run, donate_argnums=(4,))

        if paged:
            def run(params, dparams, prefix, plen, slot, bts, dbts,
                    cache, dcache):
                return (refill(params, prefix, plen, slot, bts, cache),
                        refill(dparams, prefix, plen, slot, dbts,
                               dcache))
            return jax.jit(run, donate_argnums=(7, 8))

        def run(params, dparams, prefix, plen, slot, cache, dcache):
            return (refill(params, prefix, plen, slot, None, cache),
                    refill(dparams, prefix, plen, slot, None, dcache))
        return jax.jit(run, donate_argnums=(5, 6))

    # ---------------------------------------------------------- admission
    def _check_fits(self, req: Request, bucket: int) -> None:
        """Validate the queue head BEFORE popping it (and before the
        caller pops a free slot or pages): a request that can NEVER be
        served raises with the queue and both allocators untouched —
        deferring it would retry forever."""
        if bucket + req.max_new + self._spec_margin() + 1 > self._cache_len:
            # out-of-bounds cache writes would be silently dropped by
            # the scatter; refuse instead
            raise ValueError(
                f"request {req.request_id}: bucket mismatch — prompt "
                f"bucket {bucket} + max_new {req.max_new} (+ spec margin "
                f"{self._spec_margin()}) exceeds cache_len "
                f"{self._cache_len}")
        if self._paged_kv:
            need = self._alloc.pages_for(self._reserve_tokens(req, bucket))
            if need > int(self.num_pages):
                raise ValueError(
                    f"request {req.request_id}: worst case needs {need} "
                    f"pages but the pool holds {self.num_pages} — it can "
                    "never be admitted (raise num_pages or page_size)")

    def _reserve_tokens(self, req: Request, bucket: int) -> int:
        """Worst-case cache entries the request can ever occupy: the
        padded prompt, plus every budgeted token, plus the speculative
        overrun (verify writes k entries past the final accepted
        position before rolling back)."""
        return max(bucket, len(req.prompt) + req.max_new
                   + self._spec_margin())

    def _pages_available(self, req: Request, bucket: int,
                         shared_pages: int = 0) -> bool:
        reserve = self._reserve_tokens(req, bucket)
        if not self._alloc.can_admit(reserve, shared_pages):
            return False
        # the draft pool never shares (draft k/v come from different
        # params), so its check ignores the prefix hit
        return self._dalloc is None or self._dalloc.can_admit(reserve)

    def _reserve_pages(self, req: Request, bucket: int, slot: int,
                       shared: Tuple[int, ...] = (),
                       cow_last: bool = False) -> None:
        """Allocate the prompt's pages now, reserve the worst case —
        chunk-boundary extends then never exceed the reservation, so an
        admitted request can always run to completion.

        ``shared`` prefix-index pages map as the slot's leading logical
        pages (refcount + 1, no allocation).  ``cow_last`` marks a
        full-page-aligned hit: the LAST shared page contains the prompt
        position the tail re-prefill must write (admission always
        re-computes the final prompt token for its logits), so it is
        detached by copy-on-write before the dispatch touches it."""
        reserve = self._reserve_tokens(req, bucket)
        self._alloc.admit(slot, bucket, reserve, shared=shared)
        if cow_last:
            pair = self._alloc.cow(slot, len(shared) - 1)
            if pair is not None:
                old, new = pair
                self._dev["cache"] = copy_page(
                    self._dev["cache"], self._paged_keys, old, new)
        if self._dalloc is not None:
            self._dalloc.admit(slot, bucket, reserve)

    def _prefix_match(self, req: Request
                      ) -> Tuple[Tuple[int, ...], int, bool]:
        """Consult the prefix index for a fresh admission.

        Returns ``(shared_pages, sh, cow_last)``: the physical pages to
        map into the slot's leading block-table entries, the static
        page-aligned shared token count the prefill skips, and whether
        the last mapped page must copy-on-write (full-aligned hit whose
        final page holds the last prompt token — it is re-prefilled for
        logits, so the write needs a private copy).  Host-swapped chain
        entries are swapped back in here; the chain truncates where
        residency fails.  ``sh`` is always capped one token short of the
        prompt so the last-token logits are computed fresh."""
        plen = len(req.prompt)
        P = self.page_size
        prompt = np.asarray(req.prompt, np.int32)
        chain = self._prefix.lookup(prompt)
        if not chain:
            return (), 0, False
        self._dev["cache"], pages = self._prefix.ensure_resident(
            self._dev["cache"], chain)
        # tokens the prefill may skip: full hit pages, minus the page
        # holding position plen-1 (its logits must be recomputed)
        sh = min(len(pages) * P, ((plen - 1) // P) * P)
        kept = sh // P
        cow_last = len(pages) > kept
        if kept == 0:
            # a single-page prompt hit saves no prefill work and would
            # only cost a COW copy — treat as a miss
            return (), 0, False
        return tuple(pages[:kept + (1 if cow_last else 0)]), sh, cow_last

    def _extend_pages(self) -> None:
        """Map pages for every write the NEXT chunk dispatch can make:
        plain decode writes ``chunk`` entries past each slot's pos;
        a speculative round writes up to ``spec_k + 1`` per iteration
        plus the ``spec_k`` verify overrun.  Bounded by the slot's
        budget (== its admission reservation), so this never raises
        for an admitted request."""
        for slot, st in enumerate(self._slots):
            if st.request is None:
                continue
            plen = len(st.request.prompt)
            pos = plen + st.count - 1          # device write pointer
            if self.speculative:
                span = self.chunk * (self.spec_k + 1)
                lim = plen + st.request.max_new + self.spec_k
            else:
                span = self.chunk
                lim = plen + st.request.max_new
            need = min(pos + span, max(lim, self._bucket_for(plen)))
            self._alloc.extend(slot, need)
            if self._dalloc is not None:
                self._dalloc.extend(slot, need)

    # --------------------------------------------- preemption / cancel
    def _save_rows(self, cache: Dict[str, Any], slot: int
                   ) -> Dict[str, np.ndarray]:
        """Host copies of every non-paged cache leaf's slot row (pos,
        SSM conv/ssm state, contiguous k/v...) with the batch axis kept,
        so restore is one dynamic_update_slice per leaf."""
        rows = {}
        for key, leaf in cache.items():
            if key == "bt" or (self._paged_kv and key in self._paged_keys):
                continue
            ax = self._slot_axes[key]
            rows[key] = np.asarray(
                jax.lax.index_in_dim(leaf, slot, ax, keepdims=True))
        return rows

    def _save_pages(self, cache: Dict[str, Any], alloc: PageAllocator,
                    slot: int, n_save: int) -> Dict[str, np.ndarray]:
        """Payloads of the first ``n_save`` pages the slot's write
        pointer has touched (entries beyond ``pos`` are junk the causal
        mask excludes, so later-mapped pages need not be saved)."""
        ids = jnp.asarray(alloc.slot_pages(slot)[:n_save], jnp.int32)
        return {key: np.asarray(jnp.take(cache[key], ids, axis=1))
                for key in self._paged_keys}

    def _capture_slot(self, slot: int, mode: str) -> _SavedSlot:
        """Park a live slot's state host-side at ``mode`` depth without
        touching the slot itself.  ``save_restore`` copies the full
        device row + touched page payloads (valid on ANY cache mode —
        nothing is freed here, so contiguous rows capture fine; the
        ctor's save_restore/paged restriction only applies to live
        evictions, which must free pages).  Shared by eviction and the
        durability snapshots."""
        st = self._slots[slot]
        req = st.request
        d = self._dev
        pos = len(req.prompt) + st.count - 1   # device write pointer
        saved = _SavedSlot(
            tokens=[int(t) for t in st.tokens],
            count=st.count, pos=pos,
            tok=np.asarray(d["tok"][slot]),
            keys=np.asarray(d["keys"][slot]),
            admitted_at=st.admitted_at,
            n_preempts=st.preempts,
            mode=mode)
        if self.speculative:
            saved.spec = bool(np.asarray(d["spec"][slot]))
            saved.acc = int(np.asarray(d["acc"][slot]))
            saved.drafted = int(np.asarray(d["drafted"][slot]))
            saved.rounds = int(np.asarray(d["rounds"][slot]))
        if mode == "save_restore":
            saved.rows = self._save_rows(d["cache"], slot)
            if self.speculative:
                saved.drows = self._save_rows(d["dcache"], slot)
            if self._paged_kv:
                n_save = pages_for(pos, self.page_size)
                saved.pages = self._save_pages(d["cache"], self._alloc,
                                               slot, n_save)
                if self._dalloc is not None:
                    saved.dpages = self._save_pages(
                        d["dcache"], self._dalloc, slot, n_save)
        return saved

    def _evict(self, slot: int) -> Request:
        """Preempt the slot at a chunk boundary: park its state
        host-side (mode-dependent depth), free the slot and every page
        it holds (the zeroed block-table row sends the frozen row's
        junk writes to the sentinel page), and hand the request back
        for re-queueing."""
        st = self._slots[slot]
        req = st.request
        d = self._dev
        saved = self._capture_slot(slot, mode=self.preemption)
        saved.n_preempts += 1
        d["done"] = d["done"].at[slot].set(True)
        if self._paged_kv:
            self._alloc.free(slot)
            if self._dalloc is not None:
                self._dalloc.free(slot)
        st.request = None
        st.tokens = []
        st.count = 0
        st.preempts = 0
        st.journaled = 0
        self._free.append(slot)
        self._preempted[req.request_id] = saved
        self._n_preempt += 1
        return req

    def _pick_victim(self, priority: int) -> Optional[int]:
        """Strictly-lower-priority active slot to evict: lowest class
        first, most-recently-admitted within the class (it has the
        least sunk work)."""
        best, bkey = None, None
        for slot, st in enumerate(self._slots):
            if st.request is None or st.request.priority >= priority:
                continue
            key = (st.request.priority, -st.seq)
            if bkey is None or key < bkey:
                best, bkey = slot, key
        return best

    def _restore(self, req: Request, slot: int, saved: _SavedSlot,
                 now_t: float) -> None:
        """Re-admit a preempted request into ``slot``.  Allocator work
        happens FIRST: an injected PoolExhausted here leaves the device
        untouched and the caller hands the slot back — admission stays
        atomic under mid-admission faults."""
        d = self._dev
        n_save = 0
        # the SAVED slot's depth decides the restore path, not the
        # scheduler-wide preemption setting: durability snapshots always
        # capture at save_restore depth (even on contiguous caches), and
        # a CRC-corrupt snapshot payload degrades just its slot to
        # recompute-from-journaled-prefix
        if saved.mode == "save_restore":
            if self._paged_kv:
                bucket = self._bucket_for(len(req.prompt))
                reserve = self._reserve_tokens(req, bucket)
                n_save = pages_for(saved.pos, self.page_size)
                self._alloc.admit(slot, saved.pos, reserve)
                try:
                    if self._dalloc is not None:
                        self._dalloc.admit(slot, saved.pos, reserve)
                except PoolExhausted:
                    self._alloc.free(slot)
                    raise
            cache = dict(d["cache"])
            if self._paged_kv:
                ids = jnp.asarray(self._alloc.table[slot, :n_save])
                for key in self._paged_keys:
                    cache[key] = cache[key].at[:, ids].set(
                        jnp.asarray(saved.pages[key]).astype(
                            cache[key].dtype))
            for key, row in saved.rows.items():
                cache[key] = jax.lax.dynamic_update_slice_in_dim(
                    cache[key], jnp.asarray(row).astype(cache[key].dtype),
                    slot, self._slot_axes[key])
            d["cache"] = cache
            if self.speculative:
                dcache = dict(d["dcache"])
                if self._dalloc is not None:
                    dids = jnp.asarray(self._dalloc.table[slot, :n_save])
                    for key in self._paged_keys:
                        dcache[key] = dcache[key].at[:, dids].set(
                            jnp.asarray(saved.dpages[key]).astype(
                                dcache[key].dtype))
                for key, row in saved.drows.items():
                    dcache[key] = jax.lax.dynamic_update_slice_in_dim(
                        dcache[key],
                        jnp.asarray(row).astype(dcache[key].dtype),
                        slot, self._slot_axes[key])
                d["dcache"] = dcache
        else:
            # recompute: re-prefill prompt + emitted prefix (everything
            # except the pending input token) into the slot row
            prefix = np.concatenate([
                np.asarray(req.prompt, np.int32),
                np.asarray(saved.tokens[:saved.count - 1], np.int32)])
            plen = int(prefix.shape[0])
            assert plen == saved.pos
            bucket = min(self._bucket_for(plen), self._cache_len)
            padded = np.full((1, bucket), self.pad_id, np.int32)
            padded[0, :plen] = prefix
            pages_a = dpages_a = None
            if self._paged_kv:
                # allocate the prefix's pages + the worst-case
                # reservation exactly as a fresh admission would
                reserve = max(self._reserve_tokens(
                    req, self._bucket_for(len(req.prompt))), bucket)
                self._alloc.admit(slot, bucket, reserve)
                try:
                    if self._dalloc is not None:
                        self._dalloc.admit(slot, bucket, reserve)
                except PoolExhausted:
                    self._alloc.free(slot)
                    raise
                # full block-table rows: the native paged re-prefill
                # addresses pages through bt exactly like admission
                pages_a = jnp.asarray(self._alloc.table[slot][None, :])
                if self._dalloc is not None:
                    dpages_a = jnp.asarray(
                        self._dalloc.table[slot][None, :])
            fn = self._resume_fns.get(bucket)
            if fn is None:
                fn = self._resume_fns[bucket] = self._build_resume_fn(
                    bucket)
            plen_a = jnp.asarray([plen], jnp.int32)
            slot_a = jnp.int32(slot)
            if self.speculative:
                if self._paged_kv:
                    d["cache"], d["dcache"] = fn(
                        self.params, self.draft_params,
                        jnp.asarray(padded), plen_a, slot_a, pages_a,
                        dpages_a, d["cache"], d["dcache"])
                else:
                    d["cache"], d["dcache"] = fn(
                        self.params, self.draft_params,
                        jnp.asarray(padded), plen_a, slot_a, d["cache"],
                        d["dcache"])
            elif self._paged_kv:
                d["cache"] = fn(self.params, jnp.asarray(padded), plen_a,
                                slot_a, pages_a, d["cache"])
            else:
                d["cache"] = fn(self.params, jnp.asarray(padded), plen_a,
                                slot_a, d["cache"])
        d["tok"] = d["tok"].at[slot].set(jnp.asarray(saved.tok))
        d["done"] = d["done"].at[slot].set(False)
        d["n_gen"] = d["n_gen"].at[slot].set(saved.count)
        d["budget"] = d["budget"].at[slot].set(req.max_new)
        d["keys"] = d["keys"].at[slot].set(jnp.asarray(saved.keys))
        if self.speculative:
            d["spec"] = d["spec"].at[slot].set(bool(saved.spec))
            d["acc"] = d["acc"].at[slot].set(saved.acc)
            d["drafted"] = d["drafted"].at[slot].set(saved.drafted)
            d["rounds"] = d["rounds"].at[slot].set(saved.rounds)
        st = self._slots[slot]
        st.request = req
        st.tokens = list(saved.tokens)
        st.count = saved.count
        st.admitted_at = saved.admitted_at
        st.preempts = saved.n_preempts
        # tokens up to here are already in the WAL (emits precede any
        # eviction/snapshot); only NEW tokens need journaling
        st.journaled = saved.count
        self._seq += 1
        st.seq = self._seq
        self._n_resume += 1
        if self._paged_kv:
            self._reseed_prefix(req, slot)

    def _force_preempt(self, request_id: int) -> bool:
        """FaultPlan hook: evict the slot running ``request_id``
        regardless of priority (no-op if not active)."""
        if self.preemption == "off":
            raise ValueError(
                "FaultPlan preempt action needs preemption enabled "
                '(preemption="save_restore" or "recompute")')
        for slot, st in enumerate(self._slots):
            if (st.request is not None
                    and st.request.request_id == int(request_id)):
                req = self._evict(slot)
                self._queue = collections.deque(
                    sorted([*self._queue, req], key=self._qkey))
                return True
        return False

    def _terminate_queued(self, req: Request, reason: CancelReason,
                          now_t: float, results: List[RequestResult]
                          ) -> None:
        """Resolve a queued request without running it: preempted ones
        carry their partial tokens, never-admitted ones just the
        prompt."""
        saved = self._preempted.pop(req.request_id, None)
        self._backoff.pop(req.request_id, None)
        self._retry_at.pop(req.request_id, None)
        toks = saved.tokens if saved is not None else []
        spec_on = (self.speculative and bool(req.speculative)
                   and saved is not None)
        if self._journal is not None:
            self._journal.append(
                "finalize", rid=int(req.request_id),
                toks=[int(t) for t in toks],
                generated=(saved.count if saved is not None else 0),
                prompt_len=len(req.prompt), slot=-1,
                arrival=float(req.arrival_time),
                admitted=float(saved.admitted_at if saved is not None
                               else now_t),
                finished=float(now_t),
                accepted=saved.acc if spec_on else None,
                drafted=saved.drafted if spec_on else None,
                reason=reason.value,
                preemptions=(saved.n_preempts if saved is not None
                             else 0))
        results.append(RequestResult(
            request_id=req.request_id,
            tokens=np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(toks, np.int32)]),
            generated=saved.count if saved is not None else 0,
            prompt_len=len(req.prompt),
            slot=-1,
            arrival_time=req.arrival_time,
            admitted_at=(saved.admitted_at if saved is not None else now_t),
            finished_at=now_t,
            accepted=saved.acc if spec_on else None,
            drafted=saved.drafted if spec_on else None,
            cancel_reason=reason,
            preemptions=saved.n_preempts if saved is not None else 0))

    def _sweep_active(self, now_t: float,
                      results: List[RequestResult]) -> None:
        """Chunk-boundary cancellation/deadline check over active
        slots: free the slot and its pages immediately."""
        for slot in range(self.capacity):
            st = self._slots[slot]
            if st.request is None:
                continue
            req = st.request
            reason = None
            if req.request_id in self._cancelled:
                self._cancelled.discard(req.request_id)
                reason = CancelReason.CANCELLED
            elif (req.deadline_s is not None
                  and now_t > req.arrival_time + req.deadline_s):
                reason = CancelReason.DEADLINE
            if reason is None:
                continue
            d = self._dev
            acc_h = drafted_h = None
            if self.speculative and bool(req.speculative):
                acc_h = np.asarray(d["acc"])
                drafted_h = np.asarray(d["drafted"])
            d["done"] = d["done"].at[slot].set(True)
            self._finalize(slot, now_t, results, acc_h, drafted_h,
                           reason=reason)

    def _defer(self, req: Request, reason: str, now_t: float,
               results: List[RequestResult],
               rejected: List[Rejected]) -> bool:
        """Backpressure bookkeeping for a blocked request.  Returns
        False when the retry budget is exhausted and the request was
        resolved (Rejected, or preempted_unresumed with partial
        tokens) — the caller drops it from the queue."""
        if self._admit_retries is None and self._backoff_base == 0.0:
            return True                    # legacy: retry every boundary
        rid = req.request_id
        policy = self._backoff.get(rid)
        if policy is None:
            policy = self._backoff[rid] = RestartPolicy(
                max_restarts=(self._admit_retries
                              if self._admit_retries is not None
                              else 1 << 30),
                window_s=float("inf"),
                base_backoff_s=self._backoff_base,
                max_backoff_s=self._backoff_max,
                clock=self._clock)
        delay = policy.on_failure()
        if delay is None:
            attempts = len(policy.crashes)
            if rid in self._preempted:
                self._terminate_queued(
                    req, CancelReason.PREEMPTED_UNRESUMED, now_t, results)
            else:
                self._backoff.pop(rid, None)
                self._retry_at.pop(rid, None)
                if self._journal is not None:
                    self._journal.append("reject", rid=rid, reason=reason,
                                         attempts=attempts,
                                         at_s=float(now_t))
                rejected.append(Rejected(request_id=rid, reason=reason,
                                         attempts=attempts,
                                         rejected_at=now_t))
            return False
        if delay > 0.0:
            self._retry_at[rid] = now_t + delay
        return True

    def _try_admit(self, req: Request, now_t: float,
                   pending: List[Tuple[Request, int, int]],
                   requeued: List[Request]
                   ) -> Tuple[bool, Optional[str]]:
        """Admit one request (fresh or resumed), preempting
        strictly-lower-priority victims if enabled and needed.  On
        failure everything is left as found (modulo victims already
        evicted for a newcomer whose own admission then faulted — they
        are parked and re-queued, a consistent state).

        Fresh paged admissions consult the prefix index FIRST: a hit
        maps the resident shared pages into the new slot's block table
        and only the uncached tail is prefilled.  When pages run short
        the index spills its coldest index-only pages to host memory
        before this falls back to a ``no_pages`` deferral."""
        rid = req.request_id
        saved = self._preempted.get(rid)
        bucket = self._bucket_for(len(req.prompt))
        shared: Tuple[int, ...] = ()
        sh = 0
        cow_last = False
        if saved is None:
            self._check_fits(req, bucket)  # never-fits raises here
            if self._prefix is not None:
                shared, sh, cow_last = self._prefix_match(req)
        kept = sh // self.page_size if self._paged_kv else 0
        spilled = False
        while True:
            if not self._free:
                reason = "no_slot"
            elif (self._paged_kv
                    and not self._pages_available(req, bucket, kept)):
                if self._prefix is not None and not spilled:
                    # swap cold index-only pages to host instead of
                    # deferring; exclude the pages this very admission
                    # is about to map
                    reserve = self._alloc.pages_for(
                        self._reserve_tokens(req, bucket))
                    need = reserve - kept - self._alloc.headroom()
                    self._dev["cache"], freed = self._prefix.spill(
                        self._dev["cache"], need, exclude=set(shared))
                    spilled = True
                    if freed:
                        continue
                reason = "no_pages"
            else:
                break
            victim = (self._pick_victim(req.priority)
                      if self.preemption != "off" else None)
            if victim is None:
                return False, reason
            requeued.append(self._evict(victim))
        slot = self._free.pop()
        try:
            if saved is not None:
                self._restore(req, slot, saved, now_t)
                self._preempted.pop(rid, None)
            else:
                if self._paged_kv:
                    self._reserve_pages(req, bucket, slot, shared=shared,
                                        cow_last=cow_last)
                if self._prefix is not None:
                    if sh > 0:
                        self._prefix.hits += 1
                    else:
                        self._prefix.misses += 1
                pending.append((req, slot, sh))
        except PoolExhausted:
            # injected mid-admission allocator fault: hand back the
            # slot and any partially-allocated pages, stay deferred
            if self._paged_kv:
                self._alloc.free(slot)
                if self._dalloc is not None:
                    self._dalloc.free(slot)
            self._free.append(slot)
            return False, "no_pages"
        return True, None

    def _admission_scan(self, now_t: float, results: List[RequestResult],
                        deferrals: Dict[str, int],
                        rejected: List[Rejected],
                        pending: List[Tuple[Request, int, int]],
                        limit: Optional[int] = None) -> None:
        """One chunk-boundary pass over the queue in ``_qkey`` order:
        resolve cancels/deadlines, honour backoff timers, admit what
        fits (preempting if enabled).  A blocked or backing-off request
        sets a priority ceiling — nothing at or below its class admits
        behind it (FIFO within priority; higher classes may pass)."""
        snapshot = list(self._queue)
        out: List[Request] = []
        requeued: List[Request] = []
        ceiling: Optional[int] = None
        admitted = 0
        i = 0
        try:
            for i, req in enumerate(snapshot):
                rid = req.request_id
                if rid in self._cancelled:
                    self._cancelled.discard(rid)
                    self._terminate_queued(req, CancelReason.CANCELLED,
                                           now_t, results)
                    continue
                if (req.deadline_s is not None
                        and now_t > req.arrival_time + req.deadline_s):
                    self._terminate_queued(req, CancelReason.DEADLINE,
                                           now_t, results)
                    continue
                if req.arrival_time > now_t:
                    out.append(req)
                    continue
                if limit is not None and admitted >= limit:
                    out.append(req)
                    continue
                if ceiling is not None and req.priority <= ceiling:
                    out.append(req)
                    continue
                if self._retry_at.get(rid, 0.0) > now_t:
                    ceiling = req.priority     # backing off, holds FIFO
                    out.append(req)
                    continue
                ok, reason = self._try_admit(req, now_t, pending, requeued)
                if ok:
                    admitted += 1
                    self._retry_at.pop(rid, None)
                    self._backoff.pop(rid, None)
                else:
                    deferrals[reason] = deferrals.get(reason, 0) + 1
                    if self._last_block is None:
                        self._last_block = reason
                    if self._defer(req, reason, now_t, results, rejected):
                        ceiling = req.priority
                        out.append(req)
        except Exception:
            # a mid-scan raise (never-fits request, real allocator bug)
            # must lose nothing: hand back this pass's not-yet-prefilled
            # pops and requeue everything untouched
            for req2, slot, _sh in pending:
                if self._paged_kv:
                    self._alloc.free(slot)
                    if self._dalloc is not None:
                        self._dalloc.free(slot)
                self._free.append(slot)
                out.append(req2)
            pending.clear()
            out.extend(snapshot[i:])
            self._queue = collections.deque(
                sorted(out + requeued, key=self._qkey))
            raise
        self._queue = collections.deque(
            sorted(out + requeued, key=self._qkey))

    def _admit_many(self, admissions: List[Tuple[Request, int, int]],
                    now: float) -> None:
        """Group (request, slot, shared-len) triples by (prompt bucket,
        shared-prefix length) and admit each group through batch-k
        prefill dispatches (k ∈ ADMIT_BATCH).  ``sh`` joins the group
        key because it is a STATIC slice bound of the jitted admission
        fn — prompts with equal buckets but different cache hits prefill
        different tails."""
        groups: Dict[Tuple[int, int], List[Tuple[Request, int]]] = {}
        for req, slot, sh in admissions:
            bucket = self._bucket_for(len(req.prompt))
            groups.setdefault((bucket, sh), []).append((req, slot))
        for (bucket, sh), pairs in groups.items():
            i = 0
            while i < len(pairs):
                kb = next(s for s in ADMIT_BATCH if s <= len(pairs) - i)
                self._admit_batch(bucket, sh, pairs[i:i + kb], now)
                i += kb

    def _admit_batch(self, bucket: int, sh: int,
                     pairs: List[Tuple[Request, int]], now: float) -> None:
        kb = len(pairs)
        padded = np.full((kb, bucket), self.pad_id, np.int32)
        plens = np.zeros((kb,), np.int32)
        max_news = np.zeros((kb,), np.int32)
        slots = np.zeros((kb,), np.int32)
        spec_new = np.zeros((kb,), bool)
        for i, (req, slot) in enumerate(pairs):
            plen = len(req.prompt)
            padded[i, :plen] = np.asarray(req.prompt, np.int32)
            plens[i] = plen
            max_news[i] = req.max_new
            slots[i] = slot
            spec_new[i] = bool(req.speculative)
        fn = self._admit_fns.get((bucket, kb, sh))
        if fn is None:
            fn = self._admit_fns[(bucket, kb, sh)] = self._build_admit_fn(
                bucket, kb, sh)
        d = self._dev
        if self._paged_kv:
            # full block-table rows (shared prefix pages + private
            # pages, mapped when the request was popped): the native
            # prefill scatter-writes through these to final addresses
            pages = jnp.asarray(np.stack(
                [self._alloc.table[slot] for _, slot in pairs]))
            dpages = (jnp.asarray(np.stack(
                [self._dalloc.table[slot] for _, slot in pairs]))
                if self._dalloc is not None else jnp.zeros((kb, 1),
                                                           jnp.int32))
        else:
            pages = jnp.zeros((kb, 1), jnp.int32)
            dpages = jnp.zeros((kb, 1), jnp.int32)
        if self.speculative:
            if self.temperature > 0.0:
                # per-request stream keys: fold_in(scheduler key,
                # request_id) split exactly as a batch-1
                # engine.generate_speculative(key=...) call — prefill
                # half draws the first token, round half seeds the
                # slot's per-round stream (row index 0)
                a_keys, s_keys = [], []
                for req, _ in pairs:
                    kq = self.spec_request_key(req.request_id)
                    kp, kr = jax.random.split(kq)
                    a_keys.append(jax.random.fold_in(kp, 0))
                    s_keys.append(jax.random.fold_in(kr, 0))
                admit_keys = jnp.stack(a_keys)
                slot_keys = jnp.stack(s_keys)
            else:
                admit_keys = jnp.zeros((kb, 2), jnp.uint32)
                slot_keys = jnp.zeros((kb, 2), jnp.uint32)
            (cache, dcache, tok, done, n_gen, budget, spec, acc, drafted,
             keys2, rounds, first) = fn(
                self.params, self.draft_params, jnp.asarray(padded),
                jnp.asarray(plens), jnp.asarray(max_news),
                jnp.asarray(slots), jnp.asarray(spec_new), admit_keys,
                slot_keys, pages, dpages, d["cache"], d["dcache"],
                d["tok"], d["done"], d["n_gen"], d["budget"], d["spec"],
                d["acc"], d["drafted"], d["keys"], d["rounds"])
            d.update(cache=cache, dcache=dcache, tok=tok, done=done,
                     n_gen=n_gen, budget=budget, spec=spec, acc=acc,
                     drafted=drafted, keys=keys2, rounds=rounds)
        else:
            if self.temperature > 0.0:
                # same per-request derivation as speculative slots:
                # fold_in(scheduler key, request_id) — a request's
                # stream never depends on admission order or placement
                admit_keys = jnp.stack(
                    [jax.random.fold_in(self._sample_key, req.request_id)
                     for req, _ in pairs])
            else:
                admit_keys = jnp.zeros((kb, 2), jnp.uint32)
            cache, tok, done, n_gen, budget, keys2, first = fn(
                self.params, jnp.asarray(padded), jnp.asarray(plens),
                jnp.asarray(max_news), jnp.asarray(slots), admit_keys,
                pages, d["cache"], d["tok"], d["done"], d["n_gen"],
                d["budget"], d["keys"])
            d.update(cache=cache, tok=tok, done=done, n_gen=n_gen,
                     budget=budget, keys=keys2)
        for i, (req, slot) in enumerate(pairs):
            st = self._slots[slot]
            st.request = req
            # keep the first token as a device scalar: int() here would
            # block the host on the prefill dispatch; finalize converts
            st.tokens = [first[i]]
            st.count = 1
            st.admitted_at = now
            st.preempts = 0
            st.journaled = 0
            self._seq += 1
            st.seq = self._seq
            if self._prefix is not None:
                # index this prompt's full pages right after dispatch
                # (XLA executes the prefill before any later read, so
                # mapping the page ids now is safe) — admissions later
                # in THIS burst can already share them
                plen = len(req.prompt)
                self._prefix.insert(
                    np.asarray(req.prompt, np.int32), plen,
                    self._alloc.slot_pages(slot)[
                        :self._alloc.pages_for(plen)])

    def _finalize(self, slot: int, now: float, results: List[RequestResult],
                  acc_h=None, drafted_h=None,
                  reason: Optional[CancelReason] = None) -> None:
        st = self._slots[slot]
        req = st.request
        # accept/draft counters only exist for slots that really ran
        # draft/verify; plain slots report n/a (None), never 0-of-0
        spec_on = (self.speculative and bool(req.speculative)
                   and acc_h is not None)
        toks_list = [int(t) for t in st.tokens]
        accepted = int(acc_h[slot]) if spec_on else None
        drafted = int(drafted_h[slot]) if spec_on else None
        if self._journal is not None:
            self._journal.append(
                "finalize", rid=int(req.request_id), toks=toks_list,
                generated=int(st.count), prompt_len=len(req.prompt),
                slot=int(slot), arrival=float(req.arrival_time),
                admitted=float(st.admitted_at), finished=float(now),
                accepted=accepted, drafted=drafted,
                reason=(reason.value if reason is not None else None),
                preemptions=int(st.preempts))
        results.append(RequestResult(
            request_id=req.request_id,
            tokens=np.concatenate([np.asarray(req.prompt, np.int32),
                                   np.asarray(toks_list, np.int32)]),
            generated=st.count,
            prompt_len=len(req.prompt),
            slot=slot,
            arrival_time=req.arrival_time,
            admitted_at=st.admitted_at,
            finished_at=now,
            accepted=accepted,
            drafted=drafted,
            cancel_reason=reason,
            preemptions=st.preempts,
        ))
        st.request = None
        st.tokens = []
        st.count = 0
        st.preempts = 0
        st.journaled = 0
        if self._paged_kv:
            # free-on-eos: every page (and the reservation) returns to
            # the pool the moment the slot finalizes
            self._alloc.free(slot)
            if self._dalloc is not None:
                self._dalloc.free(slot)
        self._free.append(slot)

    # -------------------------------------------------------- durability
    def _take_snapshot(self, step: int) -> None:
        """Capture every active slot at save_restore depth plus the
        queue into the snapshot store (async, atomic-rename commit).
        Snapshots are tagged by the journal LSN — monotone across
        process restarts, unlike the step counter, and recovery uses it
        to know which journal suffix postdates the snapshot.  Scalars
        (tokens / tok / PRNG key / spec counters) live in meta.json so
        a CRC-corrupt payload file degrades its slot to recompute
        instead of losing it."""
        slot_arrays: Dict[int, Dict[str, np.ndarray]] = {}
        slot_meta: Dict[str, Any] = {}
        for slot, st in enumerate(self._slots):
            if st.request is None:
                continue
            saved = self._capture_slot(slot, mode="save_restore")
            arrays: Dict[str, np.ndarray] = {}
            for pfx, payload in (("rows__", saved.rows),
                                 ("drows__", saved.drows),
                                 ("pages__", saved.pages),
                                 ("dpages__", saved.dpages)):
                for key, arr in (payload or {}).items():
                    arrays[pfx + key] = arr
            slot_arrays[slot] = arrays
            sm: Dict[str, Any] = {
                "request": _request_meta(st.request),
                "tokens": saved.tokens, "count": saved.count,
                "pos": saved.pos, "tok": int(saved.tok[0]),
                "keys": [int(saved.keys[0]), int(saved.keys[1])],
                "admitted_at": saved.admitted_at,
                "n_preempts": saved.n_preempts}
            if self.speculative:
                sm.update(spec=saved.spec, acc=saved.acc,
                          drafted=saved.drafted, rounds=saved.rounds)
            if self._paged_kv:
                # shared-page mapping + refcounts travel with the
                # snapshot: payloads above hold page CONTENTS, so
                # recovery restores private copies and _reseed_prefix
                # rebuilds sharing — this records what sharing existed
                pgs = self._alloc.slot_pages(slot)
                sm["pages"] = [int(pg) for pg in pgs]
                sm["refcounts"] = [int(self._alloc.refcount(pg))
                                   for pg in pgs]
                sm["shared"] = sum(
                    1 for pg in pgs if self._alloc.refcount(pg) > 1)
            slot_meta[str(slot)] = sm
        meta = {
            "step": int(step),
            "lsn": int(self._journal.lsn) if self._journal is not None
            else 0,
            "config": self._durability_config(),
            "slots": slot_meta,
            "queue": [_request_meta(r) for r in self._queue],
        }
        if self._prefix is not None:
            meta["prefix"] = {
                "entries": len(self._prefix),
                "resident": self._prefix.resident_pages(),
                "swapped": self._prefix.swapped_pages(),
            }
        tag = meta["lsn"] if self._journal is not None else int(step)
        self._snap_store.save(tag, slot_arrays, meta)

    def _reseed_prefix(self, req: Request, slot: int) -> None:
        """Re-seed the prefix index from a restored slot.  Restores
        always land on private pages (snapshot/preemption payloads
        carry page CONTENTS), and after a crash the old process's index
        — host-side state — is gone; re-inserting the slot's full
        prompt pages lets the resumed drain share again.  Only full
        prompt pages are indexed, and decode writes land at
        ``pos >= plen``, so indexed pages are never written after this.
        On a plain preemption resume the digests usually still exist
        (pinned through the eviction) — insert just touches them."""
        if self._prefix is None:
            return
        prompt = np.asarray(req.prompt, np.int32)
        plen = int(prompt.shape[0])
        pages = self._alloc.slot_pages(slot)
        self._prefix.insert(prompt, plen,
                            pages[:self._alloc.pages_for(plen)])

    # --------------------------------------------------------------- run
    def run(self, requests: Optional[Sequence[Request]] = None
            ) -> SchedulerRun:
        """Drain ``requests`` (plus anything already submitted).

        Arrivals are honoured against the wall clock: a request with
        ``arrival_time=t`` becomes admissible ``t`` seconds after the
        drain starts.  Admission happens at chunk boundaries (grouped
        into batch-k prefills); the hot loop is one jitted chunk
        dispatch per ``chunk`` decode steps or draft/verify rounds.
        """
        for r in requests or ():
            self.submit(r)
        self._queue = collections.deque(
            sorted(self._queue, key=self._qkey))
        self._ensure_state()
        if self._journal is not None and not self._journal_cfg:
            # one config record per journal: pins everything the resumed
            # streams' bit-identity depends on (recovery validates it)
            self._journal.append("config", **self._durability_config())
            self._journal_cfg = True
        if self._chunk_fn is None:
            self._chunk_fn = (self._build_spec_chunk_fn() if self.speculative
                              else self._build_chunk_fn())

        results: List[RequestResult] = []
        occupancy: List[Tuple[float, int]] = []
        deferrals: Dict[str, int] = {}
        rejected: List[Rejected] = []
        slow: set = set()
        chunks = 0
        step = 0
        self._backoff.clear()
        self._retry_at.clear()
        # NOTE: _cancelled deliberately survives across run() calls —
        # cancel() promises "honoured at the next chunk boundary", and
        # crash recovery re-applies journaled-but-unhonoured cancels
        # BEFORE the resumed drain starts (durability.recover_into)
        self._last_block = None
        self._n_preempt = 0
        self._n_resume = 0
        plan = self._fault_plan
        straggler = StragglerDetector(threshold=self._straggler_threshold,
                                      patience=2)
        # retries for injected pre-dispatch faults (dispatch_error,
        # chunk-boundary extend hit by an armed allocator fault): the
        # fault fires BEFORE any buffer donation, so state is intact
        # and the retried chunk emits identical tokens
        dispatch_policy = RestartPolicy(
            max_restarts=self._dispatch_retries, window_s=float("inf"),
            base_backoff_s=self._backoff_base,
            max_backoff_s=self._backoff_max, clock=self._clock)
        dispatch_fault = False
        # the allocator and prefix index persist across run() calls (a
        # warm prefix cache is the whole point), so per-run counters
        # are diffs against their values at drain start
        _pa, _pi = self._alloc, self._prefix
        cow0 = _pa.cow_copies if _pa is not None else 0
        hits0 = _pi.hits if _pi is not None else 0
        miss0 = _pi.misses if _pi is not None else 0
        sin0 = _pi.swap_ins if _pi is not None else 0
        sout0 = _pi.swap_outs if _pi is not None else 0
        t0 = self._clock()

        def now() -> float:
            skew = plan.skew if plan is not None else 0.0
            return self._clock() - t0 + skew

        # backoff disabled (the legacy spin-retry configuration)?
        legacy = self._admit_retries is None and self._backoff_base == 0.0

        while self._queue or len(self._free) < self.capacity:
            now_t = now()
            # fault-plan actions for this boundary fire exactly once —
            # a boundary retried after an injected dispatch failure
            # does not re-fire them
            if plan is not None:
                for kind, arg in plan.take(step):
                    if kind == "cancel":
                        self.cancel(arg)
                    elif kind == "preempt":
                        self._force_preempt(arg)
                    elif kind == "clock_skew":
                        plan.skew += float(arg)
                    elif kind == "pool_exhausted":
                        if self._alloc is not None:
                            self._alloc.inject_fault()
                    elif kind == "dispatch_error":
                        dispatch_fault = True
                    elif kind == "crash":
                        # simulated process death: propagate with NO
                        # cleanup — the journal is fsync'd per record
                        # and snapshots commit atomically, so disk state
                        # is exactly what a SIGKILL here would leave
                        raise SchedulerCrash(step)
                now_t = now()
            step += 1
            # cancellation/deadline sweep over active slots, then the
            # queue walk: admission — continuous refills freed slots at
            # every boundary; drain is textbook static batching (waits
            # for ALL slots free plus a full batch's worth of arrivals)
            # but still resolves queued cancels/deadlines in between.
            self._sweep_active(now_t, results)
            pending: List[Tuple[Request, int]] = []
            self._last_block = None
            if self.admission == "continuous":
                self._admission_scan(now_t, results, deferrals, rejected,
                                     pending)
            else:
                limit = 0
                if len(self._free) == self.capacity and self._queue:
                    need = min(self.capacity, len(self._queue))
                    if list(self._queue)[need - 1].arrival_time <= now_t:
                        limit = need
                self._admission_scan(now_t, results, deferrals, rejected,
                                     pending, limit=limit)
            if pending:
                self._admit_many(pending, now())
            active = self.capacity - len(self._free)
            if active == 0:
                if not self._queue:
                    continue               # loop condition exits
                if (self._last_block == "no_pages" and legacy
                        and plan is None):
                    # nothing in flight can ever free a page: refusing
                    # loudly beats spinning (reservation accounting
                    # makes this unreachable unless state is corrupt —
                    # _check_fits already rejects never-fits requests;
                    # with backoff enabled the retry budget resolves it
                    # to Rejected instead)
                    raise PoolExhausted(
                        "page pool exhausted with zero active slots — "
                        f"cannot make progress [{self._alloc.accounting()}]")
                # idle: sleep up to the next admissible arrival or
                # backoff-retry time
                target = min(
                    max(r.arrival_time,
                        self._retry_at.get(r.request_id, 0.0))
                    for r in self._queue)
                wait = target - now()
                if wait > 0:
                    self._sleep(min(wait, 0.01))
                continue
            t_chunk = self._clock()
            try:
                if dispatch_fault:
                    dispatch_fault = False
                    raise InjectedFault("injected dispatch failure")
                if self._paged_kv:
                    # map pages for every write the next dispatch can
                    # make, then mirror the block tables to the device
                    self._extend_pages()
                    d0 = self._dev
                    d0["cache"]["bt"] = jnp.asarray(self._alloc.table)
                    if self.speculative:
                        d0["dcache"]["bt"] = jnp.asarray(self._dalloc.table)
            except (InjectedFault, PoolExhausted):
                # pre-dispatch failure: nothing was donated, state is
                # intact — back off and retry the boundary (extend is
                # idempotent: already-covered slots are no-ops)
                delay = dispatch_policy.on_failure()
                if delay is None:
                    raise
                if delay > 0:
                    self._sleep(delay)
                continue
            occupancy.append((now(), active))
            d = self._dev
            acc_h = drafted_h = None
            if self.speculative:
                (cache, dcache, tok, done, n_gen, acc, drafted, rounds,
                 toks) = self._chunk_fn(
                    self.params, self.draft_params, d["cache"], d["dcache"],
                    d["tok"], d["done"], d["n_gen"], d["budget"],
                    d["spec"], d["acc"], d["drafted"], d["keys"],
                    d["rounds"])
                d.update(cache=cache, dcache=dcache, tok=tok, done=done,
                         n_gen=n_gen, acc=acc, drafted=drafted,
                         rounds=rounds)
            else:
                cache, tok, done, n_gen, keys, toks = self._chunk_fn(
                    self.params, d["cache"], d["tok"], d["done"],
                    d["n_gen"], d["budget"], d["keys"])
                d.update(cache=cache, tok=tok, done=done, n_gen=n_gen,
                         keys=keys)
            chunks += 1
            done_h = np.asarray(d["done"])
            ngen_h = np.asarray(d["n_gen"])
            toks_h = np.asarray(toks)
            # per-chunk dispatch wall-time (the np.asarray sync above
            # blocks on the dispatch) -> straggler detection: chunks
            # persistently slower than the run median get flagged
            straggler.record(f"c{chunks - 1}", self._clock() - t_chunk)
            for h in straggler.stragglers():
                slow.add(int(h[1:]))
            if self.speculative and any(
                    done_h[s] for s in range(self.capacity)
                    if self._slots[s].request is not None):
                # accept counters only matter when a slot finalizes this
                # chunk; skip the transfers on no-finish chunks
                acc_h = np.asarray(d["acc"])
                drafted_h = np.asarray(d["drafted"])
            tnow = now()
            jtok = jkeys = jacc = jdraft = jrounds = None
            if self._journal is not None:
                # emit records carry the slot's post-chunk scalars (next
                # input token, PRNG key, spec counters): enough for the
                # recompute fallback to continue the exact stream even
                # when the snapshot payload is lost
                jtok = np.asarray(d["tok"])
                jkeys = np.asarray(d["keys"])
                if self.speculative:
                    jacc = np.asarray(d["acc"])
                    jdraft = np.asarray(d["drafted"])
                    jrounds = np.asarray(d["rounds"])
            for slot in range(self.capacity):
                st = self._slots[slot]
                if st.request is None:
                    continue
                # a slot's real tokens are the first (n_gen - seen)
                # entries of its chunk row: once done it emits fill
                # (speculative rounds pre-pack variable advances the
                # same way)
                new = int(ngen_h[slot]) - st.count
                if new > 0:
                    st.tokens.extend(int(t) for t in toks_h[slot, :new])
                    st.count += new
                if self._journal is not None and st.count > st.journaled:
                    rec = dict(
                        rid=int(st.request.request_id),
                        at=int(st.journaled),
                        toks=[int(t) for t in
                              st.tokens[st.journaled:st.count]],
                        tok=int(jtok[slot, 0]),
                        keys=[int(jkeys[slot, 0]), int(jkeys[slot, 1])])
                    if self.speculative:
                        rec.update(acc=int(jacc[slot]),
                                   drafted=int(jdraft[slot]),
                                   rounds=int(jrounds[slot]))
                    self._journal.append("emit", **rec)
                    st.journaled = st.count
                if done_h[slot]:
                    self._finalize(slot, tnow, results, acc_h, drafted_h)
            if (self._snap_store is not None and self._snap_every > 0
                    and chunks % self._snap_every == 0
                    and (self._queue or len(self._free) < self.capacity)):
                self._take_snapshot(step)

        elapsed = now()
        gen = sum(r.generated for r in results)
        return SchedulerRun(
            results=results, elapsed=elapsed, generated=gen, chunks=chunks,
            occupancy=occupancy,
            accepted=sum(r.accepted for r in results
                         if r.accepted is not None),
            drafted=sum(r.drafted for r in results
                        if r.drafted is not None),
            deferrals=deferrals, rejected=rejected,
            preemptions=self._n_preempt, resumes=self._n_resume,
            slow_chunks=sorted(slow),
            page_high_water=_pa.high_water if _pa is not None else 0,
            prefix_hits=(_pi.hits - hits0) if _pi is not None else 0,
            prefix_misses=(_pi.misses - miss0) if _pi is not None else 0,
            cow_copies=(_pa.cow_copies - cow0) if _pa is not None else 0,
            swap_ins=(_pi.swap_ins - sin0) if _pi is not None else 0,
            swap_outs=(_pi.swap_outs - sout0) if _pi is not None else 0)
