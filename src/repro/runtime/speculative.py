"""Speculative decoding: a compressed draft proposes, the target
verifies k+1 positions in ONE dispatch.

PIFA's density dial gives the draft for free: the same architecture
compressed more aggressively (lower MPIFA density, lower rank) decodes
cheaply, and the full-density target scores the whole proposed run in
a single multi-token cached forward (`model.verify_step`).  Accepted
runs advance every cache by 1..k+1 tokens per round instead of 1 —
the first serving mode here where tokens/dispatch exceeds 1.

Acceptance follows standard rejection sampling:

  greedy      accept d_i while it equals the target argmax; emit the
              target's own token at the first mismatch (or the bonus
              token after k accepts).  Output is BIT-IDENTICAL to
              target-only engine generation — the same bar PR 1/2 used.
  sampled     accept d_i w.p. min(1, p_t(d_i)/p_d(d_i)); on reject,
              sample from the normalized residual max(0, p_t - p_d).
              The emitted distribution equals target-only sampling
              (Leviathan et al. 2023), though not draw-for-draw.

Rollback follows the per-cache-type contract in ``models/layers.py``
(see the "Speculative verify" section there): positional KV caches
reset ``pos`` and let the causal mask hide the rejected suffix; SSM
recurrences and ring circular buffers verify via a scan of cached
decode steps with per-step state checkpoints (k+1 small states / saved
ring slots), and ``rollback_verify`` / ``restore_decode`` select or
restore the accepted prefix.  The same hooks roll the DRAFT cache back
(``ckpt_decode`` snapshots collected in the draft scan).

The contract is ADDRESSING-AGNOSTIC: the scheduler's paged slots
(``cache="paged"``, ``runtime/paging.py``) page both the target and
the draft KV through block tables that ride inside the cache pytree,
and every hook passes them through untouched — verify's k+1 writes may
span a page boundary, but rollback stays a ``pos`` reset because pages
are only freed at finalize, never mid-flight, so rejected-suffix junk
is causally masked exactly as in a contiguous cache
(tests/test_rollback.py's paged property tests pin this).

Sampled streams are PER-ROW keyed: row i of a generate call draws from
``fold_in(key_r, i)`` folded with its round counter, and the per-round
draft/accept/correction draws flow through the shared helpers below
(`sample_rows`, `spec_round_keys`, `accept_fixup_rows`).  The serving
scheduler threads the identical derivation through its slots, so a
sampled speculative scheduler slot reproduces the token stream of a
batch-1 ``engine.generate_speculative`` call with the same key.

That per-slot round counter is also what makes speculative slots
PREEMPTIBLE: the scheduler's save/restore path (``preemption=
"save_restore"``) checkpoints each slot's stream key together with its
round counter and accept/draft accounting, and pages both the TARGET
and the DRAFT KV pools through the same block-table snapshot.  Because
preemption only happens at chunk boundaries — never mid-round — a
restored slot's next round folds the same (key, round) pair it would
have folded uninterrupted, so a preempted-and-resumed sampled
speculative request emits the bit-identical token stream.  Nothing in
this module needs to know about preemption; the contract it must hold
is only that all cross-round state lives in (cache, cur, done,
n_emitted, out, round counter), which the round function above already
guarantees.

The per-round device program is: one scanned draft pass (k+1 draft
decode steps — the extra step seats the last proposal's k/v for the
all-accept case), one target verify dispatch, and pure-jnp accept /
rollback / output-scatter bookkeeping.  The Python loop re-enters once
per ROUND (1..k+1 tokens), not per token.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["SpeculativeResult", "SpeculativeEngine", "truncated_probs",
           "sample_rows", "spec_round_keys", "accept_fixup_rows"]


@dataclasses.dataclass(frozen=True)
class SpeculativeResult:
    """One speculative generation call with accept/reject accounting."""

    tokens: jax.Array          # (b, prompt_len + max_new) int32
    tokens_per_sec: float      # generated tokens / wall-clock (post-warmup)
    generated: int             # real (pre-eos) generated token count
    compile_time: float        # first-call tracing+compile seconds
    rounds: int                # draft+verify rounds (verify dispatches)
    alive_rounds: int          # sum over rounds of alive (undone) rows
    drafted: int               # draft tokens proposed (alive rows only)
    accepted: int              # draft tokens accepted by the target
    emitted: int               # tokens emitted by spec rounds (incl. eos)

    @property
    def acceptance_rate(self) -> float:
        """Fraction of proposed draft tokens the target accepted."""
        return self.accepted / max(self.drafted, 1)

    @property
    def emitted_per_dispatch(self) -> float:
        """Mean tokens materialized per verify dispatch per alive row
        (target-only decoding scores exactly 1.0 on this metric)."""
        return self.emitted / max(self.alive_rounds, 1)


def truncated_probs(logits: jax.Array, temperature: float,
                    top_k: int) -> jax.Array:
    """The sampling distribution `engine.sample_logits` draws from:
    optional top-k truncation, then temperature softmax.  Rejection
    sampling needs the *probabilities*, not just draws, so draft and
    target distributions must go through the identical transform."""
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.nn.softmax(logits / temperature, axis=-1)


def sample_rows(logits: jax.Array, keys: jax.Array, temperature: float,
                top_k: int) -> jax.Array:
    """Per-row-keyed sampling: (b, V) logits + (b, 2) keys -> (b,) int32.

    Same transform as ``engine.sample_logits`` but each row draws from
    its OWN key, so a scheduler slot and a batch-1 engine row with the
    same key produce the same draw.
    """
    if top_k > 0:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    return jax.vmap(lambda kk, lg: jax.random.categorical(
        kk, lg / temperature))(keys, logits).astype(jnp.int32)


def spec_round_keys(row_keys: jax.Array, round_idx: jax.Array, k: int
                    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row draft/accept/correction keys for one draft+verify round.

    row_keys (b, 2) per-row stream keys; round_idx (b,) per-row round
    counters (the engine broadcasts its global round, the scheduler
    carries a per-slot counter — for a row served alone they agree).
    Returns (dkeys (k+1, b, 2) scan-ready, ukeys (b, 2), ckeys (b, 2)).
    """
    rk = jax.vmap(jax.random.fold_in)(row_keys, round_idx)
    trio = jax.vmap(lambda kk: jax.random.split(kk, 3))(rk)      # (b, 3, 2)
    dk = jax.vmap(lambda kk: jax.random.split(kk, k + 1))(trio[:, 0])
    return jnp.moveaxis(dk, 0, 1), trio[:, 1], trio[:, 2]


def accept_fixup_rows(drafts: jax.Array, p_t: jax.Array, p_d: jax.Array,
                      ukeys: jax.Array, ckeys: jax.Array,
                      use_residual: Optional[jax.Array] = None
                      ) -> Tuple[jax.Array, jax.Array]:
    """Per-row rejection sampling with residual fixup.

    drafts (b, k) proposed tokens; p_t (b, k+1, V) target probs; p_d
    (b, k, V) draft probs; ukeys/ckeys (b, 2) per-row keys.  Accept
    d_i w.p. min(1, p_t(d_i)/p_d(d_i)); the correction token at i<k is
    drawn from the normalized residual max(0, p_t - p_d) (degenerate
    residuals fall back to p_t — acceptance there is near-1 anyway),
    at the bonus position i==k from plain p_t.

    ``use_residual`` (b,) bool: rows set False never accept and draw
    every correction from the PLAIN target distribution — plain
    (non-speculative) slots mixed into a sampled speculative batch,
    whose emitted tokens must be ordinary target samples.

    Returns (match (b, k) bool, corr (b, k+1) int32).  Shared by the
    speculative engine and the scheduler so per-seed streams agree.
    """
    k = drafts.shape[1]
    pt_d = jnp.take_along_axis(p_t[:, :k, :], drafts[..., None],
                               axis=-1)[..., 0]
    pd_d = jnp.take_along_axis(p_d, drafts[..., None], axis=-1)[..., 0]
    u = jax.vmap(lambda kk: jax.random.uniform(kk, (k,)))(ukeys)
    match = u * jnp.maximum(pd_d, 1e-30) < pt_d
    resid = jnp.maximum(p_t[:, :k, :] - p_d, 0.0)
    denom = jnp.sum(resid, axis=-1, keepdims=True)
    resid = jnp.where(denom > 1e-30, resid / jnp.maximum(denom, 1e-30),
                      p_t[:, :k, :])
    if use_residual is not None:
        match = match & use_residual[:, None]
        resid = jnp.where(use_residual[:, None, None], resid,
                          p_t[:, :k, :])
    corr_dist = jnp.concatenate([resid, p_t[:, k:, :]], axis=1)
    corr = jax.vmap(lambda kk, pr: jax.random.categorical(
        kk, jnp.log(jnp.maximum(pr, 1e-30)), axis=-1)
    )(ckeys, corr_dist).astype(jnp.int32)
    return match, corr


class SpeculativeEngine:
    """Draft-then-verify generation over any model-zoo cache surface.

    Shares the GenerationEngine restack surface: draft and target are
    the SAME architecture with independently compressed params (each
    restacked separately — rank buckets may differ), each with its own
    cache.  Jitted prefill/round functions are cached per
    (shape, sampling, k, both-param-signatures) key.
    """

    def __init__(self, model, *, draft_model=None, max_buckets: int = 4,
                 cache_dtype: Any = jnp.float32, restacker=None,
                 draft_restacker=None):
        from repro.runtime.engine import GenerationEngine
        self.model = model
        self.draft_model = draft_model if draft_model is not None else model
        self.cache_dtype = cache_dtype
        self._restacker = restacker or GenerationEngine(
            model, max_buckets=max_buckets, cache_dtype=cache_dtype)
        if draft_restacker is not None:
            self._draft_restacker = draft_restacker
        elif self.draft_model is self.model:
            self._draft_restacker = self._restacker
        else:
            self._draft_restacker = GenerationEngine(
                self.draft_model, max_buckets=max_buckets,
                cache_dtype=cache_dtype)
        self._fns: Dict[Tuple, Any] = {}

    # ------------------------------------------------------------- build
    def _build(self, max_new: int, k: int, temperature: float, top_k: int,
               eos_id: Optional[int]):
        model, draft_model = self.model, self.draft_model
        fill = jnp.int32(eos_id if eos_id is not None else 0)

        def prefill(tparams, dparams, pf_in, tcache, dcache, b, key_p):
            tlogits, tcache = model.prefill(tparams, pf_in, tcache)
            _, dcache = draft_model.prefill(dparams, pf_in, dcache)
            lg = tlogits[:, -1, :]
            if temperature > 0.0:
                row_kp = jax.vmap(lambda i: jax.random.fold_in(key_p, i)
                                  )(jnp.arange(b))
                tok = sample_rows(lg, row_kp, temperature, top_k)[:, None]
            else:
                tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)[:, None]
            done = (jnp.zeros((b,), jnp.bool_) if eos_id is None
                    else (tok[:, 0] == eos_id))
            out = jnp.full((b, max_new), fill, jnp.int32)
            out = out.at[:, 0].set(tok[:, 0])
            n_emitted = jnp.ones((b,), jnp.int32)
            return tcache, dcache, tok, done, n_emitted, out

        def spec_round(tparams, dparams, tcache, dcache, cur, done,
                       n_emitted, out, key_r, rnd):
            b = cur.shape[0]
            pos0 = tcache["pos"]
            ar = jnp.arange(k + 1)[None, :]

            # ---- per-row round keys: row i draws from fold_in(key_r, i)
            # folded with the round counter — scheduler slots replicate
            # this derivation per request (see module docstring)
            if temperature > 0.0:
                row_keys = jax.vmap(lambda i: jax.random.fold_in(key_r, i)
                                    )(jnp.arange(b))
                dkeys, ukeys, ckeys = spec_round_keys(
                    row_keys, jnp.full((b,), rnd, jnp.int32), k)
            else:
                dkeys = jnp.zeros((k + 1, b, 2), jnp.uint32)

            # ---- draft: k proposals + one extra step that seats the
            # last proposal's cache entry (needed when all k are
            # accepted); pre-step ckpt_decode snapshots make the draft
            # cache rollbackable for SSM/ring families
            def dbody(carry, kt):
                tok, c = carry
                ck = draft_model.ckpt_decode(c)
                lg, c = draft_model.decode_step(dparams, tok, c)
                lgl = lg[:, -1, :]
                if temperature > 0.0:
                    nxt = sample_rows(lgl, kt, temperature, top_k)[:, None]
                else:
                    nxt = jnp.argmax(lgl, axis=-1).astype(jnp.int32)[:, None]
                return (nxt, c), (nxt[:, 0], lgl, ck)

            (_, dcache), (props, dlogits, dcks) = jax.lax.scan(
                dbody, (cur, dcache), dkeys)
            drafts = props[:k].T                       # (b, k): d_1..d_k

            # ---- verify: target scores [cur, d_1..d_k] in one dispatch
            vin = jnp.concatenate([cur, drafts], axis=1)       # (b, k+1)
            tlogits, tcache = model.verify_step(tparams, vin, tcache)

            if temperature == 0.0:
                tgt = jnp.argmax(tlogits, axis=-1).astype(jnp.int32)
                match = drafts == tgt[:, :k]
                acc_prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)
                a = jnp.sum(acc_prefix, axis=1)    # accepted drafts (b,)
                # emitting tgt[:, :a+1] IS "a accepted drafts + the
                # target's correction/bonus token": accepted d_i equals
                # tgt[:, i-1] by construction
                emitted = tgt
            else:
                p_t = truncated_probs(tlogits, temperature, top_k)
                p_d = truncated_probs(jnp.moveaxis(dlogits[:k], 0, 1),
                                      temperature, top_k)     # (b, k, V)
                match, corr = accept_fixup_rows(drafts, p_t, p_d,
                                                ukeys, ckeys)
                drafts_pad = jnp.pad(drafts, ((0, 0), (0, 1)))
                acc_prefix = jnp.cumprod(match.astype(jnp.int32), axis=1)
                a = jnp.sum(acc_prefix, axis=1)    # accepted drafts (b,)
                emitted = jnp.where(ar < a[:, None], drafts_pad, corr)

            # ---- emit bookkeeping: clip to budget, stop at eos
            cap = jnp.maximum(max_new - n_emitted, 0)
            emit_n = jnp.minimum(a + 1, cap)
            if eos_id is not None:
                iseos = (emitted == eos_id) & (ar < emit_n[:, None])
                has_eos = jnp.any(iseos, axis=1)
                emit_n = jnp.where(has_eos, jnp.argmax(iseos, axis=1) + 1,
                                   emit_n)
            emit_n = jnp.where(done, 0, emit_n)
            accepted = jnp.sum(jnp.minimum(a, emit_n))
            alive = jnp.sum(jnp.where(done, 0, 1))

            last = jnp.take_along_axis(
                emitted, jnp.maximum(emit_n - 1, 0)[:, None], axis=1)
            cur = jnp.where(emit_n[:, None] > 0, last, cur)
            new_done = done | (n_emitted + emit_n >= max_new)
            if eos_id is not None:
                new_done = new_done | (~done & has_eos)

            # ---- rollback: both caches keep only the accepted prefix
            # (per-cache-type contract — pos reset for positional KV,
            # checkpoint selection for SSM, saved-slot restore for ring)
            tcache = model.rollback_verify(tcache, pos0, emit_n)
            dcache = draft_model.restore_decode(dcache, dcks, pos0,
                                                emit_n)

            # ---- pack emitted tokens into the output buffer (per-row
            # offsets; rejected-suffix lanes indexed out of range are
            # dropped by the scatter)
            rows = jnp.arange(b)[:, None]
            oidx = jnp.where(ar < emit_n[:, None],
                             n_emitted[:, None] + ar, max_new)
            out = out.at[rows, oidx].set(emitted, mode="drop")
            n_emitted = n_emitted + emit_n
            return (tcache, dcache, cur, new_done, n_emitted, out,
                    accepted, alive, jnp.sum(emit_n))

        return (jax.jit(prefill, static_argnums=(5,)), jax.jit(spec_round))

    # ---------------------------------------------------------- generate
    def generate(self, target_params: Pytree, draft_params: Pytree,
                 prompts: jax.Array, max_new: int,
                 cache_len: Optional[int] = None, *, spec_k: int = 4,
                 temperature: float = 0.0, top_k: int = 0,
                 eos_id: Optional[int] = None,
                 key: Optional[jax.Array] = None,
                 prefill_inputs: Optional[Pytree] = None
                 ) -> SpeculativeResult:
        """Generate ``max_new`` tokens after ``prompts`` (b, s) int32,
        drafting ``spec_k`` tokens per round with ``draft_params``.
        ``prefill_inputs`` substitutes for ``prompts`` in both prefill
        calls for families with richer prefill batches (enc-dec
        frames)."""
        assert max_new >= 1 and spec_k >= 1
        if not hasattr(self.model, "verify_step"):
            raise ValueError("speculative decoding needs a verify_step "
                             f"surface; {type(self.model).__name__} has none")
        tparams = self._restacker.prepare_params(target_params)
        dparams = self._draft_restacker.prepare_params(draft_params)
        b, s = prompts.shape[0], prompts.shape[1]
        if cache_len is None:
            # speculation writes up to spec_k entries beyond the final
            # accepted position before rolling back
            cache_len = s + max_new + spec_k + 1
        probe = jax.eval_shape(lambda: self.model.init_cache(
            b, cache_len, dtype=self.cache_dtype))
        if isinstance(probe, dict) and "kl" in probe:
            w = self.model.cfg.sliding_window
            if spec_k + 1 > w:
                raise ValueError(
                    f"ring verify rollback needs spec_k + 1 <= window: "
                    f"spec_k {spec_k} vs window {w} — each verify step "
                    "must overwrite a distinct ring slot")
        from repro.models.linear import _PIFA_KERNEL
        if _PIFA_KERNEL:
            from repro.kernels.pifa_matmul.autotune import tune_pifa_params
            tune_pifa_params(tparams, b)
            tune_pifa_params(dparams, b)

        def psig(params):
            leaves, treedef = jax.tree_util.tree_flatten(params)
            return (treedef,
                    tuple((l.shape, str(l.dtype)) for l in leaves))

        pf_in = prompts if prefill_inputs is None else prefill_inputs
        sig = (max_new, int(spec_k), float(temperature), int(top_k), eos_id,
               b, s, cache_len, _PIFA_KERNEL, psig(tparams), psig(dparams),
               None if prefill_inputs is None else psig(prefill_inputs))
        cold = sig not in self._fns
        if cold:
            self._fns[sig] = self._build(max_new, int(spec_k),
                                         float(temperature), int(top_k),
                                         eos_id)
        prefill_fn, round_fn = self._fns[sig]
        if key is None:
            key = jax.random.PRNGKey(0)

        def one_run():
            tcache = self.model.init_cache(b, cache_len,
                                           dtype=self.cache_dtype)
            dcache = self.draft_model.init_cache(b, cache_len,
                                                 dtype=self.cache_dtype)
            key_p, key_r = jax.random.split(key)
            tcache, dcache, cur, done, n_emitted, out = prefill_fn(
                tparams, dparams, pf_in, tcache, dcache, b, key_p)
            rounds = alive_rounds = accepted = emitted = 0
            # each round emits >=1 token per alive row, so max_new
            # rounds always suffice; the loop usually exits far earlier
            for r in range(max_new):
                if bool(jnp.all(done)):
                    break
                (tcache, dcache, cur, done, n_emitted, out, acc, alive,
                 emit) = round_fn(tparams, dparams, tcache, dcache, cur,
                                  done, n_emitted, out, key_r,
                                  jnp.int32(r))
                rounds += 1
                alive_rounds += int(alive)
                accepted += int(acc)
                emitted += int(emit)
            jax.block_until_ready(out)
            return out, rounds, alive_rounds, accepted, emitted

        t0 = time.perf_counter()
        out, rounds, alive_rounds, accepted, emitted = one_run()
        dt = time.perf_counter() - t0
        compile_time = 0.0
        if cold:
            t_first = dt
            t0 = time.perf_counter()
            out, rounds, alive_rounds, accepted, emitted = one_run()
            dt = time.perf_counter() - t0
            compile_time = max(0.0, t_first - dt)

        gen = jnp.asarray(out)
        if eos_id is not None:
            n_real = int(jnp.sum(jnp.cumprod(
                (gen != eos_id).astype(jnp.int32), axis=1)))
        else:
            n_real = int(gen.size)
        tokens = jnp.concatenate([prompts, gen], axis=1)
        return SpeculativeResult(
            tokens=tokens, tokens_per_sec=n_real / max(dt, 1e-9),
            generated=n_real, compile_time=compile_time, rounds=rounds,
            alive_rounds=alive_rounds, drafted=alive_rounds * int(spec_k),
            accepted=accepted, emitted=emitted)
