"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment these hooks attach to the cluster
coordinator (GKE/Borg health service); the container is single-process,
so the components are implemented against an injectable clock + process
registry and exercised by failure-injection tests — the logic that would
run per-host at scale, minus the RPC transport.

  * HeartbeatRegistry  — hosts check in; silence > timeout marks them
    dead and triggers the configured callback (evict + restore).
  * StragglerDetector  — per-step-time EWMA; a host whose step time
    exceeds ``threshold x`` the fleet median for ``patience``
    consecutive steps is flagged (TPU stragglers are usually a
    thermally-throttled or pre-failing chip; mitigation = checkpoint,
    evict, resume on spares — see ElasticPlan in elastic.py).
  * RestartPolicy      — exponential backoff with a crash budget; the
    train loop consults it on every failure, and the serving scheduler
    reuses it for admission backpressure (a deferred request retries
    with exponential backoff until its budget exhausts -> Rejected) and
    for chunk-dispatch retries under injected faults.
  * FaultPlan          — a deterministic fault schedule for the serving
    scheduler's failure-injection tests: at chosen chunk boundaries it
    injects allocator exhaustion, dispatch exceptions, clock skew,
    cancellations, or forced preemptions.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Optional, Tuple

__all__ = ["HeartbeatRegistry", "StragglerDetector", "RestartPolicy",
           "FaultPlan", "InjectedFault", "SchedulerCrash"]


class HeartbeatRegistry:
    def __init__(self, timeout_s: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {}
        self.dead: set = set()

    def register(self, host: str) -> None:
        """Expect heartbeats from ``host`` starting now.  Without this, a
        host that dies BEFORE its first ``beat()`` is never tracked and
        never reported dead — registration opens the silence window at
        the expected-join time, so ``check()`` flags it like any other
        silent host.  A no-op for hosts that already beat."""
        self.last_seen.setdefault(host, self.clock())

    def beat(self, host: str) -> None:
        if host in self.dead:
            self.dead.discard(host)  # host came back (restart completed)
        self.last_seen[host] = self.clock()

    def check(self) -> List[str]:
        """Newly-dead hosts since last check."""
        now = self.clock()
        newly = []
        for host, seen in self.last_seen.items():
            if host not in self.dead and now - seen > self.timeout:
                self.dead.add(host)
                newly.append(host)
        return newly

    def alive(self) -> List[str]:
        return [h for h in self.last_seen if h not in self.dead]


class StragglerDetector:
    """Flags hosts consistently slower than the fleet median."""

    def __init__(self, threshold: float = 1.5, patience: int = 3,
                 ewma: float = 0.5):
        self.threshold = threshold
        self.patience = patience
        self.ewma = ewma
        self.step_time: Dict[str, float] = {}
        self.strikes: Dict[str, int] = defaultdict(int)
        self._fresh: set = set()   # hosts with a record() since last poll

    def record(self, host: str, step_seconds: float) -> None:
        prev = self.step_time.get(host)
        self.step_time[host] = (step_seconds if prev is None else
                                self.ewma * step_seconds + (1 - self.ewma) * prev)
        self._fresh.add(host)

    def stragglers(self) -> List[str]:
        if len(self.step_time) < 2:
            return []
        times = sorted(self.step_time.values())
        median = times[len(times) // 2]
        out = []
        # Strikes advance at most once per new fleet observation: a poll
        # with no record() since the last one must not burn patience
        # (polling twice per step would flag at 2x speed), and an
        # already-flagged host stays flagged without its strike count
        # drifting while no new data arrives.  A host's own EWMA need
        # not have moved — "persistently slow" means slower than the
        # fleet median as that median keeps evolving.
        fresh = bool(self._fresh)
        for host, t in self.step_time.items():
            if t > self.threshold * median:
                if fresh:
                    self.strikes[host] += 1
                if self.strikes[host] >= self.patience:
                    out.append(host)
            elif fresh:
                self.strikes[host] = 0
        self._fresh.clear()
        return out


@dataclasses.dataclass
class RestartPolicy:
    """Exponential backoff + crash budget (crash-loop protection)."""

    max_restarts: int = 10
    window_s: float = 3600.0
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.crashes: deque = deque()

    def on_failure(self) -> Optional[float]:
        """Returns backoff seconds before restarting, or None = give up."""
        now = self.clock()
        self.crashes.append(now)
        while self.crashes and now - self.crashes[0] > self.window_s:
            self.crashes.popleft()
        n = len(self.crashes)
        if n > self.max_restarts:
            return None
        return min(self.base_backoff_s * (2 ** (n - 1)), self.max_backoff_s)


class InjectedFault(RuntimeError):
    """A deliberately-injected failure (see :class:`FaultPlan`).

    Deliberately a distinct type so the scheduler's retry wrapper can
    catch exactly the failures the harness planted without masking real
    bugs behind a broad ``except``."""


class SchedulerCrash(RuntimeError):
    """Injected process death at a chunk boundary (``crash`` FaultPlan
    kind).  Unlike :class:`InjectedFault` this is NOT retried in-process:
    it propagates out of ``ServingScheduler.run()``, abandoning the
    scheduler object mid-flight exactly like a SIGKILL would, and the
    only way forward is crash recovery from the write-ahead journal and
    snapshots (``runtime/durability.py``) on a FRESH scheduler."""

    def __init__(self, step: int):
        super().__init__(f"injected crash at chunk boundary {step}")
        self.step = int(step)


class FaultPlan:
    """Deterministic fault schedule keyed by scheduler loop iteration.

    The serving scheduler consumes one batch of actions per chunk
    boundary (``take(step)`` — each action fires exactly once, so a
    boundary retried after an injected dispatch failure does not
    re-fire).  Supported kinds:

      * ``pool_exhausted`` — arm the page allocator to raise
        ``PoolExhausted`` on its next admit/extend call (mid-admission
        and mid-flight allocator failure paths);
      * ``dispatch_error`` — raise :class:`InjectedFault` at the next
        chunk dispatch, BEFORE any device buffer is donated, so a retry
        reproduces the exact same tokens;
      * ``clock_skew`` — add ``arg`` seconds to the scheduler's notion
        of now (deadline/backoff robustness under clock jumps);
      * ``cancel`` — call ``scheduler.cancel(arg)`` at that boundary;
      * ``preempt`` — force-preempt the slot running request-id ``arg``
        regardless of priority (deterministic preempt->resume
        bit-identity tests without needing real contention);
      * ``crash`` — raise :class:`SchedulerCrash` at that boundary,
        tearing down the run loop without any cleanup (simulated process
        death; exercised by the durability crash-recovery tests).

    ``step`` counts scheduler loop iterations from 0; admission for a
    step happens AFTER its actions fire, so the earliest step at which
    an admitted request can be preempted or cancelled is 1.
    """

    KINDS = ("pool_exhausted", "dispatch_error", "clock_skew", "cancel",
             "preempt", "crash")

    def __init__(self):
        self._actions: Dict[int, List[Tuple[str, Any]]] = defaultdict(list)
        self.skew = 0.0                  # accumulated clock_skew seconds
        self.fired: List[Tuple[int, str, Any]] = []

    def at(self, step: int, kind: str, arg: Any = None) -> "FaultPlan":
        if kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {kind!r}; "
                             f"one of {self.KINDS}")
        self._actions[int(step)].append((kind, arg))
        return self

    def take(self, step: int) -> List[Tuple[str, Any]]:
        """Pop and return the actions armed for ``step`` (once only)."""
        acts = self._actions.pop(int(step), [])
        self.fired.extend((int(step), k, a) for k, a in acts)
        return acts

    def pending(self) -> int:
        return sum(len(v) for v in self._actions.values())
