"""Fault-tolerance runtime: heartbeats, straggler detection, restart policy.

On a real multi-pod deployment these hooks attach to the cluster
coordinator (GKE/Borg health service); the container is single-process,
so the components are implemented against an injectable clock + process
registry and exercised by failure-injection tests — the logic that would
run per-host at scale, minus the RPC transport.

  * HeartbeatRegistry  — hosts check in; silence > timeout marks them
    dead and triggers the configured callback (evict + restore).
  * StragglerDetector  — per-step-time EWMA; a host whose step time
    exceeds ``threshold x`` the fleet median for ``patience``
    consecutive steps is flagged (TPU stragglers are usually a
    thermally-throttled or pre-failing chip; mitigation = checkpoint,
    evict, resume on spares — see ElasticPlan in elastic.py).
  * RestartPolicy      — exponential backoff with a crash budget; the
    train loop consults it on every failure.
"""
from __future__ import annotations

import dataclasses
import time
from collections import defaultdict, deque
from typing import Callable, Dict, List, Optional

__all__ = ["HeartbeatRegistry", "StragglerDetector", "RestartPolicy"]


class HeartbeatRegistry:
    def __init__(self, timeout_s: float, clock: Callable[[], float] = time.monotonic):
        self.timeout = timeout_s
        self.clock = clock
        self.last_seen: Dict[str, float] = {}
        self.dead: set = set()

    def beat(self, host: str) -> None:
        if host in self.dead:
            self.dead.discard(host)  # host came back (restart completed)
        self.last_seen[host] = self.clock()

    def check(self) -> List[str]:
        """Newly-dead hosts since last check."""
        now = self.clock()
        newly = []
        for host, seen in self.last_seen.items():
            if host not in self.dead and now - seen > self.timeout:
                self.dead.add(host)
                newly.append(host)
        return newly

    def alive(self) -> List[str]:
        return [h for h in self.last_seen if h not in self.dead]


class StragglerDetector:
    """Flags hosts consistently slower than the fleet median."""

    def __init__(self, threshold: float = 1.5, patience: int = 3,
                 ewma: float = 0.5):
        self.threshold = threshold
        self.patience = patience
        self.ewma = ewma
        self.step_time: Dict[str, float] = {}
        self.strikes: Dict[str, int] = defaultdict(int)

    def record(self, host: str, step_seconds: float) -> None:
        prev = self.step_time.get(host)
        self.step_time[host] = (step_seconds if prev is None else
                                self.ewma * step_seconds + (1 - self.ewma) * prev)

    def stragglers(self) -> List[str]:
        if len(self.step_time) < 2:
            return []
        times = sorted(self.step_time.values())
        median = times[len(times) // 2]
        out = []
        for host, t in self.step_time.items():
            if t > self.threshold * median:
                self.strikes[host] += 1
                if self.strikes[host] >= self.patience:
                    out.append(host)
            else:
                self.strikes[host] = 0
        return out


@dataclasses.dataclass
class RestartPolicy:
    """Exponential backoff + crash budget (crash-loop protection)."""

    max_restarts: int = 10
    window_s: float = 3600.0
    base_backoff_s: float = 5.0
    max_backoff_s: float = 300.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self.crashes: deque = deque()

    def on_failure(self) -> Optional[float]:
        """Returns backoff seconds before restarting, or None = give up."""
        now = self.clock()
        self.crashes.append(now)
        while self.crashes and now - self.crashes[0] > self.window_s:
            self.crashes.popleft()
        n = len(self.crashes)
        if n > self.max_restarts:
            return None
        return min(self.base_backoff_s * (2 ** (n - 1)), self.max_backoff_s)
