"""Elastic scaling plans: recompute mesh + batch split when hosts change.

The contract with the rest of the system:

  1. ``plan(available_chips)`` picks the largest supported mesh that
     fits, preferring to shrink the *data* axis (pure DP shrink keeps
     every weight shard layout identical => restore is a cheap reshard)
     and only then the pod axis;
  2. ``Checkpointer.restore`` places the old arrays against the new
     mesh's shardings (arrays are saved unsharded-per-key, so any mesh
     can consume them);
  3. the data pipeline re-splits ``global_batch`` over the new
     ``num_shards``; batches remain a pure function of (seed, step), so
     no data is skipped or repeated after the resize.

tests/test_elastic.py exercises shrink + regrow through a real
checkpoint round-trip (1-device container: meshes over placeholder
devices).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

__all__ = ["ElasticPlan", "plan_mesh"]


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    global_batch: int
    chips_used: int
    chips_idle: int


def plan_mesh(available_chips: int, *, model_parallel: int = 16,
              global_batch: int = 256, min_data: int = 1,
              pods: Optional[int] = None) -> ElasticPlan:
    """Largest (data x model) mesh under the chip budget.

    The model axis is fixed by the config (TP degree is a property of
    the model's memory footprint, not of fleet size); elasticity acts on
    data (and pod) axes.  global_batch stays constant — per-shard batch
    grows as the fleet shrinks (keeps optimization identical), until
    min_data is hit.
    """
    if pods and pods > 1:
        per_pod = available_chips // pods
        data = max(min_data, per_pod // model_parallel)
        shape: Tuple[int, ...] = (pods, data, model_parallel)
        names: Tuple[str, ...] = ("pod", "data", "model")
        used = pods * data * model_parallel
    else:
        data = max(min_data, available_chips // model_parallel)
        while data > min_data and global_batch % data != 0:
            data -= 1
        shape = (data, model_parallel)
        names = ("data", "model")
        used = data * model_parallel
    if used > available_chips:
        raise ValueError(
            f"need >= {model_parallel} chips (have {available_chips})")
    return ElasticPlan(mesh_shape=shape, axis_names=names,
                       global_batch=global_batch, chips_used=used,
                       chips_idle=available_chips - used)
