"""Logical sharding rules: param/activation PartitionSpecs per arch.

Mesh axes (launch/mesh.py): ``("data", "model")`` single-pod,
``("pod", "data", "model")`` multi-pod.  Logical mapping:

  batch          -> (pod, data)     pure DP across pods (DCN-friendly)
  vocab / heads / ff / experts / pifa-rank -> model   (TP / EP)
  weight non-TP dim -> data         (FSDP / ZeRO-3; `fsdp_axes` extends
                                     it to (data, pod) for >300B configs)
  kv-cache seq   -> data            for long_500k (batch=1: sequence/
                                     context parallelism over the cache)

GSPMD tolerates non-divisible dims (56 heads / 16-way model) by
padding, so the rules never need per-arch divisibility cases.

PIFA params (the paper's layer, DESIGN.md §5): ``wp (r, n)`` shards r on
model (its output y_p is the TP-gathered activation — r < m means PIFA
*shrinks* TP all-gather bytes by r/m vs a dense layer); ``c (m-r, r)``
shards its output rows on model; ``inv_perm`` replicates.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any

__all__ = ["ShardingRules", "param_specs", "param_shardings",
           "batch_specs", "cache_specs", "named", "leaf_spec", "constrain"]


def constrain(x, *roles):
    """Logical activation sharding constraint, mesh-aware and eager-safe.

    ``roles`` name each dim: "batch" -> (pod, data), "model" -> model,
    "data" -> data, None -> unsharded.  No-op when no named mesh is
    active (eager tests, single-device benches), so model code can
    constrain unconditionally.  GSPMD occasionally drops the batch
    sharding through reshape/scan patterns (observed in the blockwise
    attention path); these constraints pin the intended layout.
    """
    names: Tuple[str, ...] = ()
    try:  # jax.set_mesh-style context
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(getattr(mesh, "axis_names", ()) or ())
    except Exception:
        pass
    if not names:
        try:  # legacy `with mesh:` context
            from jax._src import mesh as _mesh_lib
            pm = _mesh_lib.thread_resources.env.physical_mesh
            if pm is not None and not pm.empty:
                names = tuple(pm.axis_names)
        except Exception:
            pass
    if not names:
        return x
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    spec = []
    for r in roles:
        if r == "batch" and batch_axes:
            spec.append(batch_axes if len(batch_axes) > 1 else batch_axes[0])
        elif r in names:
            spec.append(r)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Knobs the perf hillclimb iterates over."""

    data_axes: Tuple[str, ...] = ("data",)       # batch axes (+"pod" if present)
    model_axis: Optional[str] = "model"
    fsdp_axes: Tuple[str, ...] = ("data",)       # weight non-TP dim
    shard_cache_seq: bool = False                # long-context: cache seq -> data
    replicate_norms: bool = True

    def for_mesh(self, mesh: Mesh) -> "ShardingRules":
        """Add the pod axis to batch/fsdp when the mesh has one."""
        if "pod" in mesh.axis_names and "pod" not in self.data_axes:
            return dataclasses.replace(
                self,
                data_axes=("pod",) + tuple(self.data_axes),
                fsdp_axes=tuple(self.fsdp_axes),
            )
        return self


def _match(path: Tuple[str, ...], *pats: str) -> bool:
    """True if the joined path matches any /-pattern suffix (regex ok)."""
    s = "/".join(path)
    return any(re.search(p, s) for p in pats)


def leaf_spec(path: Tuple[str, ...], ndim: int, rules: ShardingRules) -> P:
    """PartitionSpec for one param leaf, by path + rank.

    Works for dense / lowrank / pifa representations and both stacked
    (leading num_layers and/or experts dims) and unstacked trees: the
    spec is derived for the *trailing* matrix dims and left-padded with
    None for any leading stacking dims.
    """
    mdl = rules.model_axis
    fsdp = tuple(a for a in rules.fsdp_axes) or None
    fsdp = fsdp if fsdp is None or len(fsdp) > 1 else fsdp[0]

    def pad(spec_tail: Tuple) -> P:
        lead = ndim - len(spec_tail)
        return P(*((None,) * lead + spec_tail))

    # ---- scalars / vectors -------------------------------------------------
    if _match(path, r"scale$", r"bias$", r"(^|/)b$", r"a_log$", r"d_skip$",
              r"dt_bias$", r"inv_perm$", r"perm$", r"count$"):
        return P(*((None,) * ndim))
    # ---- embeddings / unembedding ------------------------------------------
    if _match(path, r"embed/table$", r"lm_head/w$"):
        return pad((mdl, fsdp))                    # vocab -> model
    if _match(path, r"vision_proj/w$", r"frontend_proj/w$"):
        return pad((None, fsdp))
    # ---- router (tiny, replicated out dim) ---------------------------------
    if _match(path, r"router/w$"):
        return pad((None, None))
    # ---- conv (channels -> model) -------------------------------------------
    if _match(path, r"conv_w$"):
        return pad((mdl, None))
    if _match(path, r"conv_b$"):
        return pad((mdl,))
    # ---- PIFA factors --------------------------------------------------------
    if _match(path, r"/wp$"):
        return pad((mdl, fsdp))                    # rank -> model
    if _match(path, r"(^|/)c$") and ndim >= 2:
        return pad((mdl, None))                    # non-pivot rows -> model
    # ---- low-rank factors ----------------------------------------------------
    if _match(path, r"(^|/)u$"):
        return pad((mdl, None))
    if _match(path, r"(^|/)vt$"):
        return pad((None, fsdp))
    # ---- dense linears: TP dim by role ---------------------------------------
    if _match(path, r"attn/q/w$", r"attn/k/w$", r"attn/v/w$",
              r"xattn/[qkv]/w$"):
        return pad((mdl, fsdp))                    # heads out -> model
    if _match(path, r"attn/o/w$", r"xattn/o/w$"):
        return pad((fsdp, mdl))                    # heads in -> model
    if _match(path, r"mlp/(up|gate)/w$", r"moe/(up|gate)/w$"):
        return pad((mdl, fsdp))                    # ff out -> model
    if _match(path, r"mlp/down/w$", r"moe/down/w$"):
        return pad((fsdp, mdl))                    # ff in -> model
    if _match(path, r"in_proj/w$"):                # mamba: inner dim -> model
        return pad((mdl, fsdp))
    if _match(path, r"out_proj/w$"):
        return pad((fsdp, mdl))
    # ---- fallback: shard the largest trailing dim on fsdp --------------------
    if ndim >= 2:
        return pad((None, fsdp))
    return P(*((None,) * ndim))


def _path_str(kp) -> Tuple[str, ...]:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        else:
            out.append(str(k))
    return tuple(out)


def sanitize_spec(spec: P, shape, mesh: Optional[Mesh]) -> P:
    """Drop axes that do not evenly divide their dim (jax requires input
    shardings to tile exactly; odd vocabs like granite's 49155 fall back
    to the next dim / replication)."""
    if mesh is None:
        return spec
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(shape):
            out.append(entry)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= sizes.get(a, 1)
        out.append(entry if shape[i] % total == 0 else None)
    return P(*out)


def param_specs(tree: Pytree, rules: ShardingRules,
                mesh: Optional[Mesh] = None) -> Pytree:
    """PartitionSpec pytree matching ``tree`` (arrays or SDS leaves)."""

    def one(kp, leaf):
        shape = getattr(leaf, "shape", ())
        nd = len(shape) if shape else (leaf.ndim if hasattr(leaf, "ndim")
                                       else np.ndim(leaf))
        spec = leaf_spec(_path_str(kp), nd, rules)
        return sanitize_spec(spec, shape, mesh)

    return jax.tree_util.tree_map_with_path(one, tree)


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def param_shardings(tree: Pytree, mesh: Mesh, rules: ShardingRules) -> Pytree:
    return named(mesh, param_specs(tree, rules.for_mesh(mesh), mesh))


def batch_specs(batch_shapes: Pytree, rules: ShardingRules,
                shard_batch: bool = True) -> Pytree:
    """Token/label/frame batches: leading batch dim -> data axes.

    ``shard_batch=False`` for long-context decode (batch=1 cells): the
    data axis is spent on the cache sequence dim instead.
    """
    da = tuple(rules.data_axes)
    da = da if len(da) > 1 else da[0]

    def spec(leaf):
        nd = leaf.ndim if hasattr(leaf, "ndim") else np.ndim(leaf)
        if not shard_batch:
            return P(*((None,) * nd))
        return P(*((da,) + (None,) * (nd - 1)))

    return jax.tree.map(spec, batch_shapes)


def cache_specs(cache_shapes: Pytree, rules: ShardingRules,
                mesh: Optional[Mesh] = None) -> Pytree:
    """KV/SSM cache sharding.

    Stacked layouts (leading num_layers dim):
      k/v      (L, B, S, Hkv, hd) -> batch on data, kv-heads on model;
               when Hkv doesn't divide the model axis (GQA kv=8 on a
               16-wide axis), the cache SEQ dim takes the model axis
               instead — otherwise the cache ends up replicated across
               model and decode drags the full cache through
               collective-permutes (§Perf iteration C2);
               long-context mode shards S on data instead (batch=1).
      conv     (L, B, K-1, conv_dim) -> conv channels on model
      ssm      (L, B, H, N, P) -> ssm heads on model
      xk/xv    like k/v (encoder memory)
      pos      (B,) replicated
    """
    r = rules
    da = tuple(r.data_axes)
    da = da if len(da) > 1 else da[0]
    mdl = r.model_axis
    mdl_size = 1
    if mesh is not None and mdl in mesh.axis_names:
        mdl_size = dict(zip(mesh.axis_names, mesh.devices.shape))[mdl]

    def spec_for(kp, leaf):
        path = _path_str(kp)
        shape = getattr(leaf, "shape", ())
        nd = len(shape) if shape else (leaf.ndim if hasattr(leaf, "ndim")
                                       else np.ndim(leaf))
        name = path[-1]
        if name in ("k", "v", "xk", "xv", "kl", "vl") and nd == 5:
            if r.shard_cache_seq:
                # context parallelism: batch too small to split, shard
                # the cache sequence dim instead (long_500k)
                return P(None, None, da, mdl, None)
            heads_divide = mesh is None or shape[3] % mdl_size == 0
            seq_divides = shape[2] % mdl_size == 0
            if not heads_divide and seq_divides:
                return P(None, da, mdl, None, None)
            return P(None, da, None, mdl, None)
        if name == "conv" and nd == 4:
            if r.shard_cache_seq:
                return P(None, None, None, mdl)
            return P(None, da, None, mdl)
        if name == "ssm" and nd == 5:
            if r.shard_cache_seq:
                return P(None, None, mdl, None, None)
            return P(None, da, mdl, None, None)
        return P(*((None,) * nd))

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)
