"""Collective-traffic accounting from optimized (post-SPMD) HLO text.

``cost_analysis()`` does not expose collective bytes, so §Roofline's
collective term is derived here: we parse ``compiled.as_text()`` and sum
the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (counting ``-start`` once, skipping the
matching ``-done``).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

__all__ = ["collective_bytes", "DTYPE_BYTES", "parse_shapes"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(([^)]*)\)")
_DONE_RE = re.compile(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)-done\(")


def parse_shapes(text: str) -> int:
    """Total bytes of every dtype[shape] literal in ``text``."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """(total operand bytes, per-op-kind breakdown) of collectives."""
    per_kind: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        if _DONE_RE.search(line):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        kind, _, operands = m.groups()
        per_kind[kind] += parse_shapes(operands)
    return sum(per_kind.values()), dict(per_kind)
