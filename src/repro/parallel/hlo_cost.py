"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but every
model here scans over layers — so FLOPs/bytes/collectives would be
undercounted by ~num_layers.  XLA's text dump carries
``backend_config={"known_trip_count":{"n":...}}`` on each while, so we
rebuild the cost bottom-up:

  1. split the module into computations, build a per-module symbol
     table  (%name -> shape)  from def lines and computation headers;
  2. per-op costs:  dot FLOPs = 2 * |result| * prod(contracted lhs dims)
     (elementwise/transcendental ops: |result| FLOPs; reduces: |operand|);
     bytes = operand + result bytes at fusion *boundaries* (ops inside
     ``calls=``-referenced fusion computations move no HBM bytes);
  3. call-graph multipliers: ENTRY has multiplicity 1; a while body
     inherits  caller_mult * trip_count;  fusion/call/conditional
     callees inherit caller_mult;
  4. collectives: operand bytes (via the symbol table) * multiplicity,
     split by kind; ``-start`` counted once, ``-done`` skipped.

Validated against unrolled-vs-scanned identical modules in
tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

__all__ = ["HloCostResult", "analyze_hlo_text", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast", "ragged-all-to-all")

# 1-flop-per-element ops (matches XLA's convention closely enough; dots
# dominate every model here by >100x).
_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "exponential", "exponential-minus-one", "log", "log-plus-one", "tanh",
    "logistic", "sqrt", "rsqrt", "cbrt", "power", "sine", "cosine", "atan2",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "clamp",
    "remainder", "erf",
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
# result is either a shape literal (with optional layout suffix) or a
# (possibly one-level-nested) tuple of them — tuples never contain parens
# except nested tuples, so match balanced-to-depth-2.
_OPCODE_RE = re.compile(r"^((?:\((?:[^()]|\([^()]*\))*\)|"
                        r"[a-z][a-z0-9]*\[[0-9,]*\]\S*))\s+"
                        r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_HDR_ARG_RE = re.compile(r"([\w.\-]+)\s*:\s*([a-z][a-z0-9]*\[[0-9,]*\])")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems_first(text: str) -> Tuple[int, List[int]]:
    """(#elements, dims) of the first shape literal in ``text``."""
    m = _SHAPE_RE.search(text)
    if not m:
        return 0, []
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return n, dims


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_txt: str           # result shape text (may be a tuple)
    operands: List[str]
    attrs: str                # everything after the operand list


@dataclasses.dataclass
class _Computation:
    name: str
    is_entry: bool
    ops: List[_Op]
    header_args: Dict[str, str]


@dataclasses.dataclass
class HloCostResult:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_breakdown: Dict[str, float]
    num_whiles: int
    max_trip_count: int
    flops_by_metadata: Dict[str, float] = dataclasses.field(default_factory=dict)
    bytes_top: List[Tuple[str, float]] = dataclasses.field(default_factory=list)
    flops_top: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


def _parse_computations(text: str) -> List[_Computation]:
    comps: List[_Computation] = []
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                hdr_args = dict(_HDR_ARG_RE.findall(m.group(3)))
                cur = _Computation(name=m.group(2), is_entry=bool(m.group(1)),
                                   ops=[], header_args=hdr_args)
            continue
        if line.strip() == "}":
            comps.append(cur)
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name, rhs = dm.groups()
        om = _OPCODE_RE.match(rhs)
        if not om:
            continue
        result_txt, opcode = om.groups()
        rest = rhs[om.end():]
        # top-level operand list: up to the matching close paren
        depth = 1
        idx = 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_txt = rest[:idx]
        attrs = rest[idx + 1:]
        operands = _OPERAND_RE.findall(operand_txt)
        cur.ops.append(_Op(name=name, opcode=opcode, result_txt=result_txt,
                           operands=operands, attrs=attrs))
    return comps


def analyze_hlo_text(text: str, top_k: int = 0) -> HloCostResult:
    comps = _parse_computations(text)
    by_name = {c.name: c for c in comps}

    # ---- symbol table (module-wide; names are unique in optimized HLO) ----
    shapes: Dict[str, str] = {}
    for c in comps:
        shapes.update(c.header_args)
        for op in c.ops:
            shapes[op.name] = op.result_txt

    # ---- per-computation structure (for fusion-body classification) -------
    comp_root: Dict[str, _Op] = {}
    comp_opcodes: Dict[str, set] = {}
    for c in comps:
        comp_opcodes[c.name] = {op.opcode for op in c.ops}
        if c.ops:
            comp_root[c.name] = c.ops[-1]

    _LAYOUT_ONLY = {"parameter", "convert", "copy", "bitcast", "reshape",
                    "transpose", "broadcast", "constant",
                    "get-tuple-element", "tuple", "slice"}

    def _is_convert_fusion(comp_name: str) -> bool:
        """Fusion bodies that only convert/relayout: the CPU backend
        materializes bf16->f32 copies around dots that a TPU (native
        bf16 MXU) never emits — exclude them from the bytes metric."""
        ops = comp_opcodes.get(comp_name, set())
        return ("convert" in ops) and ops.issubset(_LAYOUT_ONLY)

    # scalar index arithmetic XLA fuses next to a dynamic-(update-)slice
    # (negative-index wrapping: compare/add/select on s32[]).  Listed
    # explicitly so a scalar-result reduce over a big operand does NOT
    # make its fusion look traffic-free.
    _INDEX_ARITH = {"compare", "add", "subtract", "multiply", "divide",
                    "remainder", "select", "clamp", "minimum", "maximum"}

    def _scalar_ops_only(comp_name: str, allowed: set) -> bool:
        """True when every op outside ``allowed``/layout is a
        scalar-valued index-arithmetic op — those move no HBM."""
        comp = by_name.get(comp_name)
        if comp is None:
            return False
        for op in comp.ops:
            if op.opcode in _LAYOUT_ONLY or op.opcode in allowed:
                continue
            if op.opcode not in _INDEX_ARITH:
                return False
            if _shape_elems_first(op.result_txt)[0] > 1:
                return False
        return True

    def _is_slice_fusion(comp_name: str) -> bool:
        """Fusion bodies of {dynamic-slice + layout/scalar-index ops}:
        per-layer weight/cache slicing out of a scan's stacked xs.  Real
        traffic is the slice, not the stacked operand (which my
        operand-counting would otherwise charge at full size, x trip
        count).  Scalar index arithmetic (the select/add wrap of
        negative scan indices) rides along for free."""
        ops = comp_opcodes.get(comp_name, set())
        return ("dynamic-slice" in ops
                and _scalar_ops_only(comp_name, {"dynamic-slice"}))

    def _dus_update_bytes(comp_name: str) -> Optional[int]:
        """If the fusion wraps a dynamic-update-slice (possibly under a
        convert/bitcast root), the real traffic is the update slice
        (in-place aliasing), not the full buffer."""
        comp = by_name.get(comp_name)
        if comp is None:
            return None
        ops = comp_opcodes.get(comp_name, set())
        if "dynamic-update-slice" not in ops or not _scalar_ops_only(
                comp_name, {"dynamic-update-slice"}):
            return None
        shp: Dict[str, str] = dict(comp.header_args)
        dus = None
        for op in comp.ops:
            shp[op.name] = op.result_txt
            if op.opcode == "dynamic-update-slice":
                dus = op
        if dus is not None and len(dus.operands) >= 2:
            return _shape_bytes(shp.get(dus.operands[1], ""))
        return None

    # ---- call-graph edges + fusion-body marking ---------------------------
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    fused_bodies = set()
    num_whiles = 0
    max_trip = 1
    for c in comps:
        for op in c.ops:
            if op.opcode == "while":
                num_whiles += 1
                tm = _TRIP_RE.search(op.attrs)
                trips = float(tm.group(1)) if tm else 1.0
                max_trip = max(max_trip, int(trips))
                bm = _BODY_RE.search(op.attrs)
                cm = _COND_RE.search(op.attrs)
                if bm:
                    edges[c.name].append((bm.group(1), trips))
                if cm:
                    edges[c.name].append((cm.group(1), trips))
            elif op.opcode == "fusion":
                fm = _CALLS_RE.search(op.attrs)
                if fm:
                    edges[c.name].append((fm.group(1), 1.0))
                    fused_bodies.add(fm.group(1))
            elif op.opcode in ("call", "async-start"):
                fm = _TO_APPLY_RE.search(op.attrs) or _CALLS_RE.search(op.attrs)
                if fm:
                    edges[c.name].append((fm.group(1), 1.0))
            elif op.opcode == "conditional":
                bm = _BRANCHES_RE.search(op.attrs)
                if bm:
                    for br in _OPERAND_RE.findall(bm.group(1)):
                        edges[c.name].append((br, 1.0))
                    for br in re.findall(r"(?<!%)\b([\w.\-]+)\b",
                                         bm.group(1)):
                        pass  # operands regex above covers %-prefixed names
            # reduce/scatter/sort to_apply reducers: negligible, skipped.

    # ---- multiplicities (Kahn topological accumulation) --------------------
    mult = _multiplicities(comps, edges)

    # ---- per-op accumulation ----------------------------------------------
    flops = 0.0
    bytes_acc = 0.0
    coll: Dict[str, float] = defaultdict(float)
    bytes_by_key: Dict[str, float] = defaultdict(float)
    flops_by_key: Dict[str, float] = defaultdict(float)

    def _key(op):
        return f"{op.opcode} {op.result_txt[:64]}"
    for c in comps:
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        in_fusion = c.name in fused_bodies
        for op in c.ops:
            res_bytes = _shape_bytes(op.result_txt)
            res_elems, res_dims = _shape_elems_first(op.result_txt)
            oc = op.opcode
            base = oc[:-6] if oc.endswith("-start") else oc
            # ---------------- flops ----------------
            if base == "dot":
                lhs_txt = shapes.get(op.operands[0], "") if op.operands else ""
                _, lhs_dims = _shape_elems_first(lhs_txt)
                k = 1
                cmx = _CONTRACT_RE.search(op.attrs)
                if cmx and lhs_dims:
                    for d in cmx.group(1).split(","):
                        if d and int(d) < len(lhs_dims):
                            k *= lhs_dims[int(d)]
                flops += m * 2.0 * res_elems * k
                flops_by_key[_key(op)] += m * 2.0 * res_elems * k
            elif base in _ELEMENTWISE:
                flops += m * res_elems
            elif base in ("reduce", "reduce-window"):
                op_elems = (_shape_elems_first(shapes.get(op.operands[0], ""))[0]
                            if op.operands else 0)
                flops += m * op_elems
            elif base == "convolution":
                # none of the models convolve (conv frontends are stubs);
                # approximate as 2 * |result| if ever present.
                flops += m * 2.0 * res_elems
            # ---------------- bytes ----------------
            # ``call`` is a control-flow boundary, not data movement: its
            # callee's ops are charged via the multiplicity edge (the CPU
            # backend wraps parallel fusions in one-op call computations,
            # which would otherwise double-charge the full buffer).
            if not in_fusion and oc not in (
                    "parameter", "constant", "get-tuple-element", "tuple",
                    "bitcast", "after-all", "call"):
                if oc == "dynamic-update-slice":
                    # in-place: read update + write the updated region
                    upd = (_shape_bytes(shapes.get(op.operands[1], ""))
                           if len(op.operands) >= 2 else res_bytes)
                    bytes_acc += m * 2 * upd
                    bytes_by_key[_key(op)] += m * 2 * upd
                elif oc == "dynamic-slice" or oc == "slice":
                    bytes_acc += m * 2 * res_bytes
                    bytes_by_key[_key(op)] += m * 2 * res_bytes
                elif oc == "fusion":
                    fm = _CALLS_RE.search(op.attrs)
                    callee = fm.group(1) if fm else ""
                    dus = _dus_update_bytes(callee)
                    if dus is not None:
                        # other (non-aliased) operands still stream in
                        others = sorted(
                            (_shape_bytes(shapes.get(o, ""))
                             for o in op.operands), reverse=True)
                        extra = sum(others[1:])  # drop the aliased buffer
                        bytes_acc += m * (2 * dus + extra)
                        bytes_by_key[_key(op)] += m * (2 * dus + extra)
                    elif _is_convert_fusion(callee):
                        pass  # CPU-only bf16<->f32 copies; TPU folds these
                    elif _is_slice_fusion(callee):
                        # per-layer slice out of stacked scan xs:
                        # read + write the slice, not the stack
                        bytes_acc += m * 2 * res_bytes
                        bytes_by_key[_key(op)] += m * 2 * res_bytes
                    else:
                        opnd_bytes = sum(_shape_bytes(shapes.get(o, ""))
                                         for o in op.operands)
                        bytes_acc += m * (opnd_bytes + res_bytes)
                        bytes_by_key[_key(op)] += m * (opnd_bytes + res_bytes)
                else:
                    opnd_bytes = sum(_shape_bytes(shapes.get(o, ""))
                                     for o in op.operands)
                    bytes_acc += m * (opnd_bytes + res_bytes)
                    bytes_by_key[_key(op)] += m * (opnd_bytes + res_bytes)
            # ---------------- collectives ----------------
            if base in COLLECTIVES and not oc.endswith("-done"):
                opnd_bytes = sum(_shape_bytes(shapes.get(o, ""))
                                 for o in op.operands)
                if opnd_bytes == 0:
                    opnd_bytes = res_bytes
                coll[base] += m * opnd_bytes

    top_b = sorted(bytes_by_key.items(), key=lambda kv: -kv[1])[:top_k]
    top_f = sorted(flops_by_key.items(), key=lambda kv: -kv[1])[:top_k]
    return HloCostResult(
        flops=flops,
        bytes_accessed=bytes_acc,
        collective_bytes=sum(coll.values()),
        collective_breakdown=dict(coll),
        num_whiles=num_whiles,
        max_trip_count=max_trip,
        bytes_top=top_b,
        flops_top=top_f,
    )


def _multiplicities(comps, edges) -> Dict[str, float]:
    """Multiplicity of each computation = sum over call paths of the
    product of trip counts (Kahn topological accumulation)."""
    indeg: Dict[str, int] = defaultdict(int)
    for caller, outs in edges.items():
        for callee, _ in outs:
            indeg[callee] += 1
    mult: Dict[str, float] = defaultdict(float)
    ready = []
    for c in comps:
        if c.is_entry:
            mult[c.name] = 1.0
        if indeg[c.name] == 0:
            ready.append(c.name)
    seen = set()
    while ready:
        name = ready.pop()
        if name in seen:
            continue
        seen.add(name)
        for callee, trips in edges.get(name, ()):  # propagate
            mult[callee] += mult[name] * trips
            indeg[callee] -= 1
            if indeg[callee] == 0:
                ready.append(callee)
    return mult
