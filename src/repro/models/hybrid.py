"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block.

Every ``cfg.attn_every`` mamba layers, a transformer block with **shared
weights** (one set of attention+MLP params reused at every invocation
point) refreshes global context — the Zamba2 recipe (arXiv:2411.15242).
Each invocation keeps its *own* KV cache (same weights, different
inputs).

Scan layout: mamba layers are reshaped to ``(n_stages, attn_every)`` and
the forward is a scan over stages (inner scan over the stage's mamba
layers, then the shared block); leftover layers (num_layers %
attn_every) run as a tail scan.  HLO stays O(1) in depth.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.mamba2 import init_mamba_block, mamba_block_apply, mamba_dims

Pytree = Any


class HybridModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        assert cfg.attn_every > 0
        self.n_stages = cfg.num_layers // cfg.attn_every
        self.n_tail = cfg.num_layers % cfg.attn_every

    # ---------------------------------------------------------------- init
    def init(self, key, dtype=jnp.float32) -> Pytree:
        cfg = self.cfg
        ke, km, ks, kh = jax.random.split(key, 4)
        mkeys = jax.random.split(km, cfg.num_layers)
        mamba = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(mkeys)
        shared = {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks, cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, cfg.resolved_head_dim,
                                     bias=cfg.use_bias, dtype=dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(kh, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                              bias=cfg.use_bias, dtype=dtype),
        }
        return {
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
            "mamba": mamba,
            "shared": shared,
            "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        }

    def _split_stages(self, mamba: Pytree):
        ns, ae = self.n_stages, self.cfg.attn_every
        main = jax.tree.map(lambda x: x[: ns * ae].reshape((ns, ae) + x.shape[1:]),
                            mamba)
        tail = jax.tree.map(lambda x: x[ns * ae:], mamba)
        return main, tail

    def _shared_apply(self, params, h, cache=None, positions=None):
        cfg = self.cfg
        sp = params["shared"]
        a_in = L.apply_norm(sp["ln1"], h, cfg.norm_eps)
        a_out, nc = L.attention_block(
            sp["attn"], a_in, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
            rope_theta=cfg.rope_theta, cache=cache, positions=positions)
        h = h + a_out
        m_in = L.apply_norm(sp["ln2"], h, cfg.norm_eps)
        return h + L.mlp_block(sp["mlp"], m_in), nc

    # ------------------------------------------------------------ forward
    def forward(self, params: Pytree, tokens: jax.Array, patches=None,
                remat: str = "none") -> jax.Array:
        cfg = self.cfg
        h = L.embed(params["embed"], tokens)
        main, tail = self._split_stages(params["mamba"])

        def mamba_body(carry, bp):
            out, _ = mamba_block_apply(bp, carry, cfg)
            return out, None

        if remat in ("full", "dots"):
            mamba_body = jax.checkpoint(mamba_body)

        def stage_body(carry, stage_params):
            out, _ = jax.lax.scan(mamba_body, carry, stage_params)
            out, _ = self._shared_apply(params, out)
            return out, None

        if self.n_stages:
            h, _ = jax.lax.scan(stage_body, h, main)
        if self.n_tail:
            h, _ = jax.lax.scan(mamba_body, h, tail)
        h = L.apply_norm(params["final_norm"], h, cfg.norm_eps)
        return L.unembed(params["embed"], h)

    def loss(self, params, tokens, labels, patches=None, remat="none"):
        logits = self.forward(params, tokens, remat=remat).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> Dict[str, jax.Array]:
        cfg = self.cfg
        d = mamba_dims(cfg)
        hd = cfg.resolved_head_dim
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1,
                               d["conv_dim"]), dtype=dtype),
            "ssm": jnp.zeros((cfg.num_layers, batch, d["nheads"],
                              d["d_state"], d["headdim"]), dtype=jnp.float32),
            "k": jnp.zeros((self.n_stages, batch, max_len, cfg.num_kv_heads, hd),
                           dtype=dtype),
            "v": jnp.zeros((self.n_stages, batch, max_len, cfg.num_kv_heads, hd),
                           dtype=dtype),
            "pos": jnp.zeros((batch,), dtype=jnp.int32),
        }

    def _step_cached(self, params, tokens, cache, last_idx=None):
        """Shared prefill/decode path over the cache (decode: sq == 1)."""
        cfg = self.cfg
        h = L.embed(params["embed"], tokens)
        ns, ae = self.n_stages, cfg.attn_every
        main, tail = self._split_stages(params["mamba"])
        main_conv, tail_conv = (jax.tree.map(lambda x: x[: ns * ae].reshape(
            (ns, ae) + x.shape[1:]), cache["conv"]),
            cache["conv"][ns * ae:])
        main_ssm = cache["ssm"][: ns * ae].reshape((ns, ae) + cache["ssm"].shape[1:])
        tail_ssm = cache["ssm"][ns * ae:]
        pos = cache["pos"]
        sq = tokens.shape[1]
        decode = sq == 1

        def mamba_step(carry, xs):
            bp, conv_c, ssm_c = xs
            if decode:
                out, nc = mamba_block_apply(bp, carry, cfg,
                                            cache={"conv": conv_c, "ssm": ssm_c})
                return out, (nc["conv"], nc["ssm"])
            # prefill: run chunked scan, recover state via block-with-cache
            # semantics (conv tail + final ssd state).
            out, st = _mamba_prefill_block(bp, carry, cfg)
            return out, st

        paged = "bt" in cache

        def stage_body(carry, xs):
            h_in = carry
            stage_p, conv_c, ssm_c, kc, vc = xs
            h_out, (nconv, nssm) = jax.lax.scan(mamba_step, h_in,
                                                (stage_p, conv_c, ssm_c))
            positions = pos[:, None] + jnp.arange(sq)[None, :]
            stage_cache = {"k": kc, "v": vc, "pos": pos}
            if paged:
                # shared-attention KV pages: decode AND native paged
                # prefill scatter through attention_block's block
                # table; conv/ssm state is constant size per slot and
                # stays contiguous by design — which is also why the
                # scheduler's prefix index never shares this family's
                # pages (the SSM state integrates the whole prompt, so
                # a mapped k/v prefix alone cannot skip prefill)
                stage_cache["bt"] = cache["bt"]
            h_out, nc = self._shared_apply(
                params, h_out, cache=stage_cache,
                positions=positions)
            return h_out, (nconv, nssm, nc["k"], nc["v"])

        if ns:
            h, (mc, ms, ks, vs) = jax.lax.scan(
                stage_body, h, (main, main_conv, main_ssm, cache["k"], cache["v"]))
            new_conv = mc.reshape((ns * ae,) + mc.shape[2:])
            new_ssm = ms.reshape((ns * ae,) + ms.shape[2:])
        else:
            ks, vs = cache["k"], cache["v"]
            new_conv = cache["conv"][:0]
            new_ssm = cache["ssm"][:0]
        if self.n_tail:
            h, (tc, ts) = jax.lax.scan(mamba_step, h, (tail, tail_conv, tail_ssm))
            new_conv = jnp.concatenate([new_conv, tc], axis=0)
            new_ssm = jnp.concatenate([new_ssm, ts], axis=0)
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "ssm": new_ssm, "k": ks, "v": vs, "pos": pos + sq}
        if paged:
            new_cache["bt"] = cache["bt"]
        h = L.apply_norm(params["final_norm"], L.take_last(h, last_idx),
                         cfg.norm_eps)
        return L.unembed(params["embed"], h), new_cache

    def prefill(self, params, tokens, cache, patches=None, last_idx=None):
        """``last_idx`` selects per-row logits positions; the SSM state
        integrates every token, so scheduler prefills for this family
        are exact-length (see runtime/scheduler.py)."""
        return self._step_cached(params, tokens, cache, last_idx=last_idx)

    def decode_step(self, params, token, cache):
        return self._step_cached(params, token, cache)

    def verify_step(self, params, tokens, cache):
        """Speculative multi-token verify: the SSM backbone integrates
        every token irreversibly, so verify runs the k+1 cached decode
        steps inside one dispatch (``L.scan_verify``) with per-step
        snapshots of the small recurrence states; the shared attention
        block's positional k/v need no snapshots (junk beyond the write
        pointer stays causally masked after the ``pos`` reset)."""
        return L.scan_verify(self, params, tokens, cache)

    def ckpt_decode(self, cache):
        """Snapshot only the irreversible leaves (conv taps + ssm
        state); the shared attention k/v rolls back positionally."""
        return {"conv": cache["conv"], "ssm": cache["ssm"]}

    def restore_decode(self, cache, cks, pos0, advance):
        cache = dict(cache)
        cache["conv"] = L.select_ckpt(cks["conv"], cache["conv"],
                                      advance, axis=1)
        cache["ssm"] = L.select_ckpt(cks["ssm"], cache["ssm"],
                                     advance, axis=1)
        cache["pos"] = pos0 + advance
        return cache

    def rollback_verify(self, cache, pos0, advance):
        return L.rollback_scan_verify(self, cache, pos0, advance)

    # ----------------------------------------------- compression harness
    def num_blocks(self) -> int:
        return self.cfg.num_layers

    def unstack_blocks(self, params: Pytree) -> Pytree:
        """Stacked mamba blocks -> list form (the shared attention block
        is a single weight set and stays as-is)."""
        if isinstance(params["mamba"], list):
            return params
        params = dict(params)
        stacked = params["mamba"]
        params["mamba"] = [jax.tree.map(lambda x, i=i: x[i], stacked)
                           for i in range(self.cfg.num_layers)]
        return params

    def restack_blocks(self, params: Pytree, *, pad: bool = False,
                       max_buckets: int = 1):
        """List form -> stacked; heterogeneous PIFA ranks re-enter the
        staged scan via exact zero-padding (single bucket — the
        (n_stages, attn_every) reshape requires one uniform stack)."""
        if not isinstance(params["mamba"], list):
            return params
        from repro.core.mpifa import pad_and_stack_blocks, try_stack_blocks
        stacked = try_stack_blocks(params["mamba"])
        if stacked is None and pad:
            stacked = pad_and_stack_blocks(params["mamba"])
        if stacked is None:
            return None
        params = dict(params)
        params["mamba"] = stacked
        return params


def _mamba_prefill_block(bp, u, cfg):
    """Mamba block over a full sequence, returning decode-ready state."""
    from repro.models.linear import apply_linear
    from repro.models.mamba2 import _causal_conv, _split_proj, _ssd_chunk_scan

    d = mamba_dims(cfg)
    h_in = L.apply_norm(bp["ln"], u, cfg.norm_eps)
    zxbcdt = apply_linear(bp["in_proj"], h_in)
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)
    xbc_conv = jax.nn.silu(_causal_conv(
        xbc, bp["conv_w"].astype(xbc.dtype), bp["conv_b"].astype(xbc.dtype)))
    x, b_mat, c_mat = jnp.split(
        xbc_conv, [d["d_inner"], d["d_inner"] + d["d_state"]], axis=-1)
    bsz, s, _ = x.shape
    x4 = x.reshape(bsz, s, d["nheads"], d["headdim"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])
    a = -jnp.exp(bp["a_log"])
    y, h_fin = _ssd_chunk_scan(x4, b_mat, c_mat, dt, dt * a, cfg.ssm_chunk)
    y = y + bp["d_skip"][None, None, :, None] * x4.astype(jnp.float32)
    y = y.reshape(bsz, s, d["d_inner"]).astype(u.dtype)
    y = L.rmsnorm(bp["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = u + apply_linear(bp["out_proj"], y)
    conv_state = xbc[:, -(cfg.ssm_conv - 1):, :]
    return out, (conv_state, h_fin)
