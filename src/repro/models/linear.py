"""The linear-layer abstraction every model in the zoo routes through.

A "linear" is a *pytree of arrays* whose key-set encodes the
representation (key sets are static under jit, so dispatch is free):

  dense    {"w": (out, in)[, "b": (out,)]}
  lowrank  {"u": (out, r), "vt": (r, in)[, "b"]}
  pifa     {"wp": (r, in), "c": (out-r, r), "inv_perm": (out,)[, "b"]}
  pifa (folded)  {"wp", "c"[, "b"]}        -- permutation folded into the
                                              consumer, no gather at all

This uniform schema is what makes the paper's technique a first-class
feature: *any* weight in *any* architecture can be swapped between
representations (by ``core/mpifa.py``) without touching model code, and
the sharding rules in ``parallel/sharding.py`` key off the same names.

Row convention everywhere: ``y = x @ W.T`` with ``x: (..., in)``.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, jax.Array]

__all__ = [
    "dense_linear",
    "lowrank_linear",
    "pifa_linear",
    "apply_linear",
    "linear_kind",
    "linear_out_dim",
    "linear_in_dim",
    "linear_param_count",
    "linear_weight",
    "set_pifa_kernel",
]

# Route PIFA layers through the fused Pallas kernel (bias + inv-perm
# gather in the epilogue, decode-shaped block selection) instead of the
# jnp two-GEMM + concat + gather chain.  Off by default: the jnp path is
# what XLA:CPU fuses best and what the TP sharding pins below target;
# flip on for TPU deployments via REPRO_PIFA_KERNEL=1 or
# set_pifa_kernel(True).
_PIFA_KERNEL = os.environ.get("REPRO_PIFA_KERNEL", "0") == "1"


def set_pifa_kernel(enabled: bool) -> bool:
    """Toggle the fused-kernel PIFA path; returns the previous value.

    The flag is read at TRACE time: functions already jit-cached keep
    the path they were traced with.  GenerationEngine keys its cache on
    the flag, so engine calls pick up a toggle; other long-lived jitted
    callables must be re-jitted after toggling.
    """
    global _PIFA_KERNEL
    prev = _PIFA_KERNEL
    _PIFA_KERNEL = bool(enabled)
    return prev


def dense_linear(key: jax.Array, in_dim: int, out_dim: int, *,
                 dtype: Any = jnp.float32, bias: bool = False,
                 scale: Optional[float] = None) -> Params:
    if scale is None:
        scale = 1.0 / np.sqrt(in_dim)
    p: Params = {"w": (jax.random.normal(key, (out_dim, in_dim)) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=dtype)
    return p


def lowrank_linear(u: Any, vt: Any, *, bias: Optional[Any] = None,
                   dtype: Any = None) -> Params:
    u = jnp.asarray(u, dtype=dtype)
    vt = jnp.asarray(vt, dtype=dtype)
    p: Params = {"u": u, "vt": vt}
    if bias is not None:
        p["b"] = jnp.asarray(bias, dtype=dtype)
    return p


def pifa_linear(factors, *, bias: Optional[Any] = None, dtype: Any = None,
                folded: bool = False) -> Params:
    """Build PIFA linear params from :class:`core.pifa.PifaFactors`."""
    p: Params = {
        "wp": jnp.asarray(factors.wp, dtype=dtype),
        "c": jnp.asarray(factors.c, dtype=dtype),
    }
    if not folded:
        p["inv_perm"] = jnp.asarray(factors.inv_perm, dtype=jnp.int32)
    if bias is not None:
        p["b"] = jnp.asarray(bias, dtype=dtype)
    return p


def linear_kind(p: Params) -> str:
    if "w" in p:
        return "dense"
    if "u" in p:
        return "lowrank"
    if "wp" in p:
        return "pifa" if "inv_perm" in p else "pifa_folded"
    raise ValueError(f"unknown linear params: {list(p)}")


def linear_out_dim(p: Params) -> int:
    k = linear_kind(p)
    if k == "dense":
        return p["w"].shape[0]
    if k == "lowrank":
        return p["u"].shape[0]
    return p["wp"].shape[0] + p["c"].shape[0]


def linear_in_dim(p: Params) -> int:
    k = linear_kind(p)
    if k == "dense":
        return p["w"].shape[1]
    if k == "lowrank":
        return p["vt"].shape[1]
    return p["wp"].shape[1]


def linear_param_count(p: Params) -> int:
    return sum(int(np.prod(v.shape)) for v in p.values())


def linear_weight(p: Params) -> jax.Array:
    """Materialize the effective dense weight (tests / compression)."""
    k = linear_kind(p)
    if k == "dense":
        return p["w"]
    if k == "lowrank":
        return p["u"] @ p["vt"]
    wcat = jnp.concatenate([p["wp"], p["c"] @ p["wp"]], axis=0)
    if k == "pifa_folded":
        return wcat
    return jnp.take(wcat, p["inv_perm"], axis=0)


def apply_linear(p: Params, x: jax.Array) -> jax.Array:
    """``y = x @ W_eff.T (+ b)`` for any representation.

    The compute cost is the paper's Section 3.3 accounting:
    dense ``2bmn``; lowrank ``2br(m+n)``; pifa ``2br(m+n-r)`` plus a
    gather (or nothing, when folded).
    """
    from repro.parallel.sharding import constrain  # cycle-free at call time

    k = linear_kind(p)
    dt = x.dtype
    if k == "dense":
        y = x @ p["w"].astype(dt).T
    elif k == "lowrank":
        t = x @ p["vt"].astype(dt).T
        t = constrain(t, *(("batch",) + (None,) * (t.ndim - 1)))
        y = t @ p["u"].astype(dt).T
    elif _PIFA_KERNEL:
        # single-dispatch fused path: both GEMMs, the output gather and
        # the bias land in one kernel (no per-call concat-then-gather)
        from repro.kernels.pifa_matmul.ops import pifa_matmul_fused
        return pifa_matmul_fused(x, p["wp"].astype(dt), p["c"].astype(dt),
                                 p.get("inv_perm"), p.get("b"))
    else:
        yp = x @ p["wp"].astype(dt).T
        # Two pins force the intended TP schedule (§Perf iteration C1/C3):
        # 1. produce y_p with its rank dim SHARDED on model (matches wp;
        #    stops GSPMD replicating the first GEMM's compute), then
        # 2. all-gather the r-sized y_p (r << m: this gather is the whole
        #    point — the alternative GSPMD picks is a partial-sum
        #    all-reduce of the (m-r)-sized second-GEMM output).
        lead = ("batch",) + (None,) * (yp.ndim - 2)
        yp = constrain(yp, *(lead + ("model",)))
        yp = constrain(yp, *(lead + (None,)))
        ynp = yp @ p["c"].astype(dt).T
        y = jnp.concatenate([yp, ynp], axis=-1)
        if k == "pifa":
            y = jnp.take(y, p["inv_perm"], axis=-1)
    if "b" in p:
        y = y + p["b"].astype(dt)
    return y
