"""Unified model API: build any assigned architecture, get its step fns.

``build_model(cfg)`` returns a model object with the common surface:

  init(key, dtype) -> params
  loss(params, inputs, labels[, remat]) -> scalar
  init_cache(batch, max_len, dtype) -> cache
  prefill(params, inputs, cache) -> (logits, cache)
  decode_step(params, token, cache) -> (logits, cache)

``batch`` layouts per family are produced by :func:`example_batch`
(eager use: tests/examples) and mirrored by ``launch/dryrun.input_specs``
(ShapeDtypeStruct stand-ins, no allocation).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.encdec import EncDecModel
from repro.models.hybrid import HybridModel
from repro.models.mamba2 import Mamba2Model
from repro.models.transformer import Transformer

Pytree = Any

__all__ = ["build_model", "example_batch", "batch_spec", "loss_fn",
           "make_train_step", "make_engine", "make_scheduler",
           "restack_for_serving"]


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return Transformer(cfg)
    if cfg.family == "ssm":
        return Mamba2Model(cfg)
    if cfg.family == "hybrid":
        return HybridModel(cfg)
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    raise ValueError(f"unknown family {cfg.family}")


def batch_spec(cfg: ModelConfig, shape: ShapeConfig,
               act_dtype=jnp.bfloat16) -> Dict[str, Tuple[tuple, Any]]:
    """(shape, dtype) descriptors for every model input of a step."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.family == "encdec":
            return {
                "frames": ((b, cfg.encoder_seq, cfg.d_model), act_dtype),
                "tokens": ((b, s), jnp.int32),
                "labels": ((b, s), jnp.int32),
            }
        if cfg.family == "vlm":
            p = cfg.num_patches
            return {
                "patches": ((b, p, cfg.d_model), act_dtype),
                "tokens": ((b, s - p), jnp.int32),
                "labels": ((b, s - p), jnp.int32),
            }
        return {"tokens": ((b, s), jnp.int32), "labels": ((b, s), jnp.int32)}
    if shape.kind == "prefill":
        out = {"tokens": ((b, s), jnp.int32)}
        if cfg.family == "encdec":
            out["frames"] = ((b, cfg.encoder_seq, cfg.d_model), act_dtype)
        if cfg.family == "vlm":
            out = {"patches": ((b, cfg.num_patches, cfg.d_model), act_dtype),
                   "tokens": ((b, s - cfg.num_patches), jnp.int32)}
        return out
    # decode: one new token against a cache of length s
    return {"token": ((b, 1), jnp.int32)}


def example_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                  act_dtype=jnp.float32) -> Dict[str, jax.Array]:
    rng = np.random.default_rng(seed)
    out = {}
    for name, (shp, dt) in batch_spec(cfg, shape, act_dtype).items():
        if dt == jnp.int32:
            out[name] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=shp), dtype=jnp.int32)
        else:
            out[name] = jnp.asarray(rng.normal(size=shp) * 0.1, dtype=dt)
    return out


def loss_fn(model, cfg: ModelConfig, params: Pytree,
            batch: Dict[str, jax.Array], remat: str = "none") -> jax.Array:
    if cfg.family == "encdec":
        return model.loss(params, {"frames": batch["frames"],
                                   "tokens": batch["tokens"]},
                          batch["labels"], remat=remat)
    if cfg.family == "vlm":
        return model.loss(params, batch["tokens"], batch["labels"],
                          patches=batch["patches"], remat=remat)
    return model.loss(params, batch["tokens"], batch["labels"], remat=remat)


def make_train_step(model, cfg: ModelConfig, optim, remat: str = "none"):
    """(params, opt_state, batch) -> (loss, params, opt_state).

    ``optim`` follows the minimal optax-like protocol of repro.optim.
    """

    def step(params, opt_state, batch):
        # allow_int: PIFA's inv_perm (int32) is a structural leaf; its
        # float0 gradient is dropped by AdamW (fine-tuning compressed
        # models trains wp/c only — paper §6: PIFA is differentiable).
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(model, cfg, p, batch, remat=remat),
            allow_int=True)(params)
        updates, opt_state = optim.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return loss, params, opt_state

    return step


def make_engine(model, **kwargs):
    """Single-dispatch generation engine for any zoo model (the scanned
    prefill+decode path; see runtime/engine.py)."""
    from repro.runtime.engine import GenerationEngine
    return GenerationEngine(model, **kwargs)


def make_scheduler(model, params, **kwargs):
    """Continuous-batching serving scheduler: slot-allocated KV cache,
    chunked scan decode, mid-flight admission (runtime/scheduler.py)."""
    from repro.runtime.scheduler import ServingScheduler
    return ServingScheduler(model, params, **kwargs)


def restack_for_serving(model, params: Pytree, *, max_buckets: int = 4
                        ) -> Pytree:
    """List-form (compressed) params -> the scanned serving form.

    Uniform blocks stack directly; heterogeneous-rank MPIFA_NS blocks
    are zero-padded to per-bucket uniform ranks (exact).  Raises
    ValueError when the blocks cannot be unified.
    """
    if not hasattr(model, "restack_blocks"):
        return params
    stacked = model.restack_blocks(params, pad=True, max_buckets=max_buckets)
    if stacked is None:
        raise ValueError("blocks cannot be re-stacked for serving")
    return stacked


def make_prefill_step(model, cfg: ModelConfig):
    def step(params, batch, cache):
        if cfg.family == "encdec":
            return model.prefill(params, {"frames": batch["frames"],
                                          "tokens": batch["tokens"]}, cache)
        if cfg.family == "vlm":
            return model.prefill(params, batch["tokens"], cache,
                                 patches=batch["patches"])
        return model.prefill(params, batch["tokens"], cache)
    return step


def make_decode_step(model, cfg: ModelConfig):
    def step(params, token, cache):
        return model.decode_step(params, token, cache)
    return step
