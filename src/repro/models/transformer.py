"""Decoder-only transformer family: dense, MoE (arctic/grok), VLM (phi-3v).

Layers are *stacked* (every block-param leaf carries a leading
``num_layers`` dim) and the forward pass is a single ``jax.lax.scan`` --
this keeps the lowered HLO size O(1) in depth, which is what makes the
512-device dry-run of 64-layer/314B-class configs compile quickly.

The class also exposes the *unscanned* per-block path used by the MPIFA
compression driver (``block_apply`` with a ``tap`` capturing every
linear's input) -- compression is offline and eager, so it does not need
the scan form.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.linear import apply_linear
from repro.parallel.sharding import constrain

Pytree = Any


@dataclasses.dataclass(frozen=True)
class LinearInfo:
    """A compressible linear inside one block: where + what."""

    path: Tuple[str, ...]   # path within the block params pytree
    kind: str               # "attn" | "mlp"
    in_dim: int
    out_dim: int


class Transformer:
    """Functional decoder-only LM; ``cfg.family`` in {dense, moe, vlm}."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- init
    def init_block(self, key, dtype=jnp.float32) -> Pytree:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        ks = jax.random.split(key, 4)
        p: Dict[str, Pytree] = {
            "ln1": L.init_rmsnorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, hd, bias=cfg.use_bias,
                                     dtype=dtype),
            "ln2": L.init_rmsnorm(cfg.d_model, dtype),
        }
        if cfg.family == "moe":
            p["moe"] = L.init_moe(ks[1], cfg.d_model, cfg.d_ff,
                                  cfg.num_experts, gated=cfg.gated_mlp,
                                  dtype=dtype)
            if cfg.moe_dense_ff:
                p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.moe_dense_ff,
                                      gated=cfg.gated_mlp, bias=cfg.use_bias,
                                      dtype=dtype)
        else:
            p["mlp"] = L.init_mlp(ks[2], cfg.d_model, cfg.d_ff,
                                  gated=cfg.gated_mlp, bias=cfg.use_bias,
                                  dtype=dtype)
        return p

    def init(self, key, dtype=jnp.float32) -> Pytree:
        cfg = self.cfg
        ke, kb, kh = jax.random.split(key, 3)
        block_keys = jax.random.split(kb, cfg.num_layers)
        blocks = jax.vmap(lambda k: self.init_block(k, dtype))(block_keys)
        params: Dict[str, Pytree] = {
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
            "blocks": blocks,
            "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = {
                "w": (jax.random.normal(kh, (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(dtype)}
        if cfg.family == "vlm":
            # stub CLIP connector: patch embeddings arrive pre-computed in a
            # frontend dim == d_model; a learned projection adapts them.
            params["vision_proj"] = {
                "w": (jax.random.normal(kh, (cfg.d_model, cfg.d_model))
                      * 0.02).astype(dtype)}
        return params

    # ------------------------------------------------------------- blocks
    def block_apply(
        self,
        bp: Pytree,
        h: jax.Array,
        *,
        window: Optional[jax.Array] = None,
        positions: Optional[jax.Array] = None,
        cache: Optional[Dict[str, jax.Array]] = None,
        window_slice: Optional[int] = None,
        per_row: bool = False,
        tap: Optional[Callable[[str, jax.Array], None]] = None,
    ) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
        cfg = self.cfg
        a_in = L.apply_norm(bp["ln1"], h, cfg.norm_eps)
        a_out, new_cache = L.attention_block(
            bp["attn"], a_in,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, rope_theta=cfg.rope_theta,
            window=window, positions=positions, cache=cache,
            window_slice=window_slice, per_row=per_row,
            tap=tap, tap_prefix="attn/")
        h = h + a_out
        m_in = L.apply_norm(bp["ln2"], h, cfg.norm_eps)
        m_out = jnp.zeros_like(h)
        if "mlp" in bp:
            m_out = m_out + L.mlp_block(bp["mlp"], m_in, tap=tap,
                                        tap_prefix="mlp/")
        if "moe" in bp:
            m_out = m_out + L.moe_block(
                bp["moe"], m_in, num_experts=cfg.num_experts,
                top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
        return h + m_out, new_cache

    def _windows(self) -> jax.Array:
        cfg = self.cfg
        return jnp.asarray(
            [cfg.window_for_layer(i) for i in range(cfg.num_layers)],
            dtype=jnp.int32)

    # ------------------------------------------------------------ forward
    def embed_tokens(self, params: Pytree, tokens: jax.Array,
                     patches: Optional[jax.Array] = None) -> jax.Array:
        h = L.embed(params["embed"], tokens)
        if self.cfg.family == "vlm" and patches is not None:
            pe = apply_linear(params["vision_proj"], patches.astype(h.dtype))
            h = jnp.concatenate([pe, h], axis=1)
        return h

    def final_logits(self, params: Pytree, h: jax.Array) -> jax.Array:
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm_eps)
        if self.cfg.tie_embeddings:
            return L.unembed(params["embed"], h)
        return apply_linear(params["lm_head"], h)

    def forward(self, params: Pytree, tokens: jax.Array,
                patches: Optional[jax.Array] = None,
                remat: str = "none") -> jax.Array:
        """Full teacher-forced forward -> logits (b, s[, +patches], vocab)."""
        h = self.embed_tokens(params, tokens, patches)
        windows = self._windows()

        def body(carry, xs):
            bp, w = xs
            out, _ = self.block_apply(bp, carry, window=w)
            return constrain(out, "batch", None, None), None

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        h, _ = jax.lax.scan(body, h, (params["blocks"], windows))
        return self.final_logits(params, h)

    def loss(self, params: Pytree, tokens: jax.Array, labels: jax.Array,
             patches: Optional[jax.Array] = None, remat: str = "none"
             ) -> jax.Array:
        logits = self.forward(params, tokens, patches, remat=remat)
        if patches is not None:
            logits = logits[:, patches.shape[1]:, :]  # loss on text positions
        logits = logits.astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ------------------------------------------------------------ serving
    def _ring_enabled(self, max_len: int) -> bool:
        cfg = self.cfg
        return bool(L.ATTN_WINDOW_SLICE and cfg.sliding_window
                    and cfg.local_global_ratio
                    and cfg.num_layers % (cfg.local_global_ratio + 1) == 0
                    and max_len > cfg.sliding_window)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> Dict[str, jax.Array]:
        """Local:global archs get RING caches for the local layers: a
        (window)-length circular buffer instead of the full context —
        at 524k context this shrinks gemma3's cache ~5x and decode
        traffic far more (§Perf iteration B2)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if self._ring_enabled(max_len):
            ratio = cfg.local_global_ratio
            ns = cfg.num_layers // (ratio + 1)
            w = cfg.sliding_window
            return {
                "k": jnp.zeros((ns, batch, max_len, cfg.num_kv_heads, hd),
                               dtype=dtype),
                "v": jnp.zeros((ns, batch, max_len, cfg.num_kv_heads, hd),
                               dtype=dtype),
                "kl": jnp.zeros((ns * ratio, batch, w, cfg.num_kv_heads, hd),
                                dtype=dtype),
                "vl": jnp.zeros((ns * ratio, batch, w, cfg.num_kv_heads, hd),
                                dtype=dtype),
                "pos": jnp.zeros((batch,), dtype=jnp.int32),
            }
        shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, hd)
        return {
            "k": jnp.zeros(shape, dtype=dtype),
            "v": jnp.zeros(shape, dtype=dtype),
            "pos": jnp.zeros((batch,), dtype=jnp.int32),
        }

    _take_last = staticmethod(L.take_last)

    def forward_cached(self, params: Pytree, tokens: jax.Array,
                       cache: Dict[str, jax.Array],
                       patches: Optional[jax.Array] = None,
                       last_idx: Optional[jax.Array] = None,
                       per_row: bool = False,
                       all_logits: bool = False
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Prefill or decode: runs `tokens` against the cache.

        For local:global archs (gemma3) at decode time the layer scan is
        *staged* — `ratio` local layers (static sliding window, cache
        reads sliced to the window) then one global layer — so a decode
        step touches O(window) bytes per local layer instead of the full
        cache (EXPERIMENTS.md §Perf, long_500k hillclimb).

        ``per_row`` scatter-writes multi-token k/v at each row's own
        ``pos`` and ``all_logits`` returns logits at every position —
        together they are the speculative multi-token verify mode
        (``verify_step``).

        A paged cache (``"bt"`` block table alongside k/v page pools —
        runtime/paging.py) takes the same layer scans: the block table
        rides through every per-layer cache dict and the scatter/gather
        addressing lives inside ``attention_block``, so paged decode,
        verify AND native paged prefill (multi-token prompt k/v
        scatter-written at ``(bt[pos // P], pos % P)``, starting at any
        ``pos`` — the shared-prefix tail path) are bit-identical to
        contiguous mode.  There is no contiguous scratch prefill
        anymore: this one path serves every cache write.
        """
        cfg = self.cfg
        h = self.embed_tokens(params, tokens, patches)
        pos = cache["pos"]
        ratio = cfg.local_global_ratio
        paged = "bt" in cache
        if "kl" in cache:  # ring caches (local:global archs)
            return self._forward_cached_ring(params, h, cache,
                                             last_idx=last_idx)
        if "block_buckets" in params:  # rank-bucketed MPIFA_NS restack
            return self._forward_cached_buckets(params, h, cache,
                                                last_idx=last_idx,
                                                per_row=per_row,
                                                all_logits=all_logits)
        # the staged sliding-window fast path slices contiguous rows;
        # paged caches use the generic scan (the window mask alone is
        # exact — slicing is only a bandwidth optimisation)
        staged = (L.ATTN_WINDOW_SLICE and cfg.sliding_window and ratio
                  and cfg.num_layers % (ratio + 1) == 0
                  and tokens.shape[1] == 1 and not paged
                  and cache["k"].shape[2] > cfg.sliding_window)

        if not staged:
            windows = self._windows()

            def body(carry, xs):
                bp, w, kc, vc = xs
                layer_cache = {"k": kc, "v": vc, "pos": pos}
                if paged:
                    layer_cache["bt"] = cache["bt"]
                out, nc = self.block_apply(bp, carry, window=w,
                                           cache=layer_cache,
                                           per_row=per_row)
                return out, (nc["k"], nc["v"])

            h, (ks, vs) = jax.lax.scan(
                body, h, (params["blocks"], windows, cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs, "pos": pos + h.shape[1]}
            if paged:
                new_cache["bt"] = cache["bt"]
            sel = h if all_logits else self._take_last(h, last_idx)
            logits = self.final_logits(params, sel)
            return logits, new_cache

        # staged local:global decode
        w_local = cfg.sliding_window
        ns = cfg.num_layers // (ratio + 1)
        stack = lambda x: x.reshape((ns, ratio + 1) + x.shape[1:])
        blocks_st = jax.tree.map(stack, params["blocks"])
        k_st, v_st = stack(cache["k"]), stack(cache["v"])

        def local_body(carry, xs):
            bp, kc, vc = xs
            out, nc = self.block_apply(
                bp, carry, window=jnp.int32(w_local),
                cache={"k": kc, "v": vc, "pos": pos}, window_slice=w_local)
            return out, (nc["k"], nc["v"])

        def stage(carry, xs):
            bp_st, kc, vc = xs
            loc = jax.tree.map(lambda x: x[:ratio], bp_st)
            glob = jax.tree.map(lambda x: x[ratio], bp_st)
            out, (ks_l, vs_l) = jax.lax.scan(
                local_body, carry, (loc, kc[:ratio], vc[:ratio]))
            out, ncg = self.block_apply(
                glob, out, window=jnp.int32(0),
                cache={"k": kc[ratio], "v": vc[ratio], "pos": pos})
            ks = jnp.concatenate([ks_l, ncg["k"][None]], axis=0)
            vs = jnp.concatenate([vs_l, ncg["v"][None]], axis=0)
            return out, (ks, vs)

        h, (ks, vs) = jax.lax.scan(stage, h, (blocks_st, k_st, v_st))
        new_cache = {
            "k": ks.reshape((cfg.num_layers,) + ks.shape[2:]),
            "v": vs.reshape((cfg.num_layers,) + vs.shape[2:]),
            "pos": pos + h.shape[1],
        }
        logits = self.final_logits(params, self._take_last(h, last_idx))
        return logits, new_cache

    def _forward_cached_buckets(self, params: Pytree, h: jax.Array,
                                cache: Dict[str, jax.Array],
                                last_idx: Optional[jax.Array] = None,
                                per_row: bool = False,
                                all_logits: bool = False
                                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Prefill/decode over rank-bucketed stacked blocks.

        Each bucket is a stacked segment of contiguous layers whose
        PIFA factors share padded ranks; one `lax.scan` per bucket,
        cache sliced by static layer offsets — still a single jit with
        O(#buckets) HLO, never the O(T^2) unstacked fallback.  Ring
        caches never reach here: ``forward_cached`` routes them to
        ``_forward_cached_ring``, which understands stage-aligned
        buckets itself.
        """
        pos = cache["pos"]
        paged = "bt" in cache
        windows = self._windows()

        def body(carry, xs):
            bp, w, kc, vc = xs
            layer_cache = {"k": kc, "v": vc, "pos": pos}
            if paged:
                layer_cache["bt"] = cache["bt"]
            out, nc = self.block_apply(bp, carry, window=w,
                                       cache=layer_cache, per_row=per_row)
            return out, (nc["k"], nc["v"])

        off = 0
        ks_parts, vs_parts = [], []
        for seg in params["block_buckets"]:
            n_seg = jax.tree_util.tree_leaves(seg)[0].shape[0]
            h, (ks, vs) = jax.lax.scan(
                body, h, (seg, windows[off:off + n_seg],
                          cache["k"][off:off + n_seg],
                          cache["v"][off:off + n_seg]))
            ks_parts.append(ks)
            vs_parts.append(vs)
            off += n_seg
        new_cache = {"k": jnp.concatenate(ks_parts, axis=0),
                     "v": jnp.concatenate(vs_parts, axis=0),
                     "pos": pos + h.shape[1]}
        if paged:
            new_cache["bt"] = cache["bt"]
        sel = h if all_logits else self._take_last(h, last_idx)
        return self.final_logits(params, sel), new_cache

    # ------------------------------------------------- ring-cache serving
    def _ring_kv(self, bp, x, positions):
        """Project+rope k/v for a local layer (ring write path)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        b, s, _ = x.shape
        k = apply_linear(bp["attn"]["k"], x).reshape(b, s, cfg.num_kv_heads,
                                                     hd)
        v = apply_linear(bp["attn"]["v"], x).reshape(b, s, cfg.num_kv_heads,
                                                     hd)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        return k, v

    def _forward_cached_ring(self, params, h, cache, last_idx=None):
        """Prefill (pos==0) or decode over ring local caches.

        Local layers keep a circular (window)-slot buffer: slot of
        absolute position p is ``p % window``; stale/garbage slots are
        masked by remapping their position to the future (causal mask
        kills them).  Per-row ``pos`` is honoured throughout (ring
        writes scatter at each row's own slot), so continuous-batching
        slot decode works on ring archs too.

        Rank-bucketed restacks (``block_buckets``) are handled by
        running the stage scan once per bucket segment; restacking
        aligns bucket boundaries to (ratio+1)-layer stages
        (`restack_blocks` passes ``granularity``), so every segment is
        a whole number of stages and cache slices stay static.
        """
        cfg = self.cfg
        ratio = cfg.local_global_ratio
        w = cfg.sliding_window
        ns = cfg.num_layers // (ratio + 1)
        pos = cache["pos"]
        b, sq, _ = h.shape
        stack_l = lambda x: x.reshape((ns, ratio) + x.shape[1:])
        kl_st, vl_st = stack_l(cache["kl"]), stack_l(cache["vl"])
        positions = pos[:, None] + jnp.arange(sq)[None, :]

        decode = sq == 1

        def local_layer(carry, xs):
            bp, kl, vl = xs  # kl/vl: (b, w, hkv, hd)
            a_in = L.apply_norm(bp["ln1"], carry, cfg.norm_eps)
            hd = cfg.resolved_head_dim
            q = apply_linear(bp["attn"]["q"], a_in).reshape(
                b, sq, cfg.num_heads, hd)
            q = L.apply_rope(q, positions, cfg.rope_theta)
            k, v = self._ring_kv(bp, a_in, positions)
            if decode:
                rows = jnp.arange(b)
                slot = jnp.mod(pos, w)                      # (b,)
                kl = kl.at[rows, slot].set(k[:, 0].astype(kl.dtype))
                vl = vl.at[rows, slot].set(v[:, 0].astype(vl.dtype))
                # absolute position held by each row's slot j:
                # p_j = pos - ((pos - j) mod w); garbage (p<0) -> future
                j = jnp.arange(w)
                kvpos = pos[:, None] - jnp.mod(pos[:, None] - j[None, :], w)
                kvpos = jnp.where(kvpos >= 0, kvpos, pos[:, None] + w + 1)
                out = L.mha(q, kl.astype(q.dtype), vl.astype(q.dtype),
                            causal=True, window=jnp.int32(w),
                            q_positions=positions,
                            kv_positions=kvpos)
            else:
                # prefill from pos==0: attend within the sequence, then
                # write the trailing window into the ring
                out = L.mha(q, k, v, causal=True, window=jnp.int32(w),
                            q_positions=positions, kv_positions=positions)
                if sq >= w:
                    s0 = sq - w
                    shift = jnp.mod(s0, w)
                    kl = jnp.roll(k[:, s0:].astype(kl.dtype), shift, axis=1)
                    vl = jnp.roll(v[:, s0:].astype(vl.dtype), shift, axis=1)
                else:
                    kl = jax.lax.dynamic_update_slice_in_dim(
                        kl, k.astype(kl.dtype), 0, axis=1)
                    vl = jax.lax.dynamic_update_slice_in_dim(
                        vl, v.astype(vl.dtype), 0, axis=1)
            out = out.reshape(b, sq, cfg.num_heads * hd)
            out = apply_linear(bp["attn"]["o"], out)
            h2 = carry + out
            m_in = L.apply_norm(bp["ln2"], h2, cfg.norm_eps)
            return h2 + L.mlp_block(bp["mlp"], m_in), (kl, vl)

        def stage(carry, xs):
            bp_st, kg, vg, kl, vl = xs
            loc = jax.tree.map(lambda x: x[:ratio], bp_st)
            glob = jax.tree.map(lambda x: x[ratio], bp_st)
            out, (nkl, nvl) = jax.lax.scan(local_layer, carry,
                                           (loc, kl, vl))
            out, ncg = self.block_apply(
                glob, out, window=jnp.int32(0),
                cache={"k": kg, "v": vg, "pos": pos}, positions=positions)
            return out, (nkl, nvl, ncg["k"], ncg["v"])

        segments = (params["block_buckets"] if "block_buckets" in params
                    else [params["blocks"]])
        so = 0  # stage offset
        kl_parts, vl_parts, kg_parts, vg_parts = [], [], [], []
        for seg in segments:
            n_seg = jax.tree_util.tree_leaves(seg)[0].shape[0]
            if n_seg % (ratio + 1) != 0:
                raise ValueError(
                    "ring-cache serving needs stage-aligned buckets: "
                    f"segment of {n_seg} layers vs stage size {ratio + 1} "
                    "(restack with granularity=local_global_ratio+1)")
            st_seg = n_seg // (ratio + 1)
            bp_st = jax.tree.map(
                lambda x: x.reshape((st_seg, ratio + 1) + x.shape[1:]), seg)
            h, (kls, vls, kgs, vgs) = jax.lax.scan(
                stage, h, (bp_st, cache["k"][so:so + st_seg],
                           cache["v"][so:so + st_seg],
                           kl_st[so:so + st_seg], vl_st[so:so + st_seg]))
            kl_parts.append(kls)
            vl_parts.append(vls)
            kg_parts.append(kgs)
            vg_parts.append(vgs)
            so += st_seg
        kls = jnp.concatenate(kl_parts, axis=0)
        vls = jnp.concatenate(vl_parts, axis=0)
        new_cache = {
            "k": jnp.concatenate(kg_parts, axis=0),
            "v": jnp.concatenate(vg_parts, axis=0),
            "kl": kls.reshape((ns * ratio,) + kls.shape[2:]),
            "vl": vls.reshape((ns * ratio,) + vls.shape[2:]),
            "pos": pos + sq,
        }
        logits = self.final_logits(params, self._take_last(h, last_idx))
        return logits, new_cache

    def prefill(self, params, tokens, cache, patches=None, last_idx=None):
        """``last_idx`` (b,) selects the per-row logits position — used
        by the serving scheduler's bucket-padded slot prefills."""
        return self.forward_cached(params, tokens, cache, patches,
                                   last_idx=last_idx)

    def decode_step(self, params, token, cache):
        """token: (b, 1) int32 -> (logits (b, 1, V), cache)."""
        return self.forward_cached(params, token, cache)

    def verify_step(self, params, tokens, cache):
        """Speculative multi-token verify: score ``tokens`` (b, k+1)
        starting at each row's OWN cache position, in one dispatch.

        Positional caches take the parallel path: k/v for all k+1
        positions are scatter-written at per-row offsets and logits are
        gathered at every position; the caller rolls back rejected
        suffixes through ``rollback_verify`` (a ``pos`` reset — junk
        beyond each row's write pointer stays causally masked until
        overwritten, the scheduler's slot-prefill exactness argument).

        Ring (local:global) caches overwrite live history in their
        circular buffers, so they verify through ``L.scan_verify``
        instead: the k+1 cached decode steps run inside this one
        dispatch, each saving the single ring entry it is about to
        overwrite (``ckpt_decode``); ``rollback_verify`` writes the
        rejected suffix's saved entries back.  Requires k+1 <= window
        (each step must hit a distinct slot).
        """
        if "kl" in cache:
            w = self.cfg.sliding_window
            if tokens.shape[1] > w:
                raise ValueError(
                    f"ring verify rollback needs k+1 <= window: "
                    f"{tokens.shape[1]} tokens vs window {w} — each "
                    "verify step must overwrite a distinct ring slot")
            return L.scan_verify(self, params, tokens, cache)
        return self.forward_cached(params, tokens, cache, per_row=True,
                                   all_logits=True)

    def ckpt_decode(self, cache):
        """Pre-step snapshot for speculative rollback: ring caches save
        the slot the next decode write will overwrite (one (hkv, hd)
        entry per local layer); positional caches need nothing."""
        if "kl" not in cache:
            return {}
        w = self.cfg.sliding_window
        return {"kl": L.ring_slot_snapshot(cache["kl"], cache["pos"], w),
                "vl": L.ring_slot_snapshot(cache["vl"], cache["pos"], w)}

    def restore_decode(self, cache, cks, pos0, advance):
        """Roll a sequence of S cached decode steps back to the first
        ``advance`` (b,): restore the rejected suffix's saved ring
        slots and reset ``pos``; positional k/v junk stays masked."""
        cache = dict(cache)
        if "kl" in cks:
            w = self.cfg.sliding_window
            cache["kl"] = L.restore_ring_slots(cache["kl"], cks["kl"],
                                               pos0, advance, w)
            cache["vl"] = L.restore_ring_slots(cache["vl"], cks["vl"],
                                               pos0, advance, w)
        cache["pos"] = pos0 + advance
        return cache

    def rollback_verify(self, cache, pos0, advance):
        """Keep only the first ``advance`` (b,) verified tokens' cache
        effects (see ``verify_step`` for the per-cache-type contract)."""
        if "ckpt" in cache:
            return L.rollback_scan_verify(self, cache, pos0, advance)
        return {**cache, "pos": pos0 + advance}

    # ----------------------------------------------- compression harness
    def num_blocks(self) -> int:
        return self.cfg.num_layers

    def block_params(self, params: Pytree, i: int) -> Pytree:
        return jax.tree.map(lambda x: x[i], params["blocks"])

    def set_block_params(self, params: Pytree, i: int, bp: Pytree) -> Pytree:
        """Replace block i.  Compressed blocks change pytree *structure*
        (dense -> lowrank/pifa), so compressed models store blocks as a
        list instead of a stacked pytree; `unstack_blocks` converts."""
        assert isinstance(params["blocks"], list), "call unstack_blocks first"
        params = dict(params)
        params["blocks"] = list(params["blocks"])
        params["blocks"][i] = bp
        return params

    def unstack_blocks(self, params: Pytree) -> Pytree:
        if "block_buckets" in params:
            params = dict(params)
            blocks: List[Pytree] = []
            for seg in params.pop("block_buckets"):
                n_seg = jax.tree_util.tree_leaves(seg)[0].shape[0]
                blocks += [jax.tree.map(lambda x, i=i: x[i], seg)
                           for i in range(n_seg)]
            params["blocks"] = blocks
            return params
        if isinstance(params["blocks"], list):
            return params
        params = dict(params)
        stacked = params["blocks"]
        params["blocks"] = [jax.tree.map(lambda x, i=i: x[i], stacked)
                            for i in range(self.cfg.num_layers)]
        return params

    def restack_blocks(self, params: Pytree, *, pad: bool = False,
                       max_buckets: int = 1) -> Optional[Pytree]:
        """Re-stack list-form blocks for the scanned serving path.

        Uniform-density MPIFA gives every block identical pytree
        structure (same PIFA ranks), so compressed models regain the
        scan + KV-cache fast path directly.  Heterogeneous blocks
        (MPIFA_NS per-layer densities) re-enter it via ``pad=True``:
        every block's PIFA/low-rank factors are zero-padded to per-path
        uniform ranks (exact — see core/mpifa.pad_pifa_rank) and, with
        ``max_buckets > 1``, the layer sequence is DP-partitioned into
        contiguous rank buckets so padding waste stays bounded; the
        result carries ``block_buckets`` (a list of stacked segments)
        instead of ``blocks``.  Returns None only when padding cannot
        unify the blocks (mixed representations at one path).
        """
        if not isinstance(params["blocks"], list):
            return params
        blocks = params["blocks"]
        from repro.core.mpifa import pad_blocks_bucketed, try_stack_blocks
        stacked_uniform = try_stack_blocks(blocks)
        if stacked_uniform is not None:
            params = dict(params)
            params["blocks"] = stacked_uniform
            return params
        if not pad:
            return None
        # ring-cache archs (local:global) scan in stages of ratio+1
        # layers, so bucket boundaries must land on stage boundaries —
        # `_forward_cached_ring` then runs one stage scan per bucket.
        granularity = 1
        cfg = self.cfg
        if (cfg.sliding_window and cfg.local_global_ratio
                and cfg.num_layers % (cfg.local_global_ratio + 1) == 0):
            granularity = cfg.local_global_ratio + 1
        buckets = pad_blocks_bucketed(blocks, max_buckets, granularity)
        if buckets is None:
            return None
        try:
            stacked = [jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *seg)
                       for seg in buckets]
        except ValueError:
            return None  # non-factor leaves disagree; cannot unify
        params = dict(params)
        if len(stacked) == 1:
            params["blocks"] = stacked[0]
        else:
            del params["blocks"]
            params["block_buckets"] = stacked
        return params

    def forward_unstacked(self, params: Pytree, tokens: jax.Array,
                          patches: Optional[jax.Array] = None) -> jax.Array:
        """Layer-by-layer forward over list-form (possibly compressed)
        blocks; used by the MPIFA pipeline and the PPL evaluator."""
        h = self.embed_tokens(params, tokens, patches)
        for i, bp in enumerate(params["blocks"]):
            w = jnp.int32(self.cfg.window_for_layer(i))
            h, _ = self.block_apply(bp, h, window=w)
        return self.final_logits(params, h)

    def linears_in_block(self) -> List[LinearInfo]:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        infos = [
            LinearInfo(("attn", "q"), "attn", cfg.d_model, cfg.num_heads * hd),
            LinearInfo(("attn", "k"), "attn", cfg.d_model, cfg.num_kv_heads * hd),
            LinearInfo(("attn", "v"), "attn", cfg.d_model, cfg.num_kv_heads * hd),
            LinearInfo(("attn", "o"), "attn", cfg.num_heads * hd, cfg.d_model),
        ]
        ff = cfg.moe_dense_ff if (cfg.family == "moe" and cfg.moe_dense_ff) else cfg.d_ff
        if cfg.family != "moe" or cfg.moe_dense_ff:
            if cfg.gated_mlp:
                infos.append(LinearInfo(("mlp", "gate"), "mlp", cfg.d_model, ff))
            infos.append(LinearInfo(("mlp", "up"), "mlp", cfg.d_model, ff))
            infos.append(LinearInfo(("mlp", "down"), "mlp", ff, cfg.d_model))
        return infos
