"""Shared building blocks for the model zoo.

Everything is functional: ``init_*`` builds a params pytree (dicts of
arrays / linear-params dicts), ``apply``-style functions consume them.
Every weight matrix flows through :mod:`repro.models.linear`, so any
module can transparently run dense / low-rank / PIFA representations --
that is how the paper's technique stays first-class across all ten
assigned architectures.

Shape conventions:
  activations  (batch, seq, d_model)
  q/k/v        (batch, seq, heads, head_dim)
  kv cache     (batch, max_len, kv_heads, head_dim)
"""
from __future__ import annotations

import math
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.linear import apply_linear, dense_linear
from repro.parallel.sharding import constrain

Pytree = Any

def take_last(h: jax.Array, last_idx: Optional[jax.Array]) -> jax.Array:
    """(b, s, d) -> (b, 1, d) at per-row ``last_idx`` (or s-1).

    Serving-scheduler slot prefills right-pad prompts to a static
    bucket, so the "last real token" differs per row; the pad tail is
    causally masked and never feeds these logits.
    """
    if last_idx is None:
        return h[:, -1:, :]
    return jnp.take_along_axis(h, last_idx[:, None, None], axis=1)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Pytree:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Pytree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype=jnp.float32) -> Pytree:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: Pytree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def apply_norm(p: Pytree, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    return layernorm(p, x, eps) if "bias" in p else rmsnorm(p, x, eps)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (b, s, h, d); positions: (b, s) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                      # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (b, s, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention (GQA + optional sliding window + KV cache)
# --------------------------------------------------------------------------

def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, *, bias: bool = False, dtype=jnp.float32
                   ) -> Pytree:
    ks = jax.random.split(key, 4)
    return {
        "q": dense_linear(ks[0], d_model, num_heads * head_dim, dtype=dtype, bias=bias),
        "k": dense_linear(ks[1], d_model, num_kv_heads * head_dim, dtype=dtype, bias=bias),
        "v": dense_linear(ks[2], d_model, num_kv_heads * head_dim, dtype=dtype, bias=bias),
        "o": dense_linear(ks[3], num_heads * head_dim, d_model, dtype=dtype, bias=bias),
    }


def _grouped_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (b, sq, h, d), k: (b, sk, hkv, d) -> (b, hkv, g, sq, sk).

    Keeps K/V un-repeated (GQA): g = h // hkv query heads share each kv
    head.  Falls back to tiling when h % hkv != 0 (never the case for
    the assigned archs).
    """
    b, sq, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    qg = q.reshape(b, sq, hkv, g, d)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k)


def _grouped_out(probs: jax.Array, v: jax.Array) -> jax.Array:
    """probs: (b, hkv, g, sq, sk), v: (b, sk, hkv, d) -> (b, sq, h, d)."""
    b, hkv, g, sq, _ = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, hkv * g, v.shape[-1])


# Chunk sizes for the blockwise (flash-style) path.  Direct attention
# materializes (b, h, sq, sk) scores — at 32k context that is terabytes;
# any real TPU deployment runs blockwise with an online softmax.  These
# are module-level knobs so the perf hillclimb can sweep them.
ATTN_Q_CHUNK = int(os.environ.get("REPRO_ATTN_Q_CHUNK", "1024"))
ATTN_KV_CHUNK = int(os.environ.get("REPRO_ATTN_KV_CHUNK", "1024"))
ATTN_DIRECT_LIMIT = 2048 * 2048  # direct path when sq*sk is at most this

# ---- perf-hillclimb flags (EXPERIMENTS.md §Perf) --------------------------
# Shard the MoE dispatch buffer's *capacity* dim over the data axis too.
# Baseline shards experts only (model axis), which replicates every
# expert's GEMMs across the 16-wide data axis — found via the roofline
# dry-run (grok/arctic useful-FLOPs ratio ~0.05).
MOE_SHARD_CAPACITY = os.environ.get("REPRO_MOE_SHARD_CAPACITY", "1") == "1"
# Decode-time sliding-window cache slicing: local-attention layers read
# only the last `window` cache entries instead of the full 524k buffer.
ATTN_WINDOW_SLICE = os.environ.get("REPRO_ATTN_WINDOW_SLICE", "1") == "1"


def _chunk_mask(qpos, kpos, kvalid, causal, window):
    """(b, cq, ck) bool mask from absolute positions."""
    delta = qpos[:, :, None] - kpos[:, None, :]
    mask = jnp.broadcast_to(kvalid[:, None, :], delta.shape)
    if causal:
        mask = mask & (delta >= 0)
    if window is not None:
        w = jnp.asarray(window)
        mask = mask & jnp.where(w > 0, delta < w, True)
    return mask


def mha(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[jax.Array] = None,
    q_positions: Optional[jax.Array] = None,
    kv_positions: Optional[jax.Array] = None,
    kv_len: Optional[jax.Array] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Grouped-query attention core.

    window: 0 / None = full; >0 = sliding window (gemma3 local layers).
    kv_len: valid cache length for decode (mask out unwritten slots).

    Dispatches to a direct path for small score matrices and to a
    blockwise online-softmax (flash-style) double-scan otherwise, so
    activation memory is O(sq * chunk) instead of O(sq * sk).
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = softmax_scale if softmax_scale is not None else 1.0 / math.sqrt(d)

    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(sk)[None, :], (b, sk))
    kvalid = (jnp.arange(sk)[None, :] < (jnp.reshape(kv_len, (-1, 1))
                                         if kv_len is not None else sk))
    kvalid = jnp.broadcast_to(kvalid, (b, sk))

    if sq * sk <= ATTN_DIRECT_LIMIT:
        mask = _chunk_mask(q_positions, kv_positions, kvalid, causal, window)
        scores = _grouped_scores(q, k).astype(jnp.float32) * scale
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1)
        # renormalize fully-masked rows to zero output (decode warmup)
        probs = jnp.where(mask[:, None, None, :, :], probs, 0.0)
        return _grouped_out(probs.astype(q.dtype), v)

    return _mha_blockwise(q, k, v, q_positions, kv_positions, kvalid,
                          causal, window, scale)


def _mha_blockwise(q, k, v, qpos, kpos, kvalid, causal, window, scale):
    """Flash-style attention: outer scan over q chunks, inner scan over
    kv chunks, carrying (running max, denominator, weighted acc)."""
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    cq = min(ATTN_Q_CHUNK, sq)
    ck = min(ATTN_KV_CHUNK, sk)
    pad_q = (-sq) % cq
    pad_k = (-sk) % ck
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad_q)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad_k)))
        kvalid = jnp.pad(kvalid, ((0, 0), (0, pad_k)))  # False padding
    nq, nk = q.shape[1] // cq, k.shape[1] // ck

    qc = jnp.moveaxis(q.reshape(b, nq, cq, hkv, g, d), 1, 0)
    qpc = jnp.moveaxis(qpos.reshape(b, nq, cq), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nk, ck, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nk, ck, hkv, d), 1, 0)
    kpc = jnp.moveaxis(kpos.reshape(b, nk, ck), 1, 0)
    kvc = jnp.moveaxis(kvalid.reshape(b, nk, ck), 1, 0)
    # pin layouts: GSPMD tends to drop batch sharding through the
    # reshape+moveaxis into the double scan (see parallel/sharding.py)
    qc = constrain(qc, None, "batch", None, "model", None, None)
    kc = constrain(kc, None, "batch", None, "model", None)
    vc = constrain(vc, None, "batch", None, "model", None)

    def q_body(_, qx):
        q_i, qp_i = qx  # (b, cq, hkv, g, d), (b, cq)

        def kv_body(carry, kx):
            m, l, acc = carry
            k_j, v_j, kp_j, kv_j = kx
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_i, k_j
                           ).astype(jnp.float32) * scale       # (b,hkv,g,cq,ck)
            s = constrain(s, "batch", "model", None, None, None)
            mask = _chunk_mask(qp_i, kp_j, kv_j, causal, window)
            mask = mask[:, None, None, :, :]
            s = jnp.where(mask, s, -jnp.inf)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            # exp of -inf rows stays 0; guard m_new == -inf (all masked)
            safe_m = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.where(mask, jnp.exp(s - safe_m[..., None]), 0.0)
            corr = jnp.where(jnp.isfinite(m), jnp.exp(m - safe_m), 0.0)
            l_new = l * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q_i.dtype), v_j)
            acc_new = acc * corr[..., None].astype(acc.dtype) + pv
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, hkv, g, cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, cq, d), q_i.dtype)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      (kc, vc, kpc, kvc))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return None, out

    _, outs = jax.lax.scan(q_body, None, (qc, qpc))   # (nq, b, hkv, g, cq, d)
    out = jnp.moveaxis(outs, 0, 1)                    # (b, nq, hkv, g, cq, d)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(b, nq * cq, h, d)
    if pad_q:
        out = out[:, :sq]
    return out.astype(q.dtype)


def attention_block(
    p: Pytree,
    x: jax.Array,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 10000.0,
    causal: bool = True,
    window: Optional[jax.Array] = None,
    positions: Optional[jax.Array] = None,
    cache: Optional[Dict[str, jax.Array]] = None,
    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
    use_rope: bool = True,
    window_slice: Optional[int] = None,
    per_row: bool = False,
    tap=None,
    tap_prefix: str = "",
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Self- or cross-attention with optional KV cache.

    cache: {"k": (b, L, hkv, d), "v": ..., "pos": (b,) int32} -- decode
    appends at ``pos`` and attends over the first ``pos+sq`` slots.
    cross_kv: precomputed (k, v) from the encoder (whisper decoder).
    per_row: multi-token cached writes scatter at each row's OWN ``pos``
    (speculative verify scores k+1 tokens from diverged per-row
    offsets) instead of the uniform ``pos[0]`` prefill slab write.

    PAGED cache (``"bt"`` present): ``k``/``v`` are page POOLS
    ``(num_pages, page_size, hkv, d)`` shared by all rows and ``bt``
    (b, n_logical) maps each row's logical page j to a physical page
    (0 = unmapped sentinel).  Writes scatter at
    ``(bt[pos // P], pos % P)``; reads gather ``pool[bt]`` back into a
    position-ordered logical view and run the UNCHANGED attention
    computation, so paged output is bit-identical to contiguous mode —
    same values, different addressing (runtime/paging.py).  Decode,
    per-row verify, and PREFILL all take the same paged write: the
    scatter index ``cache["pos"][:, None] + arange(sq)`` is already
    per-row and multi-token, so admission prefills write prompt k/v
    straight into pool pages at their final addresses — no contiguous
    scratch cache, no post-hoc page scatter.  Shared prefix pages
    (refcounted, runtime/paging.py) are never written here: the
    scheduler starts each row's tail prefill past its shared region and
    copy-on-writes the one page a full-prefix hit would touch.
    """
    b, sq, _ = x.shape
    if tap is not None:
        tap(tap_prefix + "q", x)
        if cross_kv is None:
            tap(tap_prefix + "k", x)
            tap(tap_prefix + "v", x)
    q = constrain(apply_linear(p["q"], x).reshape(b, sq, num_heads, head_dim),
                  "batch", None, "model", None)

    if cross_kv is not None:
        k, v = cross_kv
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
        out = mha(q, k, v, causal=False, q_positions=positions)
        new_cache = cache
    else:
        k = constrain(apply_linear(p["k"], x).reshape(b, sq, num_kv_heads,
                                                      head_dim),
                      "batch", None, "model", None)
        v = constrain(apply_linear(p["v"], x).reshape(b, sq, num_kv_heads,
                                                      head_dim),
                      "batch", None, "model", None)
        if positions is None:
            if cache is not None:
                positions = cache["pos"][:, None] + jnp.arange(sq)[None, :]
            else:
                positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))
        if use_rope:
            q = apply_rope(q, positions, rope_theta)
            k = apply_rope(k, positions, rope_theta)
        if cache is not None:
            if "bt" in cache:
                # paged block-table cache: scatter this step's k/v into
                # the shared page pool at (bt[pos // P], pos % P), then
                # gather the row's pages back into a position-ordered
                # logical view so the attention math below is the SAME
                # computation as contiguous mode (bit-identity argument
                # in runtime/paging.py).  Unmapped logical pages read
                # the sentinel page — junk that kv_len/causal masking
                # excludes exactly; frozen-row junk writes land there.
                # The index is per-row AND multi-token, so decode
                # (sq=1), speculative verify (per_row), and native
                # paged prefill (sq=tail length from pos=shared) are
                # one write path.
                bt = cache["bt"]
                P = cache["k"].shape[1]
                idx = cache["pos"][:, None] + jnp.arange(sq)[None, :]
                pg = jnp.take_along_axis(
                    bt, jnp.clip(idx // P, 0, bt.shape[1] - 1), axis=1)
                kp = cache["k"].at[pg, idx % P].set(
                    k.astype(cache["k"].dtype))
                vp = cache["v"].at[pg, idx % P].set(
                    v.astype(cache["v"].dtype))
                kc = jnp.take(kp, bt, axis=0).reshape(
                    (b, bt.shape[1] * P) + kp.shape[2:])
                vc = jnp.take(vp, bt, axis=0).reshape(
                    (b, bt.shape[1] * P) + vp.shape[2:])
                new_cache = {**cache, "k": kp, "v": vp,
                             "pos": cache["pos"] + sq}
            elif sq == 1 or per_row:
                # decode / speculative verify: per-row scatter at each
                # sequence's own pos — continuous-batching slots decode
                # at *different* positions (runtime/scheduler.py) and
                # verify scores k+1 tokens from diverged per-row
                # offsets (runtime/speculative.py), so the write index
                # must be per-row, not pos[0]
                rows = jnp.arange(b)[:, None]
                idx = cache["pos"][:, None] + jnp.arange(sq)[None, :]
                kc = cache["k"].at[rows, idx].set(
                    k.astype(cache["k"].dtype))
                vc = cache["v"].at[rows, idx].set(
                    v.astype(cache["v"].dtype))
                new_cache = {"k": kc, "v": vc, "pos": cache["pos"] + sq}
            else:
                # prefill: uniform pos across batch (slot prefills run
                # batch-1 from pos 0; training-free paths never mix)
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype),
                    cache["pos"][0], axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype),
                    cache["pos"][0], axis=1)
                new_cache = {"k": kc, "v": vc, "pos": cache["pos"] + sq}
            if (ATTN_WINDOW_SLICE and window_slice and sq == 1
                    and kc.shape[1] > window_slice):
                # sliding-window decode: touch only the trailing `window`
                # cache entries (hillclimb: gemma3 long_500k read the
                # full 524k buffer for its 1024-window local layers);
                # the slice start is per-row for slot-batched decode
                start = jnp.clip(cache["pos"] + sq - window_slice, 0,
                                 kc.shape[1] - window_slice)
                kw = jax.vmap(lambda kr, st: jax.lax.dynamic_slice_in_dim(
                    kr, st, window_slice, 0))(kc, start)
                vw = jax.vmap(lambda vr, st: jax.lax.dynamic_slice_in_dim(
                    vr, st, window_slice, 0))(vc, start)
                kv_positions = start[:, None] + jnp.arange(window_slice)[None, :]
                out = mha(q, kw.astype(q.dtype), vw.astype(q.dtype),
                          causal=True, window=window, q_positions=positions,
                          kv_positions=kv_positions, kv_len=new_cache["pos"])
            else:
                kv_positions = jnp.broadcast_to(
                    jnp.arange(kc.shape[1])[None, :], (b, kc.shape[1]))
                out = mha(q, kc.astype(q.dtype), vc.astype(q.dtype),
                          causal=True, window=window, q_positions=positions,
                          kv_positions=kv_positions, kv_len=new_cache["pos"])
        else:
            new_cache = None
            out = mha(q, k, v, causal=causal, window=window,
                      q_positions=positions, kv_positions=positions)

    out = out.reshape(b, sq, num_heads * head_dim)
    if tap is not None:
        tap(tap_prefix + "o", out)
    return apply_linear(p["o"], out), new_cache


# --------------------------------------------------------------------------
# MLP (gated / plain)
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, *, gated: bool = True,
             bias: bool = False, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 3)
    p = {
        "up": dense_linear(ks[0], d_model, d_ff, dtype=dtype, bias=bias),
        "down": dense_linear(ks[1], d_ff, d_model, dtype=dtype, bias=bias),
    }
    if gated:
        p["gate"] = dense_linear(ks[2], d_model, d_ff, dtype=dtype, bias=bias)
    return p


def mlp_block(p: Pytree, x: jax.Array, *, act=jax.nn.silu, tap=None,
              tap_prefix: str = "") -> jax.Array:
    if tap is not None:
        tap(tap_prefix + "up", x)
        if "gate" in p:
            tap(tap_prefix + "gate", x)
    up = apply_linear(p["up"], x)
    if "gate" in p:
        # Folding contract (core/folding.py): when `up` is pifa_folded the
        # gate emits its outputs *in up's cat order*, so the elementwise
        # product is consistent and `down` consumes cat order directly.
        h = act(apply_linear(p["gate"], x)) * up
    else:
        h = act(up)
    if tap is not None:
        tap(tap_prefix + "down", h)
    return apply_linear(p["down"], h)


# --------------------------------------------------------------------------
# Mixture of Experts (sort + capacity; experts shard on the `model` axis)
# --------------------------------------------------------------------------

def init_moe(key, d_model: int, d_ff: int, num_experts: int, *,
             gated: bool = True, dtype=jnp.float32) -> Pytree:
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d_model)
    p = {
        "router": dense_linear(ks[0], d_model, num_experts, dtype=jnp.float32),
        "up": {"w": (jax.random.normal(ks[1], (num_experts, d_ff, d_model)) * scale).astype(dtype)},
        "down": {"w": (jax.random.normal(ks[2], (num_experts, d_model, d_ff)) * (1.0 / math.sqrt(d_ff))).astype(dtype)},
    }
    if gated:
        p["gate"] = {"w": (jax.random.normal(ks[3], (num_experts, d_ff, d_model)) * scale).astype(dtype)}
    return p


def apply_expert_linear(p: Pytree, x: jax.Array) -> jax.Array:
    """Batched per-expert linear. x: (E, C, in) -> (E, C, out).

    Same representation dispatch as `apply_linear`, but with a leading
    expert dim on every factor (PIFA-per-expert).
    """
    dt = x.dtype
    if "w" in p:
        return jnp.einsum("eci,eoi->eco", x, p["w"].astype(dt))
    if "u" in p:
        t = jnp.einsum("eci,eri->ecr", x, p["vt"].astype(dt))
        return jnp.einsum("ecr,eor->eco", t, p["u"].astype(dt))
    yp = jnp.einsum("eci,eri->ecr", x, p["wp"].astype(dt))
    ynp = jnp.einsum("ecr,eor->eco", yp, p["c"].astype(dt))
    ycat = jnp.concatenate([yp, ynp], axis=-1)
    if "inv_perm" in p:
        ycat = jnp.take_along_axis(ycat, p["inv_perm"][:, None, :], axis=-1)
    return ycat


def _dp_group_count() -> int:
    """Size of the active data-parallel axes (pod*data), 1 when unmeshed.

    Used by the grouped MoE dispatch: scatters/gathers stay local to a
    data shard; only the (E, C, d) slabs cross the mesh (the EP
    all-to-all pattern).  See EXPERIMENTS.md §Perf iteration A2.
    """
    names, sizes = (), {}
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = tuple(getattr(mesh, "axis_names", ()) or ())
        if names:
            sizes = dict(zip(names, mesh.axis_sizes))
    except Exception:
        pass
    if not names:
        try:
            from jax._src import mesh as _mesh_lib
            pm = _mesh_lib.thread_resources.env.physical_mesh
            if pm is not None and not pm.empty:
                names = tuple(pm.axis_names)
                sizes = dict(zip(names, pm.devices.shape))
        except Exception:
            return 1
    g = 1
    for a in ("pod", "data"):
        g *= sizes.get(a, 1)
    return g


def moe_block(
    p: Pytree,
    x: jax.Array,
    *,
    num_experts: int,
    top_k: int = 2,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
) -> jax.Array:
    """Top-k token-choice MoE with per-expert capacity (dropped overflow).

    Sort-based dispatch, *grouped by data shard*: tokens are split into
    G = |pod|x|data| groups matching their sharding, each group sorts and
    scatters locally into its (E, C_g, d) slab — so the dispatch buffer
    is sharded (E -> model, group -> data) and the only cross-device
    traffic is the slab exchange (EP all-to-all), not a scatter
    all-reduce.  x: (..., d) -> same shape.
    """
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    groups = _dp_group_count() if MOE_SHARD_CAPACITY else 1
    if t % groups != 0:
        groups = 1
    tg = t // groups
    xg = xt.reshape(groups, tg, d)

    router_logits = apply_linear(p["router"], xg.astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)          # (G, Tg, E)
    top_p, top_i = jax.lax.top_k(probs, top_k)              # (G, Tg, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    a = tg * top_k
    flat_expert = top_i.reshape(groups, a)
    flat_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), top_k)[None], (groups, a))
    flat_w = top_p.reshape(groups, a)

    order = jnp.argsort(flat_expert, axis=1)
    s_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    s_token = jnp.take_along_axis(flat_token, order, axis=1)
    s_w = jnp.take_along_axis(flat_w, order, axis=1)

    # floor of 4 slots: grouped dispatch at decode batch sizes would
    # otherwise leave capacity=1 and drop heavily under routing variance
    capacity = max(1, min(4, tg * top_k),
                   int(math.ceil(tg * top_k / num_experts
                                 * capacity_factor)))
    csum = jnp.broadcast_to(jnp.arange(a)[None], (groups, a))
    seg_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(num_experts)))(s_expert)
    pos_in_grp = csum - jnp.take_along_axis(seg_start, s_expert, axis=1)
    keep = pos_in_grp < capacity
    slot = jnp.where(keep, s_expert * capacity + pos_in_grp,
                     num_experts * capacity)

    buf = jnp.zeros((groups, num_experts * capacity + 1, d), dtype=x.dtype)
    buf = jax.vmap(lambda b, s, xv, st: b.at[s].set(xv[st])
                   )(buf, slot, xg, s_token)
    h = buf[:, : num_experts * capacity].reshape(
        groups, num_experts, capacity, d)
    # EP x DP layout: experts on model, groups on the data axes
    h = constrain(h, "batch", "model", None, None)

    def expert_ffn(hc):
        up = apply_expert_linear(p["up"], hc)
        if "gate" in p:
            hh = act(apply_expert_linear(p["gate"], hc)) * up
        else:
            hh = act(up)
        return apply_expert_linear(p["down"], hh)

    out = jax.vmap(expert_ffn)(h)                       # (G, E, C, d)
    out = constrain(out, "batch", "model", None, None)

    out_flat = out.reshape(groups, num_experts * capacity, d)
    g_idx = jnp.clip(slot, 0, num_experts * capacity - 1)
    gathered = jax.vmap(lambda of, gi: of[gi])(out_flat, g_idx)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    y = jnp.zeros((groups, tg, d), dtype=x.dtype)
    y = jax.vmap(lambda yz, st, gv, sw: yz.at[st].add(
        gv * sw[:, None].astype(yz.dtype)))(y, s_token, gathered, s_w)
    return y.reshape(orig_shape)


# --------------------------------------------------------------------------
# Speculative verify: the cache contract shared by every family
# --------------------------------------------------------------------------
#
# ``verify_step(params, tokens, cache)`` scores ``tokens`` (b, k+1) from
# each row's own cache position in ONE dispatch and advances the cache
# by k+1; ``rollback_verify(vcache, pos0, advance)`` then keeps only the
# first ``advance`` (b,) tokens' effects.  How a family honours the
# rollback half depends on what its cache remembers:
#
#   positional KV   junk beyond the write pointer is causally masked, so
#                   resetting ``pos`` IS the rollback (transformer,
#                   encdec) — no checkpoints needed.
#   SSM state       the recurrence integrates every token irreversibly,
#                   so verify runs the k+1 cached decode steps inside one
#                   dispatch (``scan_verify``) and snapshots the SMALL
#                   per-step states (conv taps + ssm state — k+1 extra
#                   copies of O(d_inner*d_state) arrays, never the full
#                   cache); rollback selects the snapshot at ``advance``.
#   ring buffers    circular buffers overwrite live history, so each
#                   verify/draft step first saves the single slot it is
#                   about to overwrite (k+1 (hkv, hd) entries per local
#                   layer); rollback writes the saved entries back over
#                   the rejected suffix's slots.
#
# The draft side of a speculative round uses the same machinery through
# ``ckpt_decode(cache)`` (pre-step snapshot, possibly {}) and
# ``restore_decode(cache, stacked_ckpts, pos0, advance)``.

def scan_verify(model, params, tokens, cache):
    """Multi-token verify as a scan of cached decode steps.

    Used by families whose decode is inherently sequential (SSM
    recurrence) or whose cache writes destroy history (ring buffers):
    one jitted dispatch runs ``tokens.shape[1]`` decode steps, collecting
    per-step logits and the pre-step ``ckpt_decode`` snapshots.  Because
    each step IS the plain decode computation, verify logits are
    bit-identical to sequential ``decode_step`` logits by construction.

    Returns (logits (b, s, vocab), vcache) where vcache is the advanced
    cache plus a ``"ckpt"`` entry of stacked (s, ...) snapshots.
    """
    def step(c, t):
        ck = model.ckpt_decode(c)
        lg, c2 = model.decode_step(params, t, c)
        return c2, (lg[:, -1, :], ck)

    xs = jnp.moveaxis(tokens, 1, 0)[:, :, None]          # (s, b, 1)
    cache2, (lgs, cks) = jax.lax.scan(step, cache, xs)
    return jnp.moveaxis(lgs, 0, 1), {**cache2, "ckpt": cks}


def rollback_scan_verify(model, vcache, pos0, advance):
    """Rollback half of ``scan_verify``: drop the checkpoint stack and
    delegate the per-leaf state selection to ``restore_decode``."""
    cache = {k: v for k, v in vcache.items() if k != "ckpt"}
    return model.restore_decode(cache, vcache["ckpt"], pos0, advance)


def select_ckpt(stacked, current, advance, axis):
    """Pick each row's post-``advance``-steps state from a snapshot
    stack.  ``stacked`` (S, ...) holds the state BEFORE step j at index
    j (so index ``advance`` is the state after ``advance`` steps);
    ``advance == S`` keeps ``current``.  ``axis`` is the batch axis of
    ``current``; ``advance`` is (b,) int32.
    """
    S = stacked.shape[0]
    sb = jnp.moveaxis(stacked, axis + 1, 0)              # (b, S, ...)
    cb = jnp.moveaxis(current, axis, 0)                  # (b, ...)

    def pick(srow, crow, a):
        return jnp.where(a >= S, crow, srow[jnp.minimum(a, S - 1)])

    out = jax.vmap(pick)(sb, cb, advance)
    return jnp.moveaxis(out, 0, axis)


def ring_slot_snapshot(buf, pos, w):
    """Gather the ring entry the NEXT decode write will overwrite.

    buf: (L, b, w, hkv, hd) stacked ring buffers; pos (b,) write
    cursor.  Returns (L, b, hkv, hd) — the per-layer contents of slot
    ``pos % w`` for each row.
    """
    slot = jnp.mod(pos, w)
    idx = slot[None, :, None, None, None]
    return jnp.take_along_axis(buf, idx, axis=2)[:, :, 0]


def restore_ring_slots(buf, saved, pos0, advance, w):
    """Undo the rejected suffix of S sequential ring writes.

    ``saved`` (S, L, b, hkv, hd) holds the pre-write contents of slot
    ``(pos0 + j) % w`` for steps j = 0..S-1; writes j < ``advance`` (b,)
    are kept, the rest restored.  Requires S <= w (each step wrote a
    distinct slot) — callers enforce spec_k + 1 <= window.
    """
    S = saved.shape[0]
    slots = jnp.mod(pos0[:, None] + jnp.arange(S)[None, :], w)   # (b, S)
    keep = jnp.arange(S)[None, :] < advance[:, None]             # (b, S)
    sv = jnp.moveaxis(saved, 0, 2)                               # (L,b,S,..)

    def per_layer(bl, svl):
        cur = jnp.take_along_axis(bl, slots[:, :, None, None], axis=1)
        vals = jnp.where(keep[:, :, None, None], cur, svl.astype(bl.dtype))
        rows = jnp.arange(bl.shape[0])[:, None]
        return bl.at[rows, slots].set(vals)

    return jax.vmap(per_layer)(buf, sv)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32) -> Pytree:
    return {"table": (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)}


def embed(p: Pytree, tokens: jax.Array) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: Pytree, h: jax.Array) -> jax.Array:
    return h @ p["table"].T.astype(h.dtype)
