"""Mamba2 (SSD — state-space duality) blocks, chunked-scan formulation.

Recurrence (per head h, state n, channel p):

    H_t = exp(dt_t * A_h) * H_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . H_t + D_h * x_t

Training/prefill uses the SSD *chunked* algorithm: within a chunk of
length Q the quadratic (attention-like) form is used; across chunks a
``lax.scan`` carries the (b, h, n, p) state.  Chunk size is
``cfg.ssm_chunk`` (128 for the full config) — the working set per chunk
is MXU-friendly and the scan keeps HLO size O(1) in sequence length.

Decode carries {conv state (K-1 taps), ssm state}; per-token cost is
O(d_inner * d_state) regardless of context length — this is why the ssm
family *runs* the ``long_500k`` cell (DESIGN.md §4).

All projections route through `models/linear.py` and are PIFA-compressible.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.linear import apply_linear, dense_linear

Pytree = Any


def mamba_dims(cfg: ModelConfig) -> Dict[str, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_dim = d_inner + 2 * cfg.ssm_state
    return dict(
        d_inner=d_inner, nheads=nheads, conv_dim=conv_dim,
        d_state=cfg.ssm_state, headdim=cfg.ssm_headdim,
        d_in_proj=2 * d_inner + 2 * cfg.ssm_state + nheads,
    )


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.float32) -> Pytree:
    d = mamba_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "ln": L.init_rmsnorm(cfg.d_model, dtype),
        "in_proj": dense_linear(ks[0], cfg.d_model, d["d_in_proj"], dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d["conv_dim"], cfg.ssm_conv))
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(dtype),
        "conv_b": jnp.zeros((d["conv_dim"],), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, d["nheads"])).astype(jnp.float32),
        "d_skip": jnp.ones((d["nheads"],), dtype=jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((d["nheads"],), 1e-2))).astype(jnp.float32),
        "gate_norm": L.init_rmsnorm(d["d_inner"], dtype),
        "out_proj": dense_linear(ks[2], d["d_inner"], cfg.d_model, dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x: (b, s, c), w: (c, k)."""
    k = w.shape[1]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # window sum: sum_j w[:, j] * x[t - (k-1) + j]
    out = jnp.zeros_like(x)
    for j in range(k):
        out = out + xp[:, j:j + x.shape[1], :] * w[:, j][None, None, :]
    return out + b[None, None, :]


def _ssd_chunk_scan(x, b_mat, c_mat, dt, da, chunk: int,
                    h0: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. x: (b,s,h,p); b_mat/c_mat: (b,s,n); dt/da: (b,s,h).

    Returns (y: (b,s,h,p), final_state: (b,h,n,p)).  fp32 internally.
    """
    bsz, s, nh, hp = x.shape
    n = b_mat.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk

    def resh(t, extra):
        return t.reshape((bsz, nc, chunk) + extra).swapaxes(0, 1)

    xc = resh(x, (nh, hp))
    bc = resh(b_mat, (n,))
    cc = resh(c_mat, (n,))
    dtc = resh(dt, (nh,))
    dac = resh(da, (nh,))

    if h0 is None:
        h0 = jnp.zeros((bsz, nh, n, hp), dtype=jnp.float32)

    idx = jnp.arange(chunk)
    tri = idx[:, None] >= idx[None, :]

    def body(h, inp):
        x_c, b_c, c_c, dt_c, da_c = inp
        ca = jnp.cumsum(da_c, axis=1)                           # (b,Q,h)
        # intra-chunk (quadratic) term
        cb = jnp.einsum("bin,bjn->bij", c_c, b_c)               # (b,Q,Q)
        lmat = jnp.exp(ca[:, :, None, :] - ca[:, None, :, :])   # (b,i,j,h)
        scores = cb[..., None] * jnp.where(tri[None, :, :, None], lmat, 0.0)
        scores = scores * dt_c[:, None, :, :]
        y = jnp.einsum("bijh,bjhp->bihp", scores, x_c)
        # inter-chunk: carry-in state
        y = y + jnp.einsum("bin,bhnp->bihp", c_c, h) * jnp.exp(ca)[..., None]
        # state update
        decay_end = jnp.exp(ca[:, -1:, :] - ca) * dt_c          # (b,Q,h)
        s_c = jnp.einsum("bjh,bjn,bjhp->bhnp", decay_end, b_c, x_c)
        h_new = jnp.exp(ca[:, -1, :])[:, :, None, None] * h + s_c
        return h_new, y

    h_fin, yc = jax.lax.scan(body, h0, (xc.astype(jnp.float32),
                                        bc.astype(jnp.float32),
                                        cc.astype(jnp.float32),
                                        dtc.astype(jnp.float32),
                                        dac.astype(jnp.float32)))
    y = yc.swapaxes(0, 1).reshape(bsz, nc * chunk, nh, hp)
    if pad:
        y = y[:, :s]
    return y, h_fin


def _split_proj(zxbcdt: jax.Array, cfg: ModelConfig):
    d = mamba_dims(cfg)
    z, xbc, dt = jnp.split(
        zxbcdt, [d["d_inner"], d["d_inner"] + d["conv_dim"]], axis=-1)
    return z, xbc, dt


def mamba_block_apply(
    p: Pytree,
    u: jax.Array,
    cfg: ModelConfig,
    *,
    cache: Optional[Dict[str, jax.Array]] = None,
    tap=None,
    tap_prefix: str = "",
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """One pre-norm Mamba2 block: u -> u + mamba(norm(u)).

    cache (decode): {"conv": (b, K-1, conv_dim), "ssm": (b, h, n, p)}.
    """
    d = mamba_dims(cfg)
    h_in = L.apply_norm(p["ln"], u, cfg.norm_eps)
    if tap is not None:
        tap(tap_prefix + "in_proj", h_in)
    zxbcdt = apply_linear(p["in_proj"], h_in)
    z, xbc, dt_raw = _split_proj(zxbcdt, cfg)

    new_cache = None
    if cache is None:
        xbc = _causal_conv(xbc, p["conv_w"].astype(xbc.dtype),
                           p["conv_b"].astype(xbc.dtype))
    else:
        # roll the conv window: state holds the previous K-1 inputs
        window = jnp.concatenate([cache["conv"].astype(xbc.dtype), xbc], axis=1)
        k = cfg.ssm_conv
        xbc = (jnp.einsum("bkc,ck->bc", window[:, -k:, :],
                          p["conv_w"].astype(xbc.dtype))
               + p["conv_b"].astype(xbc.dtype))[:, None, :]
        new_conv = window[:, -(k - 1):, :]
    xbc = jax.nn.silu(xbc)

    x, b_mat, c_mat = jnp.split(
        xbc, [d["d_inner"], d["d_inner"] + d["d_state"]], axis=-1)
    bsz, s, _ = x.shape
    x = x.reshape(bsz, s, d["nheads"], d["headdim"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])          # (b,s,h)
    a = -jnp.exp(p["a_log"])                                     # (h,)
    da = dt * a[None, None, :]

    if cache is None:
        y, _ = _ssd_chunk_scan(x, b_mat, c_mat, dt, da, cfg.ssm_chunk)
    else:
        # single-token recurrence
        hst = cache["ssm"].astype(jnp.float32)                   # (b,h,n,p)
        xt = x[:, 0].astype(jnp.float32)                         # (b,h,p)
        bt = b_mat[:, 0].astype(jnp.float32)                     # (b,n)
        ct = c_mat[:, 0].astype(jnp.float32)
        dtt = dt[:, 0]                                           # (b,h)
        hst = (jnp.exp(da[:, 0])[:, :, None, None] * hst
               + jnp.einsum("bh,bn,bhp->bhnp", dtt, bt, xt))
        y = jnp.einsum("bn,bhnp->bhp", ct, hst)[:, None]         # (b,1,h,p)
        new_cache = {"conv": new_conv, "ssm": hst}

    y = y + p["d_skip"][None, None, :, None] * x.astype(jnp.float32)
    y = y.reshape(bsz, s, d["d_inner"]).astype(u.dtype)
    y = L.rmsnorm(p["gate_norm"], y * jax.nn.silu(z), cfg.norm_eps)
    if tap is not None:
        tap(tap_prefix + "out_proj", y)
    return u + apply_linear(p["out_proj"], y), new_cache


class Mamba2Model:
    """Attention-free LM: embed -> N mamba blocks -> norm -> unembed."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, key, dtype=jnp.float32) -> Pytree:
        cfg = self.cfg
        ke, kb = jax.random.split(key)
        bkeys = jax.random.split(kb, cfg.num_layers)
        blocks = jax.vmap(lambda k: init_mamba_block(k, cfg, dtype))(bkeys)
        return {
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
            "blocks": blocks,
            "final_norm": L.init_rmsnorm(cfg.d_model, dtype),
        }

    def forward(self, params: Pytree, tokens: jax.Array,
                patches=None, remat: str = "none") -> jax.Array:
        h = L.embed(params["embed"], tokens)

        def body(carry, bp):
            out, _ = mamba_block_apply(bp, carry, self.cfg)
            return out, None

        if remat == "full":
            body = jax.checkpoint(body)
        elif remat == "dots":
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        h, _ = jax.lax.scan(body, h, params["blocks"])
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm_eps)
        return L.unembed(params["embed"], h)

    def loss(self, params, tokens, labels, patches=None, remat="none"):
        logits = self.forward(params, tokens, remat=remat).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> Dict[str, jax.Array]:
        cfg = self.cfg
        d = mamba_dims(cfg)
        lyr = cfg.num_layers
        return {
            "conv": jnp.zeros((lyr, batch, cfg.ssm_conv - 1, d["conv_dim"]), dtype=dtype),
            "ssm": jnp.zeros((lyr, batch, d["nheads"], d["d_state"], d["headdim"]),
                             dtype=jnp.float32),
            "pos": jnp.zeros((batch,), dtype=jnp.int32),
        }

    def prefill(self, params, tokens, cache, patches=None, last_idx=None):
        """Run the chunked scan then *materialize* the decode state.

        Prefill state extraction reuses the chunk scan's final state.
        ``last_idx`` selects per-row logits positions; note the SSM
        state integrates every input token, so scheduler prefills for
        this family must be exact-length (no right padding) — the
        scheduler's exact prompt mode handles that.
        """
        h = L.embed(params["embed"], tokens)
        convs, ssms = [], []

        def body(carry, bp):
            u = carry
            d = mamba_dims(self.cfg)
            h_in = L.apply_norm(bp["ln"], u, self.cfg.norm_eps)
            zxbcdt = apply_linear(bp["in_proj"], h_in)
            z, xbc, dt_raw = _split_proj(zxbcdt, self.cfg)
            xbc_conv = jax.nn.silu(_causal_conv(
                xbc, bp["conv_w"].astype(xbc.dtype), bp["conv_b"].astype(xbc.dtype)))
            x, b_mat, c_mat = jnp.split(
                xbc_conv, [d["d_inner"], d["d_inner"] + d["d_state"]], axis=-1)
            bsz, s, _ = x.shape
            x4 = x.reshape(bsz, s, d["nheads"], d["headdim"])
            dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])
            a = -jnp.exp(bp["a_log"])
            y, h_fin = _ssd_chunk_scan(x4, b_mat, c_mat, dt, dt * a,
                                       self.cfg.ssm_chunk)
            y = y + bp["d_skip"][None, None, :, None] * x4.astype(jnp.float32)
            y = y.reshape(bsz, s, d["d_inner"]).astype(u.dtype)
            y = L.rmsnorm(bp["gate_norm"], y * jax.nn.silu(z), self.cfg.norm_eps)
            out = u + apply_linear(bp["out_proj"], y)
            conv_state = xbc[:, -(self.cfg.ssm_conv - 1):, :]
            return out, (conv_state, h_fin)

        h, (convs, ssms) = jax.lax.scan(body, h, params["blocks"])
        # pass through any extra cache entries (e.g. a scheduler-side
        # block table): this family's state is constant size per slot,
        # so the paged KV cache is a no-op for it by design — and the
        # scheduler's prefix index never shares its pages either (the
        # SSM state integrates the whole prompt; there is no positional
        # k/v prefix a later request could map instead of prefilling)
        new_cache = {**cache, "conv": convs.astype(cache["conv"].dtype),
                     "ssm": ssms,
                     "pos": cache["pos"] + tokens.shape[1]}
        h = L.apply_norm(params["final_norm"], L.take_last(h, last_idx),
                         self.cfg.norm_eps)
        return L.unembed(params["embed"], h), new_cache

    # ----------------------------------------------- compression harness
    def num_blocks(self) -> int:
        return self.cfg.num_layers

    def unstack_blocks(self, params: Pytree) -> Pytree:
        """Stacked blocks -> list form (per-block compression edits)."""
        if isinstance(params["blocks"], list):
            return params
        params = dict(params)
        stacked = params["blocks"]
        params["blocks"] = [jax.tree.map(lambda x, i=i: x[i], stacked)
                            for i in range(self.cfg.num_layers)]
        return params

    def restack_blocks(self, params: Pytree, *, pad: bool = False,
                       max_buckets: int = 1) -> Optional[Pytree]:
        """List form -> stacked scan form; heterogeneous-rank PIFA
        blocks (MPIFA_NS) re-enter via exact zero-padding when
        ``pad=True`` (core/mpifa.pad_and_stack_blocks).  The SSM decode
        scan consumes one stacked segment, so this family always pads
        to a single bucket."""
        if not isinstance(params["blocks"], list):
            return params
        from repro.core.mpifa import pad_and_stack_blocks, try_stack_blocks
        stacked = try_stack_blocks(params["blocks"])
        if stacked is None and pad:
            stacked = pad_and_stack_blocks(params["blocks"])
        if stacked is None:
            return None
        params = dict(params)
        params["blocks"] = stacked
        return params

    def verify_step(self, params, tokens, cache):
        """Speculative multi-token verify for the SSM family.

        The recurrence integrates every token irreversibly, so the
        rollback contract is honoured by CHECKPOINTING instead of
        masking: ``L.scan_verify`` runs the k+1 cached decode steps
        inside one dispatch, snapshotting the small per-step decode
        states (conv taps + ssm state — k+1 copies of
        O(d_inner * d_state) per layer, never a full cache copy);
        ``rollback_verify`` selects each row's state at the last
        accepted position.  Logits are bit-identical to sequential
        ``decode_step`` logits by construction.
        """
        return L.scan_verify(self, params, tokens, cache)

    def ckpt_decode(self, cache):
        """Pre-step snapshot of the leaves a decode step overwrites
        irreversibly: the conv window taps and the ssm state."""
        return {"conv": cache["conv"], "ssm": cache["ssm"]}

    def restore_decode(self, cache, cks, pos0, advance):
        """Roll S cached decode steps back to the first ``advance``
        (b,): select each row's snapshot (stack index j = state before
        step j; ``advance == S`` keeps the current state)."""
        cache = dict(cache)
        cache["conv"] = L.select_ckpt(cks["conv"], cache["conv"],
                                      advance, axis=1)
        cache["ssm"] = L.select_ckpt(cks["ssm"], cache["ssm"],
                                     advance, axis=1)
        cache["pos"] = pos0 + advance
        return cache

    def rollback_verify(self, cache, pos0, advance):
        return L.rollback_scan_verify(self, cache, pos0, advance)

    def decode_step(self, params, token, cache):
        h = L.embed(params["embed"], token)

        def body(carry, xs):
            bp, conv_c, ssm_c = xs
            out, nc = mamba_block_apply(
                bp, carry, self.cfg,
                cache={"conv": conv_c, "ssm": ssm_c})
            return out, (nc["conv"], nc["ssm"])

        h, (convs, ssms) = jax.lax.scan(
            body, h, (params["blocks"], cache["conv"], cache["ssm"]))
        new_cache = {**cache, "conv": convs.astype(cache["conv"].dtype),
                     "ssm": ssms, "pos": cache["pos"] + 1}
        h = L.apply_norm(params["final_norm"], h, self.cfg.norm_eps)
        return L.unembed(params["embed"], h), new_cache
