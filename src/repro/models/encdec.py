"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
feeds precomputed frame embeddings ``(batch, encoder_seq, d_model)``
directly into the encoder (a learned input projection stands in for the
conv stack).  Positional information is sinusoidal (whisper uses
fixed sinusoids for the encoder, learned for the decoder; we use
sinusoids for both -- irrelevant to systems behaviour).

Encoder blocks: bidirectional self-attn + MLP (LayerNorm, biases, gelu).
Decoder blocks: causal self-attn + cross-attn over encoder memory + MLP.
Decode caches: self-attn KV per decoder layer + precomputed cross KV.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.linear import apply_linear, dense_linear

Pytree = Any


def _sinusoid(seq: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    dim = jnp.arange(0, d, 2)[None, :].astype(jnp.float32)
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe[:, :d].astype(dtype)


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def _init_block(self, key, cross: bool, dtype) -> Pytree:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        ks = jax.random.split(key, 5)
        p = {
            "ln1": L.init_layernorm(cfg.d_model, dtype),
            "attn": L.init_attention(ks[0], cfg.d_model, cfg.num_heads,
                                     cfg.num_kv_heads, hd, bias=cfg.use_bias,
                                     dtype=dtype),
            "ln2": L.init_layernorm(cfg.d_model, dtype),
            "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp,
                              bias=cfg.use_bias, dtype=dtype),
        }
        if cross:
            p["ln_x"] = L.init_layernorm(cfg.d_model, dtype)
            p["xattn"] = L.init_attention(ks[2], cfg.d_model, cfg.num_heads,
                                          cfg.num_kv_heads, hd,
                                          bias=cfg.use_bias, dtype=dtype)
        return p

    def init(self, key, dtype=jnp.float32) -> Pytree:
        cfg = self.cfg
        ke, kf, kenc, kdec = jax.random.split(key, 4)
        enc_keys = jax.random.split(kenc, cfg.encoder_layers)
        dec_keys = jax.random.split(kdec, cfg.num_layers)
        return {
            "frontend_proj": dense_linear(kf, cfg.d_model, cfg.d_model,
                                          dtype=dtype, bias=True),
            "embed": L.init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype),
            "enc_blocks": jax.vmap(
                lambda k: self._init_block(k, False, dtype))(enc_keys),
            "dec_blocks": jax.vmap(
                lambda k: self._init_block(k, True, dtype))(dec_keys),
            "enc_norm": L.init_layernorm(cfg.d_model, dtype),
            "dec_norm": L.init_layernorm(cfg.d_model, dtype),
        }

    # ------------------------------------------------------------ encoder
    def encode(self, params: Pytree, frames: jax.Array) -> jax.Array:
        """frames: (b, enc_seq, d_model) precomputed embeddings (stub)."""
        cfg = self.cfg
        h = apply_linear(params["frontend_proj"], frames)
        h = h + _sinusoid(h.shape[1], cfg.d_model, h.dtype)[None]

        def body(carry, bp):
            a_in = L.apply_norm(bp["ln1"], carry, cfg.norm_eps)
            a_out, _ = L.attention_block(
                bp["attn"], a_in, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                causal=False, use_rope=False)
            h2 = carry + a_out
            m_in = L.apply_norm(bp["ln2"], h2, cfg.norm_eps)
            return h2 + L.mlp_block(bp["mlp"], m_in, act=jax.nn.gelu), None

        h, _ = jax.lax.scan(body, h, params["enc_blocks"])
        return L.apply_norm(params["enc_norm"], h, cfg.norm_eps)

    # ------------------------------------------------------------ decoder
    def _dec_block(self, bp, h, memory=None, cross_kv=None, cache=None,
                   positions=None, per_row=False):
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        a_in = L.apply_norm(bp["ln1"], h, cfg.norm_eps)
        a_out, nc = L.attention_block(
            bp["attn"], a_in, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd, causal=True,
            use_rope=False, cache=cache, positions=positions,
            per_row=per_row)
        h = h + a_out
        x_in = L.apply_norm(bp["ln_x"], h, cfg.norm_eps)
        if cross_kv is None:
            b, sk = memory.shape[0], memory.shape[1]
            k = apply_linear(bp["xattn"]["k"], memory).reshape(
                b, sk, cfg.num_kv_heads, hd)
            v = apply_linear(bp["xattn"]["v"], memory).reshape(
                b, sk, cfg.num_kv_heads, hd)
            cross_kv = (k, v)
        x_out, _ = L.attention_block(
            bp["xattn"], x_in, num_heads=cfg.num_heads,
            num_kv_heads=cfg.num_kv_heads, head_dim=hd,
            cross_kv=cross_kv, use_rope=False, positions=positions)
        h = h + x_out
        m_in = L.apply_norm(bp["ln2"], h, cfg.norm_eps)
        return h + L.mlp_block(bp["mlp"], m_in, act=jax.nn.gelu), nc

    def decode_train(self, params, tokens, memory):
        cfg = self.cfg
        h = L.embed(params["embed"], tokens)
        h = h + _sinusoid(h.shape[1], cfg.d_model, h.dtype)[None]

        def body(carry, bp):
            out, _ = self._dec_block(bp, carry, memory=memory)
            return out, None

        h, _ = jax.lax.scan(body, h, params["dec_blocks"])
        h = L.apply_norm(params["dec_norm"], h, cfg.norm_eps)
        return L.unembed(params["embed"], h)

    def forward(self, params, batch_or_tokens, patches=None, remat="none"):
        """batch: {"frames": (b, enc_seq, d), "tokens": (b, s)}."""
        batch = batch_or_tokens
        memory = self.encode(params, batch["frames"])
        return self.decode_train(params, batch["tokens"], memory)

    def loss(self, params, batch, labels, patches=None, remat="none"):
        logits = self.forward(params, batch).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    # ------------------------------------------------------------ serving
    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16
                   ) -> Dict[str, jax.Array]:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        lyr = cfg.num_layers
        return {
            "k": jnp.zeros((lyr, batch, max_len, cfg.num_kv_heads, hd), dtype=dtype),
            "v": jnp.zeros((lyr, batch, max_len, cfg.num_kv_heads, hd), dtype=dtype),
            "xk": jnp.zeros((lyr, batch, cfg.encoder_seq, cfg.num_kv_heads, hd),
                            dtype=dtype),
            "xv": jnp.zeros((lyr, batch, cfg.encoder_seq, cfg.num_kv_heads, hd),
                            dtype=dtype),
            "pos": jnp.zeros((batch,), dtype=jnp.int32),
        }

    def prefill(self, params, batch, cache, patches=None, last_idx=None):
        """Encode audio, precompute cross-KV, then run prompt tokens.

        ``last_idx`` (b,) selects per-row logits positions for
        bucket-padded slot prefills (serving scheduler)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        memory = self.encode(params, batch["frames"])
        b, sk = memory.shape[0], memory.shape[1]

        def xkv(bp):
            k = apply_linear(bp["xattn"]["k"], memory).reshape(
                b, sk, cfg.num_kv_heads, hd)
            v = apply_linear(bp["xattn"]["v"], memory).reshape(
                b, sk, cfg.num_kv_heads, hd)
            return k, v

        def kv_body(carry, bp):
            k, v = xkv(bp)
            return carry, (k, v)

        _, (xk, xv) = jax.lax.scan(kv_body, 0, params["dec_blocks"])
        cache = dict(cache)
        cache["xk"], cache["xv"] = (xk.astype(cache["xk"].dtype),
                                    xv.astype(cache["xv"].dtype))
        return self._decode_cached(params, batch["tokens"], cache,
                                   last_idx=last_idx)

    def decode_step(self, params, token, cache):
        return self._decode_cached(params, token, cache)

    def verify_step(self, params, tokens, cache):
        """Speculative multi-token verify: the decoder self-attention
        cache is purely positional (cross-KV is static memory), so
        rejected suffixes roll back by resetting ``pos`` exactly as in
        the decoder-only transformer."""
        return self._decode_cached(params, tokens, cache, per_row=True,
                                   all_logits=True)

    def ckpt_decode(self, cache):
        """Positional cache: decode steps need no rollback snapshots."""
        return {}

    def restore_decode(self, cache, cks, pos0, advance):
        """Rollback is a ``pos`` reset — junk beyond each row's write
        pointer stays causally masked until overwritten."""
        return {**cache, "pos": pos0 + advance}

    def rollback_verify(self, cache, pos0, advance):
        return {**cache, "pos": pos0 + advance}

    # ----------------------------------------------- compression harness
    def num_blocks(self) -> int:
        return self.cfg.num_layers

    def unstack_blocks(self, params: Pytree) -> Pytree:
        """Stacked encoder/decoder blocks -> list form."""
        params = dict(params)
        for key, n in (("enc_blocks", self.cfg.encoder_layers),
                       ("dec_blocks", self.cfg.num_layers)):
            if not isinstance(params[key], list):
                stacked = params[key]
                params[key] = [jax.tree.map(lambda x, i=i: x[i], stacked)
                               for i in range(n)]
        return params

    def restack_blocks(self, params: Pytree, *, pad: bool = False,
                       max_buckets: int = 1):
        """List form -> stacked for both stacks; heterogeneous PIFA
        ranks re-enter the scan via exact zero-padding (single bucket
        per stack)."""
        from repro.core.mpifa import pad_and_stack_blocks, try_stack_blocks
        params = dict(params)
        for key in ("enc_blocks", "dec_blocks"):
            if not isinstance(params[key], list):
                continue
            stacked = try_stack_blocks(params[key])
            if stacked is None and pad:
                stacked = pad_and_stack_blocks(params[key])
            if stacked is None:
                return None
            params[key] = stacked
        return params

    def _decode_cached(self, params, tokens, cache, last_idx=None,
                       per_row=False, all_logits=False):
        cfg = self.cfg
        pos = cache["pos"]
        sq = tokens.shape[1]
        h = L.embed(params["embed"], tokens)
        positions = pos[:, None] + jnp.arange(sq)[None, :]
        # paged caches store k as a page pool: the logical context
        # length is pages * page_size, not the pool's axis-2 extent
        clen = (cache["bt"].shape[1] * cache["k"].shape[2]
                if "bt" in cache else cache["k"].shape[2])
        pe = _sinusoid(clen, cfg.d_model, h.dtype)
        h = h + jnp.take(pe, jnp.clip(positions, 0, pe.shape[0] - 1), axis=0)

        def body(carry, xs):
            bp, kc, vc, xk, xv = xs
            layer_cache = {"k": kc, "v": vc, "pos": pos}
            if "bt" in cache:
                # paged self-attention KV (runtime/paging.py): decode,
                # verify and native multi-token paged prefill all go
                # through attention_block's block-table scatter; the
                # cross-KV stays per-slot — it is static encoder memory
                # (prefix sharing never applies: the scheduler cannot
                # serve enc-dec at all, and xk/xv are not positional)
                layer_cache["bt"] = cache["bt"]
            out, nc = self._dec_block(
                bp, carry, cross_kv=(xk.astype(carry.dtype), xv.astype(carry.dtype)),
                cache=layer_cache, positions=positions,
                per_row=per_row)
            return out, (nc["k"], nc["v"])

        h, (ks, vs) = jax.lax.scan(
            body, h, (params["dec_blocks"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache = dict(cache)
        new_cache.update({"k": ks, "v": vs, "pos": pos + sq})
        sel = h if all_logits else L.take_last(h, last_idx)
        h = L.apply_norm(params["dec_norm"], sel, cfg.norm_eps)
        return L.unembed(params["embed"], h), new_cache
