import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the very first lines: jax locks the device count on first init.
#   Only the dry-run forces 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without
hardware:  ``jax.jit(step, in_shardings=...).lower(**ShapeDtypeStructs)
.compile()`` must succeed on the 16x16 (256-chip) production mesh AND
the 2x16x16 (512-chip, 2-pod) mesh; we then extract

  * ``compiled.memory_analysis()``  (per-device bytes — does it fit)
  * ``compiled.cost_analysis()``    (per-device HLO FLOPs / HBM bytes)
  * collective operand bytes parsed from the post-SPMD HLO text

which feed EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch command_r_35b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
  python -m repro.launch.dryrun ... --compression pifa --density 0.55
"""
import argparse
import dataclasses
import functools
import json
import pathlib
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ARCH_IDS, SHAPES, ModelConfig, ShapeConfig,
                                cell_is_runnable, get_config)
from repro.core.density import rank_for_density_pifa
from repro.launch.mesh import make_mesh_from_spec, make_production_mesh
from repro.models.model import batch_spec, build_model, loss_fn, make_train_step
from repro.optim.adamw import AdamW
from repro.parallel import sharding as sh
from repro.parallel.hlo_cost import analyze_hlo_text

Pytree = Any

# TPU v5e hardware constants for the roofline terms (per chip).
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (1-link conservative)


# ---------------------------------------------------------------------------
# Compressed (PIFA) parameter shape planning — serving dry-runs use the
# paper's deployment representation without materializing anything.
# ---------------------------------------------------------------------------

_COMPRESSIBLE = ("q", "k", "v", "o", "up", "gate", "down", "in_proj",
                 "out_proj")


def compress_shape_tree(tree: Pytree, density: float,
                        path: tuple = (), folded: bool = False) -> Pytree:
    """Replace every compressible dense linear's shapes with PIFA shapes.

    Works on ``jax.eval_shape`` trees; supports stacked leading dims
    (num_layers, num_experts).  Routers/norms/embeddings stay dense,
    matching the paper's density accounting.  ``folded`` drops the MLP
    up-projection's gather (core/folding.py: permutation absorbed into
    the consumer) — the beyond-paper serving mode.
    """
    if isinstance(tree, dict):
        name = path[-1] if path else ""
        if ("w" in tree and name in _COMPRESSIBLE
                and getattr(tree["w"], "ndim", 0) >= 2
                and "router" not in path):
            w = tree["w"]
            lead, (m, n) = w.shape[:-2], w.shape[-2:]
            r = rank_for_density_pifa(m, n, density)
            # TPU adaptation (DESIGN.md SS2/SS6): align the PIFA rank so
            # (r, m-r) tile onto the 16-way model axis and the 128-lane
            # MXU -- unaligned ranks (the density formula gives e.g.
            # r=3765 for command-r's up-proj) fail the even-sharding
            # check and silently REPLICATE every PIFA weight.
            for mult in (256, 128, 64, 16):
                if r >= mult and (m - (r // mult) * mult) % 16 == 0:
                    r = (r // mult) * mult
                    break
            out = {
                "wp": jax.ShapeDtypeStruct(lead + (r, n), w.dtype),
                "c": jax.ShapeDtypeStruct(lead + (m - r, r), w.dtype),
                "inv_perm": jax.ShapeDtypeStruct(lead + (m,), jnp.int32),
            }
            if folded and name == "up" and len(path) >= 2 \
                    and path[-2] == "mlp":
                del out["inv_perm"]
            if "b" in tree:
                out["b"] = tree["b"]
            return out
        return {k: compress_shape_tree(v, density, path + (k,), folded)
                for k, v in tree.items()}
    return tree


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------

def tree_param_count(tree: Pytree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))


def active_param_count(tree: Pytree, cfg: ModelConfig) -> int:
    """MoE-aware: experts contribute top_k/E of their mass per token."""
    total = 0
    def walk(t, path):
        nonlocal total
        if isinstance(t, dict):
            for k, v in t.items():
                walk(v, path + (k,))
        else:
            n = int(np.prod(t.shape))
            if "moe" in path and not any("router" in p for p in path):
                n = int(n * cfg.top_k / max(cfg.num_experts, 1))
            total += n
    walk(tree, ())
    return total


def _sds(shape, dtype, mesh, spec):
    spec = sh.sanitize_spec(spec, shape, mesh)
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=jax.sharding.NamedSharding(mesh, spec))


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh, rules,
                act_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input — weak-type
    correct, sharded, no device allocation."""
    shard_batch = shape.global_batch >= 2
    specs = sh.batch_specs(
        {k: np.zeros(s, dtype=np.int32 if d == jnp.int32 else np.float32)
         for k, (s, d) in batch_spec(cfg, shape, act_dtype).items()},
        rules, shard_batch=shard_batch)
    out = {}
    for name, (shp, dt) in batch_spec(cfg, shape, act_dtype).items():
        out[name] = _sds(shp, dt, mesh, specs[name])
    return out


def build_cell(arch: str, shape_name: str, mesh, *, compression: str = "dense",
               density: float = 0.55, remat: str = "dots",
               param_dtype=jnp.bfloat16, rules: Optional[sh.ShardingRules] = None):
    """Returns (jitted_fn, example_args_SDS, meta) for one cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    base_rules = rules or sh.ShardingRules(
        shard_cache_seq=(shape.name == "long_500k"))
    rules = base_rules.for_mesh(mesh)

    params_sds = jax.eval_shape(
        functools.partial(model.init, dtype=param_dtype),
        jax.random.PRNGKey(0))
    if compression in ("pifa", "pifa_folded") and shape.kind != "train":
        params_sds = compress_shape_tree(
            params_sds, density, folded=(compression == "pifa_folded"))
    p_shard = sh.param_shardings(params_sds, mesh, rules)
    params_in = jax.tree.map(
        lambda s, sh_: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh_),
        params_sds, p_shard)

    meta = dict(arch=arch, shape=shape_name, kind=shape.kind,
                compression=compression, remat=remat,
                mesh=f"{'x'.join(str(d) for d in mesh.devices.shape)}",
                n_devices=int(mesh.devices.size),
                params=tree_param_count(params_sds),
                params_active=active_param_count(params_sds, cfg))

    if shape.kind == "train":
        optim = AdamW(lr=1e-4, weight_decay=0.01)
        opt_sds = jax.eval_shape(optim.init, params_sds)
        o_shard = sh.param_shardings(opt_sds, mesh, rules)
        opt_in = jax.tree.map(
            lambda s, sh_: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh_),
            opt_sds, o_shard)
        batch_in = input_specs(cfg, shape, mesh, rules)
        step = make_train_step(model, cfg, optim, remat=remat)
        fn = jax.jit(step, donate_argnums=(0, 1))
        return fn, (params_in, opt_in, batch_in), meta

    # serving cells
    cache_len = shape.seq_len
    cache_sds = jax.eval_shape(
        functools.partial(model.init_cache, shape.global_batch, cache_len,
                          dtype=jnp.bfloat16))
    c_specs = sh.cache_specs(cache_sds, rules, mesh)
    cache_in = jax.tree_util.tree_map(
        lambda s, sp: _sds(s.shape, s.dtype, mesh, sp), cache_sds, c_specs)

    if shape.kind == "prefill":
        batch_in = input_specs(cfg, shape, mesh, rules)

        def prefill_fn(params, batch, cache):
            if cfg.family == "encdec":
                return model.prefill(params, {"frames": batch["frames"],
                                              "tokens": batch["tokens"]}, cache)
            if cfg.family == "vlm":
                return model.prefill(params, batch["tokens"], cache,
                                     patches=batch["patches"])
            return model.prefill(params, batch["tokens"], cache)

        fn = jax.jit(prefill_fn, donate_argnums=(2,))
        return fn, (params_in, batch_in, cache_in), meta

    # decode
    tok_spec = sh.batch_specs({"token": np.zeros((shape.global_batch, 1),
                                                 np.int32)},
                              rules, shard_batch=shape.global_batch >= 2)
    token_in = _sds((shape.global_batch, 1), jnp.int32, mesh,
                    tok_spec["token"])

    def decode_fn(params, token, cache):
        return model.decode_step(params, token, cache)

    fn = jax.jit(decode_fn, donate_argnums=(2,))
    return fn, (params_in, token_in, cache_in), meta


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------

def analyze(compiled, meta: Dict, tokens_per_step: int) -> Dict:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        # jax < 0.5 returns a one-element list of per-device dicts;
        # newer versions return the dict directly
        cost = cost[0] if cost else {}
    mem = compiled.memory_analysis()
    text = compiled.as_text()
    # Trip-count-aware accounting (XLA's cost_analysis counts while
    # bodies once; every model here scans over layers).
    hc = analyze_hlo_text(text)
    coll_total, coll_kinds = hc.collective_bytes, hc.collective_breakdown

    flops_dev = float(hc.flops)
    bytes_dev = float(hc.bytes_accessed)
    n_dev = meta["n_devices"]

    compute_t = flops_dev / PEAK_FLOPS
    memory_t = bytes_dev / HBM_BW
    collective_t = coll_total / ICI_BW

    fwd_bwd = 6 if meta["kind"] == "train" else 2
    model_flops_global = fwd_bwd * meta["params_active"] * tokens_per_step
    model_flops_dev = model_flops_global / n_dev

    bound = max((("compute", compute_t), ("memory", memory_t),
                 ("collective", collective_t)), key=lambda kv: kv[1])

    out = dict(meta)
    out.update(
        tokens_per_step=tokens_per_step,
        hlo_flops_per_dev=flops_dev,
        hlo_bytes_per_dev=bytes_dev,
        xla_flops_raw=float(cost.get("flops", 0.0)),
        xla_bytes_raw=float(cost.get("bytes accessed", 0.0)),
        num_whiles=hc.num_whiles,
        max_trip_count=hc.max_trip_count,
        collective_bytes_per_dev=coll_total,
        collective_breakdown=coll_kinds,
        compute_term_s=compute_t,
        memory_term_s=memory_t,
        collective_term_s=collective_t,
        bound=bound[0],
        step_time_bound_s=bound[1],
        model_flops_per_dev=model_flops_dev,
        useful_flops_ratio=(model_flops_dev / flops_dev) if flops_dev else 0.0,
        roofline_fraction=(model_flops_dev / PEAK_FLOPS) / bound[1]
        if bound[1] > 0 else 0.0,
        argument_bytes=mem.argument_size_in_bytes,
        output_bytes=mem.output_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        peak_bytes_per_dev=(mem.argument_size_in_bytes
                            + mem.temp_size_in_bytes),
        fits_v5e_16g=bool(mem.argument_size_in_bytes + mem.temp_size_in_bytes
                          < 16e9),
    )
    return out


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             compression: str = "dense", density: float = 0.55,
             remat: str = "dots", mesh_spec: Optional[str] = None) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_runnable(cfg, shape)
    if not ok:
        return dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                    compression=compression, status="skipped", reason=why)
    if mesh_spec:
        mesh = make_mesh_from_spec(mesh_spec)
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    fn, args, meta = build_cell(arch, shape_name, mesh,
                                compression=compression, density=density,
                                remat=remat)
    with jax.sharding.use_mesh(mesh) if hasattr(jax.sharding, "use_mesh") \
            else mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    res = analyze(compiled, meta, tokens)
    res.update(status="ok", lower_s=round(t_lower, 1),
               compile_s=round(t_compile, 1))
    return res


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS + ("llama2_7b",), default=None)
    ap.add_argument("--shape", choices=tuple(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"),
                    default="pod")
    ap.add_argument("--mesh-spec", default=None,
                    help="override, e.g. 2x4 (reduced-device tests)")
    ap.add_argument("--all", action="store_true",
                    help="every (arch x shape) cell")
    ap.add_argument("--compression",
                    choices=("dense", "pifa", "pifa_folded"),
                    default="dense")
    ap.add_argument("--density", type=float, default=0.55)
    ap.add_argument("--remat", choices=("none", "dots", "full"),
                    default="dots")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    cells = []
    archs = (args.arch,) if args.arch else ARCH_IDS
    shapes = (args.shape,) if args.shape else tuple(SHAPES)
    if not (args.all or args.arch or args.shape):
        raise SystemExit("pass --all or --arch/--shape")
    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    for a in archs:
        for s in shapes:
            for m in meshes:
                cells.append((a, s, m))

    failures = 0
    for a, s, m in cells:
        tag = f"{a}.{s}.{m}.{args.compression}"
        outfile = outdir / f"{tag}.json"
        if outfile.exists():
            print(f"[dryrun] {tag}: cached", flush=True)
            continue
        print(f"[dryrun] {tag}: running...", flush=True)
        try:
            res = run_cell(a, s, m, compression=args.compression,
                           density=args.density, remat=args.remat,
                           mesh_spec=args.mesh_spec)
        except Exception as e:  # a failing cell is a bug in our system
            failures += 1
            res = dict(arch=a, shape=s, mesh=m, compression=args.compression,
                       status="error", error=f"{type(e).__name__}: {e}",
                       traceback=traceback.format_exc()[-4000:])
        outfile.write_text(json.dumps(res, indent=1, default=str))
        brief = {k: res.get(k) for k in
                 ("status", "bound", "compute_term_s", "memory_term_s",
                  "collective_term_s", "roofline_fraction", "compile_s",
                  "reason", "error")}
        print(f"[dryrun] {tag}: {brief}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
