"""Batched serving driver: dense or MPIFA-PIFA, scanned-engine decode.

The paper's deployment mode: compress once (MPIFA at --density), then
serve with PIFA layers.  Decode runs through the single-dispatch
generation engine (`runtime/engine.py`): prefill + the whole decode
loop is ONE jitted `lax.scan`, and heterogeneous-rank MPIFA_NS models
re-enter it via rank-bucketed zero-padded restacking instead of the
old O(T^2) full-recompute fallback.  The legacy per-token Python loop
is kept (``generate`` below) for comparison — the driver reports both,
the CPU-container analogue of Table 7.

``--draft-density`` turns on speculative decoding: a SECOND, more
aggressively compressed model drafts ``--spec-k`` tokens per round
and the serving target verifies them in one dispatch
(runtime/speculative.py).  Transformer-family drafts come from the
calibrated MPIFA driver; every other family (SSM / hybrid / encdec /
ring) uses the data-free PIFA walker (``compress_generic``) — their
verify rolls back through per-step state checkpoints.  Greedy
speculative output is checked bit-identical against plain engine
generation.

``--preempt`` demos preemptible serving (ISSUE 6): a high ``--priority``
latecomer evicts a low-priority slot at a chunk boundary through paged
block-table save/restore, optional ``--deadline`` / ``--cancel-request``
resolve requests early with reason codes, and every completed stream is
checked bit-identical to an uninterrupted run.

  PYTHONPATH=src python -m repro.launch.serve --arch tiny --density 0.55
  PYTHONPATH=src python -m repro.launch.serve --arch tiny \
      --draft-density 0.35 --spec-k 4
  PYTHONPATH=src python -m repro.launch.serve --arch tiny --smoke \
      --compression none --preempt --deadline 0.5 --cancel-request 0
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.data.calibration import calibration_batches
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.scheduler import (FaultPlan, Request, ServingScheduler)


def serve_continuous(model, params, *, vocab_size: int, n_requests: int = 8,
                     capacity: int = 4, chunk: int = 4, max_new: int = 16,
                     prompt_len: int = 16, eos_id=None, seed: int = 0,
                     label: str = "dense", draft_params=None,
                     spec_k: int = 4, cache: str = "contiguous",
                     page_size: int = 16) -> float:
    """Continuous-batching vs run-to-completion on one request mix.

    Mixed generation budgets under simultaneous arrival: the drain
    baseline holds every slot until the whole batch finishes, the
    continuous scheduler refills freed slots at chunk boundaries.
    ``cache="paged"`` serves both modes through the block-table page
    pool (runtime/paging.py) — output must not change.
    Returns the speedup (continuous / drain aggregate tokens/s).
    """
    rng = np.random.default_rng(seed)

    def mk_requests():
        reqs = []
        for i in range(n_requests):
            plen = int(rng.integers(max(2, prompt_len // 2), prompt_len + 1))
            budget = int(rng.choice([max(1, max_new // 8),
                                     max(1, max_new // 4),
                                     max(1, max_new // 2), max_new]))
            reqs.append(Request(
                request_id=i,
                prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
                max_new=budget))
        return reqs

    warm_set, bench_set = mk_requests(), mk_requests()
    runs = {}
    for mode in ("drain", "continuous"):
        # one prompt bucket + explicit cache_len: every draw fits (the
        # warm set must not be the one sizing the cache)
        sched = ServingScheduler(model, params, capacity=capacity,
                                 chunk=chunk, eos_id=eos_id,
                                 admission=mode,
                                 prompt_buckets=(prompt_len,),
                                 cache_len=(prompt_len + max_new + 1
                                            + (spec_k if draft_params
                                               is not None else 0)),
                                 draft_params=draft_params, spec_k=spec_k,
                                 cache=cache, page_size=page_size)
        sched.run(list(warm_set))           # warm: compile chunk/admits
        runs[mode] = sched.run(list(bench_set))  # same mix for both modes
        r = runs[mode]
        spec_note = (f", accept {r.acceptance_rate:.2f}"
                     if draft_params is not None else "")
        defer_note = (f", deferrals {dict(r.deferrals)}"
                      if r.deferrals else "")
        print(f"[serve] {label} {mode:10s}: {r.tokens_per_sec:7.1f} "
              f"tokens/s  ({r.generated} tokens, {r.chunks} chunks, "
              f"occupancy {r.mean_occupancy:.2f}/{capacity}{spec_note}"
              f"{defer_note})",
              flush=True)
    speedup = (runs["continuous"].tokens_per_sec
               / max(runs["drain"].tokens_per_sec, 1e-9))
    print(f"[serve] {label} continuous/drain speedup: {speedup:.2f}x",
          flush=True)
    return speedup


def serve_preemptible(model, params, *, vocab_size: int, capacity: int = 2,
                      chunk: int = 4, max_new: int = 32,
                      prompt_len: int = 16, seed: int = 0,
                      page_size: int = 16, priority: int = 1,
                      deadline_s=None, cancel_id=None) -> None:
    """Preemptible, deadline-aware serving demo (ISSUE 6).

    A batch of low-priority long requests saturates every slot; a
    high-priority short request arrives mid-run and evicts a victim at
    a chunk boundary via paged block-table save/restore.  Optionally a
    low request carries a --deadline and another is cancelled
    mid-flight via a FaultPlan.  Prints per-request outcomes (reason
    codes, preemption counts) and verifies the preempted victims'
    streams are bit-identical to an uninterrupted run.
    """
    rng = np.random.default_rng(seed)
    # prompts drawn ONCE: both runs must serve the identical mix or
    # the bit-identity check below is meaningless
    prompts = [rng.integers(0, vocab_size, prompt_len).astype(np.int32)
               for _ in range(capacity + 2)]

    def mk():
        reqs = []
        for i in range(capacity + 1):
            reqs.append(Request(
                request_id=i, prompt=prompts[i], max_new=max_new,
                deadline_s=(deadline_s if deadline_s is not None
                            and i == 1 else None)))
        reqs.append(Request(
            request_id=90, prompt=prompts[-1],
            max_new=max(1, max_new // 4), arrival_time=0.05,
            priority=priority))
        return reqs

    plan = (FaultPlan().at(2, "cancel", cancel_id)
            if cancel_id is not None else None)

    def run(preemption, fault_plan):
        sched = ServingScheduler(
            model, params, capacity=capacity, chunk=chunk,
            prompt_buckets=(prompt_len,),
            cache_len=prompt_len + max_new + 1,
            cache="paged", page_size=page_size,
            preemption=preemption, fault_plan=fault_plan)
        return sched.run(mk())

    ref = {r.request_id: r.tokens.tolist()
           for r in run("off", None).results}
    res = run("save_restore", plan)
    print(f"[serve] preemptible: {res.preemptions} preemption(s), "
          f"{res.resumes} resume(s), {len(res.rejected)} rejected, "
          f"slow chunks {res.slow_chunks}", flush=True)
    for r in sorted(res.results, key=lambda r: r.request_id):
        reason = r.cancel_reason.value if r.cancel_reason else "completed"
        intact = (ref.get(r.request_id) == r.tokens.tolist()
                  if r.cancel_reason is None else "n/a")
        print(f"[serve]   req {r.request_id:3d} prio "
              f"{'hi' if r.request_id == 90 else 'lo'}: {reason:12s} "
              f"{r.generated:3d} tokens, preempted x{r.preemptions}, "
              f"bit-identical={intact}", flush=True)
    for r in res.results:
        if r.cancel_reason is None and ref.get(r.request_id) is not None:
            if r.tokens.tolist() != ref[r.request_id]:
                raise SystemExit(f"request {r.request_id}: preemption "
                                 "changed the token stream")


def serve_prefix_cache(model, params, *, vocab_size: int, capacity: int = 4,
                       chunk: int = 4, max_new: int = 16,
                       prompt_len: int = 32, n_requests: int = 8,
                       page_size: int = 16, seed: int = 0) -> None:
    """Shared-prefix paged serving demo (ISSUE 8).

    A burst of requests sharing a long page-aligned prompt prefix (the
    system-prompt / few-shot-template traffic shape) runs twice through
    one scheduler: the first drain seeds the content-hash prefix index,
    the second hits it — cache-hit admissions map the shared physical
    pages at refcount + 1 and prefill only the uncached tail.  Prints
    the observability counters (pool high-water, hit/miss, COW copies,
    swap in/out) for both drains and verifies every stream bit-identical
    to a cold scheduler run of the same mix (non-zero exit on
    divergence)."""
    rng = np.random.default_rng(seed)
    shared = rng.integers(0, vocab_size,
                          (prompt_len // 2)).astype(np.int32)

    def mk(base_id):
        reqs = []
        for i in range(n_requests):
            tail = rng.integers(
                0, vocab_size,
                int(rng.integers(2, prompt_len - len(shared) + 1)))
            reqs.append(Request(
                request_id=base_id + i,
                prompt=np.concatenate([shared,
                                       tail.astype(np.int32)]),
                max_new=max(1, max_new // 2 if i % 2 else max_new)))
        return reqs

    cold_set, warm_set = mk(0), mk(100)
    kwargs = dict(capacity=capacity, chunk=chunk,
                  prompt_buckets=(prompt_len,),
                  cache_len=prompt_len + max_new + 1,
                  cache="paged", page_size=page_size)
    sched = ServingScheduler(model, params, prefix_cache=True, **kwargs)
    results = []
    for label, reqs in (("cold", cold_set), ("warm", warm_set)):
        run = sched.run(list(reqs))
        results.extend(run.results)
        print(f"[serve] prefix-cache {label}: {run.tokens_per_sec:7.1f} "
              f"tokens/s — hits {run.prefix_hits}, misses "
              f"{run.prefix_misses}, cow {run.cow_copies}, swap "
              f"{run.swap_ins}in/{run.swap_outs}out, pool high-water "
              f"{run.page_high_water} pages", flush=True)
        if label == "warm" and run.prefix_hits == 0:
            raise SystemExit("prefix cache never hit on the warm drain")
    # bit-identity: a cold scheduler (no prefix reuse) over the same mix
    ref_sched = ServingScheduler(model, params, **kwargs)
    ref = {r.request_id: r.tokens.tolist()
           for r in ref_sched.run(cold_set + warm_set).results}
    bad = sorted(r.request_id for r in results
                 if r.tokens.tolist() != ref[r.request_id])
    if bad:
        raise SystemExit(f"prefix-cache serving diverged on requests "
                         f"{bad} — shared pages must be invisible")
    print(f"[serve] prefix-cache: all {len(results)} streams "
          "bit-identical to the unshared run", flush=True)


def serve_durable(model, params, *, vocab_size: int, journal_dir: str,
                  snapshot_every: int = 2, resume: bool = False,
                  crash_at=None, capacity: int = 4, chunk: int = 4,
                  max_new: int = 16, prompt_len: int = 16,
                  n_requests: int = 8, page_size: int = 16,
                  paged: bool = False, seed: int = 0) -> int:
    """Durable serving demo (ISSUE 7): crash-and-resume round trip.

    Two invocations over the same ``--journal-dir``:

      1. ``--crash-at N`` runs with a write-ahead journal + snapshots
         and an injected :class:`SchedulerCrash` at chunk boundary N —
         the process exits 17 with in-flight work on disk only;
      2. ``--resume`` recovers a FRESH scheduler from the journal +
         latest snapshot, drains it, and verifies every stream is
         bit-identical to an uninterrupted in-process reference run —
         non-zero exit on any divergence (the CI hard gate).
    """
    from repro.runtime.durability import (Durability, finish_recovered,
                                          recover_into)
    from repro.runtime.fault_tolerance import SchedulerCrash

    rng = np.random.default_rng(seed)
    # the request mix derives ONLY from the seed: both invocations (and
    # the in-process reference) must serve the identical requests
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(max(2, prompt_len // 2), prompt_len + 1))
        budget = int(rng.choice([max(1, max_new // 4),
                                 max(1, max_new // 2), max_new]))
        reqs.append(Request(
            request_id=i,
            prompt=rng.integers(0, vocab_size, plen).astype(np.int32),
            max_new=budget))
    kwargs = dict(capacity=capacity, chunk=chunk,
                  prompt_buckets=(prompt_len,),
                  cache_len=prompt_len + max_new + 1,
                  cache="paged" if paged else "contiguous",
                  page_size=page_size)

    if not resume:
        dur = Durability(journal_dir, snapshot_every=snapshot_every)
        plan = (FaultPlan().at(int(crash_at), "crash")
                if crash_at is not None else None)
        sched = ServingScheduler(model, params, durability=dur,
                                 fault_plan=plan, **kwargs)
        try:
            run = sched.run(list(reqs))
        except SchedulerCrash as e:
            dur.close()
            print(f"[serve] durable: {e} — journal + snapshots left in "
                  f"{journal_dir}; resume with --resume", flush=True)
            return 17
        dur.close()
        print(f"[serve] durable: clean drain ({run.generated} tokens, "
              f"{len(run.results)} results) — journal in {journal_dir}",
              flush=True)
        return 0

    # --resume: recover a fresh scheduler from disk, drain, verify
    dur = Durability(journal_dir, snapshot_every=snapshot_every)
    sched = ServingScheduler(model, params, durability=dur, **kwargs)
    info = recover_into(sched)
    rec = finish_recovered(sched, info)
    dur.close()
    print(f"[serve] durable resume: recovered in {info.recover_s*1e3:.1f}ms "
          f"(snapshot {info.snapshot_tag}, {len(info.restored)} restored, "
          f"{len(info.recomputed)} recomputed, {len(info.requeued)} "
          f"requeued, {info.truncated_bytes} torn bytes), replayed "
          f"{rec.replayed} journaled tokens, {rec.mismatches} mismatches",
          flush=True)
    ref = ServingScheduler(model, params, **kwargs).run(list(reqs))
    ref_toks = {r.request_id: r.tokens.tolist() for r in ref.results}
    got = {r.request_id: r.tokens.tolist() for r in rec.run.results}
    bad = sorted(rid for rid in ref_toks
                 if got.get(rid) != ref_toks[rid])
    if rec.mismatches or bad:
        raise SystemExit(
            f"durable resume diverged: {rec.mismatches} replay "
            f"mismatches, requests {bad} differ from the uninterrupted "
            "reference")
    print(f"[serve] durable resume: all {len(ref_toks)} streams "
          "bit-identical to the uninterrupted run", flush=True)
    return 0


def compress_generic(model, params, density, *, per_block=None):
    """Family-agnostic PIFA compression: every dense linear inside every
    block is factorized data-free (SVD prune, no reconstruction).

    The transformer-family MPIFA calibration driver
    (``compress_transformer``) stays the paper-faithful path; this
    walker is what gives the OTHER families (mamba2 / hybrid / encdec /
    ring archs) cheap speculative DRAFTS and compressed serving
    targets.  ``per_block`` (list of densities, cycled over blocks)
    produces MPIFA_NS-style heterogeneous ranks.
    """
    from repro.core.mpifa import MpifaConfig, compress_linear_params

    def walk(node, mc):
        if isinstance(node, dict):
            if "w" in node and getattr(node["w"], "ndim", 0) == 2:
                return compress_linear_params(mc, node)
            return {k: walk(v, mc) for k, v in node.items()}
        return node

    lst = model.unstack_blocks(params)
    out = dict(lst)
    for key in ("blocks", "mamba", "enc_blocks", "dec_blocks"):
        if key not in lst or not isinstance(lst[key], list):
            continue
        blocks = []
        for i, bp in enumerate(lst[key]):
            rho = per_block[i % len(per_block)] if per_block else density
            blocks.append(walk(bp, MpifaConfig(density=rho, prune="svd",
                                               reconstruct="none")))
        out[key] = blocks
    return out


def generate(model, params, prompts, max_new: int, cache_len: int,
             unstacked: bool = False):
    """LEGACY greedy batched generation; returns (tokens, tokens/sec).

    Re-dispatches a jitted step per token from Python — kept as the
    baseline the engine is measured against (and as the fallback for
    params the restack hooks cannot unify).
    """
    b = prompts.shape[0]
    cache = model.init_cache(b, cache_len, dtype=jnp.float32)
    if unstacked:
        # compressed params arrive in list form; uniform-density MPIFA
        # blocks re-stack into the scanned KV-cache fast path.
        restacked = (model.restack_blocks(params)
                     if hasattr(model, "restack_blocks") else None)
        if restacked is not None:
            params = restacked
        else:
            # heterogeneous ranks (MPIFA_NS): full-recompute fallback
            out = [prompts]
            t0 = time.time()
            cur = prompts
            for _ in range(max_new):
                logits = model.forward_unstacked(params, cur)
                nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
                cur = jnp.concatenate([cur, nxt], axis=1)
                out.append(nxt)
            dt = time.time() - t0
            return jnp.concatenate(out, axis=1), b * max_new / dt

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)
    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [prompts, tok]
    for _ in range(max_new - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    return jnp.concatenate(out, axis=1), b * max_new / dt


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny",
                    choices=("tiny",) + ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--density", type=float, default=0.55)
    ap.add_argument("--calib-samples", type=int, default=8)
    ap.add_argument("--compression", default="pifa",
                    choices=("none", "pifa", "lowrank"))
    ap.add_argument("--loop", default="both",
                    choices=("engine", "legacy", "both"),
                    help="scanned single-dispatch engine, the legacy "
                         "per-token Python loop, or both (reports speedup)")
    ap.add_argument("--max-buckets", type=int, default=4,
                    help="rank buckets for MPIFA_NS restacking")
    ap.add_argument("--continuous", action="store_true",
                    help="also run the continuous-batching scheduler vs "
                         "run-to-completion batching (mixed budgets)")
    ap.add_argument("--capacity", type=int, default=4,
                    help="scheduler slot count (KV-cache rows)")
    ap.add_argument("--chunk", type=int, default=4,
                    help="decode steps per scheduler dispatch")
    ap.add_argument("--requests", type=int, default=8,
                    help="requests for the --continuous comparison")
    ap.add_argument("--paged", action="store_true",
                    help="serve the --continuous comparison through the "
                         "paged block-table KV cache (runtime/paging.py)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page with --paged")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="run the shared-prefix serving demo (needs "
                         "--paged): refcounted copy-on-write pages, a "
                         "content-hash prefix index, and host swap — "
                         "warm-drain streams checked bit-identical to "
                         "an unshared run")
    ap.add_argument("--preempt", action="store_true",
                    help="run the preemptible-serving demo: a high "
                         "--priority latecomer evicts a low-priority slot "
                         "(paged save/restore) and every stream is checked "
                         "bit-identical to an uninterrupted run")
    ap.add_argument("--priority", type=int, default=1,
                    help="priority class of the --preempt latecomer "
                         "(higher preempts lower)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="deadline (seconds after arrival) for one low "
                         "request in the --preempt demo")
    ap.add_argument("--cancel-request", type=int, default=None,
                    help="request id to cancel mid-flight in the "
                         "--preempt demo (low requests are 0..capacity)")
    ap.add_argument("--journal-dir", default=None,
                    help="durable-serving mode: write-ahead journal + "
                         "snapshots under this directory (skips the "
                         "engine benchmarks; see --crash-at / --resume)")
    ap.add_argument("--snapshot-every", type=int, default=2,
                    help="scheduler snapshot cadence in chunk dispatches "
                         "(durable mode)")
    ap.add_argument("--crash-at", type=int, default=None,
                    help="inject a SchedulerCrash at this chunk boundary "
                         "(durable mode; process exits 17)")
    ap.add_argument("--resume", action="store_true",
                    help="recover from --journal-dir, drain, and verify "
                         "bit-identity against an uninterrupted reference "
                         "(non-zero exit on divergence)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--draft-density", type=float, default=None,
                    help="MPIFA density for a speculative DRAFT model; "
                         "enables draft/verify decoding")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="draft tokens proposed per verify dispatch")
    ap.add_argument("--params-npz", default=None,
                    help="trained weights from launch/train.py checkpoints")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.arch == "tiny" or not args.smoke \
        else get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    if args.journal_dir is not None:
        return serve_durable(
            model, params, vocab_size=cfg.vocab_size,
            journal_dir=args.journal_dir,
            snapshot_every=args.snapshot_every, resume=args.resume,
            crash_at=args.crash_at, capacity=args.capacity,
            chunk=args.chunk, max_new=args.max_new,
            prompt_len=args.prompt_len, n_requests=args.requests,
            page_size=args.page_size, paged=args.paged, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        dtype=jnp.int32)
    cache_len = args.prompt_len + args.max_new + 1
    engine = GenerationEngine(model, max_buckets=args.max_buckets)

    def serve(p, label, unstacked=False):
        """Run the selected loop(s); returns the engine (or legacy)
        tokens for agreement checks."""
        toks = None
        tps_leg = None
        if args.loop in ("legacy", "both"):
            toks, tps_leg = generate(model, p, prompts, args.max_new,
                                     cache_len, unstacked=unstacked)
            print(f"[serve] {label} legacy-loop: {tps_leg:.1f} tokens/s",
                  flush=True)
        if args.loop in ("engine", "both"):
            try:
                res = engine.generate(p, prompts, args.max_new, cache_len,
                                      temperature=args.temperature,
                                      top_k=args.top_k,
                                      key=jax.random.PRNGKey(args.seed))
            except ValueError as e:  # un-unifiable blocks: legacy fallback
                print(f"[serve] {label} engine unavailable ({e}); "
                      "use --loop legacy", flush=True)
                if toks is None:
                    toks, _ = generate(model, p, prompts, args.max_new,
                                       cache_len, unstacked=unstacked)
                return toks
            print(f"[serve] {label} engine: {res.tokens_per_sec:.1f} tokens/s"
                  f" (compile {res.compile_time:.2f}s)", flush=True)
            if tps_leg is not None and args.temperature == 0.0:
                # only comparable when both loops decode greedily (the
                # legacy loop has no sampling path)
                agree = float(jnp.mean((res.tokens == toks)
                                       .astype(jnp.float32)))
                print(f"[serve] {label} engine/legacy speedup: "
                      f"{res.tokens_per_sec / tps_leg:.2f}x "
                      f"(token agreement {agree:.3f})", flush=True)
            toks = res.tokens
        return toks

    draft = None
    if args.draft_density is not None:
        t0 = time.time()
        if cfg.family in ("dense", "vlm"):
            calib_d = calibration_batches(cfg.vocab_size,
                                          args.calib_samples, 64)
            draft = compress_transformer(
                model, params, calib_d,
                MpifaConfig(density=args.draft_density))
        else:
            # SSM / hybrid / encdec / ring archs: family-agnostic
            # data-free PIFA walker (speculation serves every family —
            # SSM/ring verify rolls back via per-step checkpoints)
            draft = compress_generic(model, params, args.draft_density)
        print(f"[serve] draft compressed in {time.time()-t0:.1f}s "
              f"(density {args.draft_density}, family {cfg.family})",
              flush=True)

    def serve_speculative(target_p, label, ref_toks):
        res = engine.generate_speculative(
            target_p, draft, prompts, args.max_new,
            spec_k=args.spec_k, temperature=args.temperature,
            top_k=args.top_k, key=jax.random.PRNGKey(args.seed))
        print(f"[serve] {label} speculative (k={args.spec_k}, draft "
              f"density {args.draft_density}): {res.tokens_per_sec:.1f} "
              f"tokens/s, accept {res.acceptance_rate:.2f}, "
              f"{res.emitted_per_dispatch:.2f} tokens/dispatch "
              f"({res.rounds} verify dispatches)", flush=True)
        if args.temperature == 0.0 and ref_toks is not None:
            exact = bool(jnp.all(res.tokens == ref_toks))
            print(f"[serve] {label} speculative greedy bit-identity: "
                  f"{exact}", flush=True)
            if not exact:
                raise SystemExit(
                    f"{label}: speculative greedy output diverged from "
                    "plain engine generation")
        return res

    toks_d = serve(params, "dense")
    if draft is not None:
        serve_speculative(params, "dense", toks_d)
    cache_mode = "paged" if args.paged else "contiguous"
    if args.prefix_cache:
        if not args.paged:
            raise SystemExit("--prefix-cache needs --paged: the "
                             "contiguous cache has no shareable pages")
        serve_prefix_cache(model, params, vocab_size=cfg.vocab_size,
                           capacity=args.capacity, chunk=args.chunk,
                           max_new=args.max_new,
                           prompt_len=args.prompt_len,
                           n_requests=args.requests,
                           page_size=args.page_size, seed=args.seed)
    if args.preempt:
        serve_preemptible(model, params, vocab_size=cfg.vocab_size,
                          capacity=args.capacity, chunk=args.chunk,
                          max_new=args.max_new, prompt_len=args.prompt_len,
                          seed=args.seed, page_size=args.page_size,
                          priority=args.priority, deadline_s=args.deadline,
                          cancel_id=args.cancel_request)
    if args.continuous:
        serve_continuous(model, params, vocab_size=cfg.vocab_size,
                         n_requests=args.requests, capacity=args.capacity,
                         chunk=args.chunk, max_new=args.max_new,
                         prompt_len=args.prompt_len, seed=args.seed,
                         label="dense" if not args.paged else "dense/paged",
                         cache=cache_mode, page_size=args.page_size)
        if draft is not None:
            serve_continuous(model, params, vocab_size=cfg.vocab_size,
                             n_requests=args.requests,
                             capacity=args.capacity, chunk=args.chunk,
                             max_new=args.max_new,
                             prompt_len=args.prompt_len, seed=args.seed,
                             label="dense+spec", draft_params=draft,
                             spec_k=args.spec_k, cache=cache_mode,
                             page_size=args.page_size)

    if args.compression != "none":
        if cfg.family not in ("dense", "vlm"):
            print("[serve] MPIFA calibration driver covers the transformer "
                  "family; other archs compress via core.mpifa."
                  "compress_linear_params (see examples/)", flush=True)
            return 0
        calib = calibration_batches(cfg.vocab_size, args.calib_samples, 64)
        mcfg = MpifaConfig(density=args.density,
                           final_repr="pifa" if args.compression == "pifa"
                           else "lowrank")
        t0 = time.time()
        cparams = compress_transformer(model, params, calib, mcfg)
        print(f"[serve] compressed in {time.time()-t0:.1f}s "
              f"(density {args.density})", flush=True)
        toks_c = serve(cparams, args.compression, unstacked=True)
        if draft is not None and args.compression == "pifa":
            serve_speculative(cparams, args.compression, toks_c)
        if args.continuous:
            serve_continuous(model, cparams, vocab_size=cfg.vocab_size,
                             n_requests=args.requests,
                             capacity=args.capacity, chunk=args.chunk,
                             max_new=args.max_new,
                             prompt_len=args.prompt_len, seed=args.seed,
                             label=args.compression, cache=cache_mode,
                             page_size=args.page_size)
        if args.temperature == 0.0:
            agree = float(jnp.mean((toks_c == toks_d).astype(jnp.float32)))
            print(f"[serve] {args.compression} token agreement with dense "
                  f"{agree:.3f}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
