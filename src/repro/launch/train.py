"""Production-shaped training driver.

Wires every substrate together: config registry -> model -> sharded
train step (pjit) -> deterministic data pipeline -> AdamW (+optional
gradient compression) -> async checkpointing -> restart/straggler
policies.  On this CPU container it trains the tiny config for real;
on a pod the same file launches per-host (jax.distributed) with the
production mesh.

  PYTHONPATH=src python -m repro.launch.train --arch tiny --steps 300
  PYTHONPATH=src python -m repro.launch.train --arch tiny --resume ...
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.checkpoint.checkpointer import Checkpointer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.launch.mesh import make_mesh_from_spec
from repro.models.model import build_model, loss_fn, make_train_step
from repro.optim.adamw import AdamW
from repro.optim.compression import Int8Compressor, PowerSGDCompressor
from repro.optim.schedule import warmup_cosine
from repro.runtime.fault_tolerance import RestartPolicy, StragglerDetector
from repro.parallel import sharding as sh


def build_trainer(args):
    if args.arch == "tiny":
        cfg = get_config("tiny")
    elif args.smoke:
        cfg = get_smoke_config(args.arch)
    else:
        cfg = get_config(args.arch)
    model = build_model(cfg)

    sched = warmup_cosine(args.lr, max(args.steps // 20, 1), args.steps)
    optim = AdamW(lr=sched, weight_decay=0.01, clip_norm=1.0)
    step_fn = make_train_step(model, cfg, optim, remat=args.remat)
    return cfg, model, optim, step_fn


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny",
                    choices=("tiny",) + ARCH_IDS + ("llama2_7b",))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config of --arch")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--remat", default="none",
                    choices=("none", "dots", "full"))
    ap.add_argument("--mesh-spec", default=None, help="e.g. 2x4")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compress", default="none",
                    choices=("none", "int8", "powersgd"))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash (fault-tolerance tests)")
    args = ap.parse_args(argv)

    cfg, model, optim, step_fn = build_trainer(args)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch, seed=args.seed)
    pipe = TokenPipeline(data_cfg)

    mesh = make_mesh_from_spec(args.mesh_spec) if args.mesh_spec else None
    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = optim.init(params)

    compressor = None
    comp_state = None
    if args.grad_compress == "int8":
        compressor = Int8Compressor()
    elif args.grad_compress == "powersgd":
        compressor = PowerSGDCompressor(rank=4)

    ckpt: Optional[Checkpointer] = None
    start_step = 0
    if args.ckpt_dir:
        ckpt = Checkpointer(args.ckpt_dir)
        latest = ckpt.latest_step()
        if latest is not None:
            (start_step, (params, opt_state),
             extra) = ckpt.restore_latest((params, opt_state))
            pipe.load_state_dict(extra["data"])
            print(f"[train] resumed from step {start_step}", flush=True)

    if mesh is not None:
        rules = sh.ShardingRules().for_mesh(mesh)
        p_sh = sh.param_shardings(params, mesh, rules)
        o_sh = sh.param_shardings(opt_state, mesh, rules)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    straggler = StragglerDetector()
    restart = RestartPolicy()
    losses = []
    host = f"host{jax.process_index()}"

    ctx = mesh if mesh is not None else _nullcontext()
    with ctx:
        for step in range(start_step, args.steps):
            if args.fail_at_step is not None and step == args.fail_at_step:
                print(f"[train] injected failure at step {step}", flush=True)
                raise SystemExit(42)
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()}
            if compressor is not None:
                # host-side error-feedback round trip (wire simulation)
                loss, grads = jax.value_and_grad(
                    lambda p: loss_fn(model, cfg, p, batch))(params)
                if comp_state is None:
                    comp_state = compressor.init(grads)
                grads, comp_state = compressor.roundtrip(grads, comp_state)
                updates, opt_state = optim.update(grads, opt_state, params)
                params = jax.tree.map(lambda p, u: p + u, params, updates)
            else:
                loss, params, opt_state = jitted(params, opt_state, batch)
            dt = time.time() - t0
            straggler.record(host, dt)
            losses.append(float(loss))
            pipe.step = step + 1
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"[train] step {step} loss {float(loss):.4f} "
                      f"({dt*1000:.0f} ms)", flush=True)
            if ckpt and (step + 1) % args.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"data": pipe.state_dict(),
                                 "loss": float(loss)})
    if ckpt:
        ckpt.save(args.steps, (params, opt_state),
                  extra={"data": pipe.state_dict(),
                         "loss": float(losses[-1])}, blocking=True)
    print(f"[train] done: first loss {losses[0]:.4f} "
          f"last loss {losses[-1]:.4f}", flush=True)
    return 0


class _nullcontext:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    sys.exit(main())
