"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run is the only place that forces the
512-placeholder-device platform, and it does so before any jax import.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh_from_spec", "AXIS_DOC"]

AXIS_DOC = {
    "pod": "across-pod data parallelism (DCN links)",
    "data": "in-pod batch / FSDP axis (ICI)",
    "model": "tensor/expert parallel axis (ICI)",
}


def _axis_type_kwargs(n: int) -> dict:
    """Explicit Auto axis types on jax >= 0.5; older jax (0.4.x) has no
    AxisType and treats every mesh axis as Auto already."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh_from_spec(spec: str) -> jax.sharding.Mesh:
    """e.g. "2x4" -> (data=2, model=4); "2x2x2" -> (pod, data, model).

    Used by the reduced-mesh subprocess tests.
    """
    dims = tuple(int(x) for x in spec.split("x"))
    if len(dims) == 2:
        axes: Tuple[str, ...] = ("data", "model")
    elif len(dims) == 3:
        axes = ("pod", "data", "model")
    else:
        raise ValueError(f"bad mesh spec {spec}")
    return jax.make_mesh(dims, axes, **_axis_type_kwargs(len(dims)))
