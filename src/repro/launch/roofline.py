"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m repro.launch.roofline --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def fmt_b(x) -> str:
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(dirpath: str) -> List[Dict]:
    out = []
    for f in sorted(pathlib.Path(dirpath).glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def one_sentence(r: Dict) -> str:
    b = r.get("bound")
    if b == "memory":
        return ("raise arithmetic intensity: larger attention/scan chunks, "
                "fuse norm chains, bf16-ize fp32 intermediates")
    if b == "collective":
        return ("shrink TP traffic: PIFA-rank gathers, 2D sharding, "
                "overlap collectives with the layer scan")
    return "already compute-bound: push MXU utilization (tile alignment)"


def table(rows: List[Dict], mesh: str, compression: str = "dense") -> str:
    lines = [
        "| arch | shape | kind | compute | memory | collective | bound | "
        "useful/HLO | roofline-frac | fits16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") not in (mesh,) and r.get("mesh") != mesh:
            continue
        if r.get("compression", "dense") != compression:
            continue
        if r.get("status") == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | "
                         f"skip | - | - | - |")
            continue
        if r.get("status") != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | ERROR | "
                         f"{r.get('error','')[:40]} | | | | | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | "
            f"{fmt_s(r['compute_term_s'])} | {fmt_s(r['memory_term_s'])} | "
            f"{fmt_s(r['collective_term_s'])} | **{r['bound']}** | "
            f"{r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.4f} | "
            f"{'y' if r.get('fits_v5e_16g') else 'n'} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--compression", default="dense")
    ap.add_argument("--sort", default="arch")
    args = ap.parse_args(argv)
    rows = load(args.dir)
    rows.sort(key=lambda r: (r.get("arch", ""), r.get("shape", "")))
    print(table(rows, args.mesh, args.compression))
    ok = [r for r in rows if r.get("status") == "ok"
          and r.get("mesh") == args.mesh
          and r.get("compression") == args.compression]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: (r["collective_term_s"] /
                                      max(r["step_time_bound_s"], 1e-12)))
        print(f"\nworst roofline fraction: {worst['arch']}.{worst['shape']} "
              f"({worst['roofline_fraction']:.5f})")
        print(f"most collective-bound: {coll['arch']}.{coll['shape']} "
              f"(coll/bound = "
              f"{coll['collective_term_s']/max(coll['step_time_bound_s'],1e-12):.2f})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
