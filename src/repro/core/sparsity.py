"""Non-uniform sparsity allocation for MPIFA_NS (paper App. B.2).

Final per-module density =

    Type Density x Layer Density / Global Density

* **Type density** splits attention vs MLP modules: attention density is
  searched over {global, global - 0.1}; MLP density is then solved so
  the *global* parameter budget is exactly preserved.
* **Layer density** follows OWL (Yin et al.): layers with more activation
  outliers keep more parameters.  We compute the OWL score from
  calibration activations (fraction of entries with |a| > theta * mean|a|)
  and map scores affinely into [global - lam, global + lam], then
  renormalize by parameter mass so the global density is unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence

import numpy as np

__all__ = ["ModuleBudget", "owl_layer_densities", "type_densities",
           "allocate_densities", "owl_scores_from_model"]


def owl_scores_from_model(model, params, calib_batches, theta: float = 5.0):
    """Per-layer OWL outlier ratios from real calibration activations.

    For each block, taps every linear input and measures the fraction of
    activations with ``|a| > theta * mean|a|`` (Yin et al.'s outlier
    criterion).  Returns a list of per-layer scores for
    :func:`owl_layer_densities`.
    """
    import jax.numpy as jnp

    scores = []
    hs = [model.embed_tokens(params, t) for t in calib_batches]
    for bi in range(model.num_blocks()):
        bp = model.block_params(params, bi)
        ratios = []

        def tap(name, x):
            a = np.abs(np.asarray(x, dtype=np.float32))
            mu = a.mean() + 1e-12
            ratios.append(float((a > theta * mu).mean()))

        win = jnp.int32(model.cfg.window_for_layer(bi))
        new_hs = []
        for h in hs:
            out, _ = model.block_apply(bp, h, window=win, tap=tap)
            new_hs.append(out)
        hs = new_hs
        scores.append(float(np.mean(ratios)) if ratios else 0.0)
    return scores


@dataclasses.dataclass(frozen=True)
class ModuleBudget:
    """One compressible module: identity + parameter mass + grouping."""

    name: str            # unique path, e.g. "block3/mlp/up"
    layer: int           # transformer block index
    kind: str            # "attn" | "mlp"
    params: int          # dense parameter count (m*n)


def owl_layer_densities(
    outlier_scores: Sequence[float],
    layer_params: Sequence[float],
    global_density: float,
    lam: float = 0.08,
) -> np.ndarray:
    """OWL-style layer densities in [global-lam, global+lam].

    ``outlier_scores[i]`` is the outlier ratio of layer ``i`` (any
    monotone saliency works); ``layer_params`` weights the
    renormalization so that sum(d_i * p_i) == global * sum(p_i).
    """
    s = np.asarray(outlier_scores, dtype=np.float64)
    p = np.asarray(layer_params, dtype=np.float64)
    if s.size == 0:
        return np.asarray([])
    rng = s.max() - s.min()
    if rng < 1e-12:
        d = np.full_like(s, global_density)
    else:
        d = (s - s.min()) / rng * (2 * lam) + (global_density - lam)
    # renormalize under the parameter-mass weighting
    cur = float((d * p).sum() / p.sum())
    d = d + (global_density - cur)
    return np.clip(d, 0.02, 1.0)


def type_densities(
    budgets: Sequence[ModuleBudget],
    global_density: float,
    attn_candidates: Sequence[float] = (0.0, -0.1),
) -> Dict[str, Dict[str, float]]:
    """Candidate {attn, mlp} density splits preserving global params.

    Returns a dict keyed by candidate label -> {"attn": da, "mlp": dm}.
    The caller scores each candidate (e.g. calibration PPL) and picks
    the best, as App. B.2 prescribes.
    """
    p_attn = sum(b.params for b in budgets if b.kind == "attn")
    p_mlp = sum(b.params for b in budgets if b.kind == "mlp")
    total = p_attn + p_mlp
    out: Dict[str, Dict[str, float]] = {}
    for delta in attn_candidates:
        da = global_density + delta
        if not (0.02 <= da <= 1.0):
            continue
        if p_mlp == 0:
            if abs(da - global_density) > 1e-9:
                continue
            dm = global_density
        else:
            dm = (global_density * total - da * p_attn) / p_mlp
        if not (0.02 <= dm <= 1.0):
            continue
        out[f"attn{delta:+.2f}"] = {"attn": da, "mlp": dm}
    if not out:  # always provide the uniform fallback
        out["uniform"] = {"attn": global_density, "mlp": global_density}
    return out


def allocate_densities(
    budgets: Sequence[ModuleBudget],
    global_density: float,
    *,
    layer_density: Mapping[int, float] | None = None,
    type_density: Mapping[str, float] | None = None,
) -> Dict[str, float]:
    """Final per-module densities (App. B.2 formula), renormalized so the
    global parameter budget is met exactly under the actual module sizes.
    """
    out: Dict[str, float] = {}
    for b in budgets:
        ld = layer_density.get(b.layer, global_density) if layer_density else global_density
        td = type_density.get(b.kind, global_density) if type_density else global_density
        out[b.name] = td * ld / global_density
    # exact renormalization (clip can bend the budget slightly)
    total = sum(b.params for b in budgets)
    got = sum(out[b.name] * b.params for b in budgets)
    if got > 0:
        scale = global_density * total / got
        for k in out:
            out[k] = float(np.clip(out[k] * scale, 0.02, 1.0))
    return out
