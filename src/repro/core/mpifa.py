"""MPIFA — the end-to-end, retraining-free compression driver (Alg. 3).

Pipeline per compressible linear, in block order:

  1. capture calibration inputs under BOTH data flows:
       X_o  from the dense model  (error-accumulation-free),
       X_u  from the compressed model built so far (degraded flow);
     accumulate ``XX^T`` (from X_u) and ``Y_t X^T`` with the Eq. 7 mixed
     target ``Y_t = lam*W X_o + (1-lam)*W X_u`` -- online, constant
     memory in #samples.
  2. prune:   (U, Vt) <- whitened SVD of W at the module's target rank
     (SVD-LLM "W" step; vanilla SVD / ASVD selectable for ablations).
  3. reconstruct ("M"):  U via Eq. 5, then Vt via Eq. 9 (optional).
  4. PIFA:  W' = U_r Vt_r -> (idx, W_p, C); because PIFA spends
     ``r^2 - r`` fewer parameters, the target rank at equal *density* is
     strictly higher than the (U, Vt) rank -- that is where MPIFA's
     quality gain over W+M comes from (Tables 2/5).
  5. (beyond paper) fold the output permutation into the consumer where
     the topology allows (core/folding.py).

The driver works against the Transformer harness (`block_apply` +
`tap`); it is family-generic for decoder-only models.  Expert-stacked
MoE weights and other archs compress through
:func:`compress_weights_only` (data-free / stats-provided), since the
paper's calibration protocol is defined for dense decoder LMs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import density as D
from repro.core import lowrank as LR
from repro.core.folding import fold_mlp
from repro.core.pifa import PifaFactors, pivoting_factorize
from repro.core.reconstruct import CalibStats, reconstruct_uv, solve_u_fullbatch
from repro.models.linear import linear_weight, lowrank_linear, pifa_linear

Pytree = Any


@dataclasses.dataclass
class MpifaConfig:
    """Knobs of Algorithm 3 + ablation switches (Table 5 rows)."""

    density: float = 0.55
    lam: float = 0.25               # Eq. 7 mix ratio
    alpha: float = 1e-3             # Eq. 9 ridge
    update_v: bool = True           # False for very large models (70B recipe)
    prune: str = "whiten"           # whiten | svd | asvd  (W step)
    reconstruct: str = "m"          # m | fullbatch | none (M / W+U / W)
    final_repr: str = "pifa"        # pifa | lowrank       (PIFA / no-PIFA)
    fold: bool = True               # beyond-paper permutation folding
    sequential_within_block: bool = True
    module_density: Optional[Mapping[str, float]] = None  # MPIFA_NS
    factor_dtype: Any = jnp.float32


def target_rank(cfg: MpifaConfig, m: int, n: int, name: str = "") -> int:
    rho = cfg.density
    if cfg.module_density and name in cfg.module_density:
        rho = cfg.module_density[name]
    if cfg.final_repr == "pifa":
        return D.rank_for_density_pifa(m, n, rho)
    return D.rank_for_density_lowrank(m, n, rho)


def _get(tree: Pytree, path: Tuple[str, ...]):
    for k in path:
        tree = tree[k]
    return tree


def _set(tree: Pytree, path: Tuple[str, ...], value) -> Pytree:
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _set(tree[path[0]], path[1:], value)
    return out


def compress_matrix(
    cfg: MpifaConfig,
    w: np.ndarray,
    rank: int,
    stats: Optional[CalibStats] = None,
    xs_fullbatch: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Steps 2-3 for one weight matrix: prune + reconstruct -> (U, Vt)."""
    if cfg.prune == "whiten" and stats is not None and stats.count > 0:
        u, vt = LR.whitened_svd(w, stats.xxt / max(stats.count, 1), rank)
    elif cfg.prune == "asvd" and stats is not None and stats.count > 0:
        act_scale = np.sqrt(np.clip(np.diag(stats.xxt) / max(stats.count, 1), 1e-12, None))
        u, vt = LR.activation_svd(w, act_scale, rank)
    else:
        u, vt = LR.svd_lowrank(w, rank)

    if cfg.reconstruct == "m" and stats is not None and stats.count > 0:
        u, vt = reconstruct_uv(w, u, vt, stats, update_v=cfg.update_v,
                               alpha=cfg.alpha)
    elif cfg.reconstruct == "fullbatch" and xs_fullbatch is not None:
        u = solve_u_fullbatch(w, vt, xs_fullbatch)
    return u, vt


def finalize_linear(cfg: MpifaConfig, u: np.ndarray, vt: np.ndarray,
                    bias=None) -> Pytree:
    """Step 4: store as PIFA (lossless re-encoding) or keep (U, Vt)."""
    if cfg.final_repr == "pifa":
        w_prime = u @ vt
        f = pivoting_factorize(w_prime, rank=u.shape[1], dtype=cfg.factor_dtype)
        return pifa_linear(f, bias=bias, dtype=cfg.factor_dtype)
    return lowrank_linear(u, vt, bias=bias, dtype=cfg.factor_dtype)


# ---------------------------------------------------------------------------
# The calibrated, flow-correct driver for the Transformer harness.
# ---------------------------------------------------------------------------

def compress_transformer(
    model,
    params: Pytree,
    calib_batches: Sequence[jax.Array],
    cfg: MpifaConfig,
    *,
    progress: Optional[Callable[[str], None]] = None,
) -> Pytree:
    """Run MPIFA over every block of a Transformer-harness model.

    calib_batches: list of token arrays (b, s) -- processed sequentially
    (the "online" property: stats are O(n^2), never O(samples)).
    Returns compressed params (blocks in list form).
    """
    note = progress or (lambda s: None)
    cfgm = model.cfg
    params_u = model.unstack_blocks(params)
    # hidden-state streams at the current block boundary, per calib batch
    hs_o = [model.embed_tokens(params, t) for t in calib_batches]
    hs_u = [h for h in hs_o]  # embeddings are not compressed: same start

    infos = model.linears_in_block()
    groups: List[List] = []
    if cfg.sequential_within_block:
        attn_in = [i for i in infos if i.path[0] == "attn" and i.path[1] != "o"]
        attn_o = [i for i in infos if i.path == ("attn", "o")]
        mlp_in = [i for i in infos if i.path[0] == "mlp" and i.path[1] != "down"]
        mlp_dn = [i for i in infos if i.path == ("mlp", "down")]
        groups = [g for g in (attn_in, attn_o, mlp_in, mlp_dn) if g]
    else:
        groups = [infos]

    for bi in range(model.num_blocks()):
        bp_dense = model.block_params(params, bi)
        win = jnp.int32(cfgm.window_for_layer(bi))
        for gi, group in enumerate(groups):
            wanted = {"/".join(i.path) for i in group}
            stats = {"/".join(i.path): CalibStats(i.in_dim, i.out_dim)
                     for i in group}
            weights = {"/".join(i.path):
                       np.asarray(linear_weight(_get(bp_dense, i.path)),
                                  dtype=np.float64) for i in group}
            xs_store: Dict[str, list] = {k: [] for k in wanted} \
                if cfg.reconstruct == "fullbatch" else {}

            bp_u = params_u["blocks"][bi]
            for s_i in range(len(calib_batches)):
                cap_o: Dict[str, np.ndarray] = {}
                cap_u: Dict[str, np.ndarray] = {}

                def tap_o(name, x, cap=cap_o):
                    if name in wanted:
                        cap[name] = np.asarray(x, dtype=np.float64)

                def tap_u(name, x, cap=cap_u):
                    if name in wanted:
                        cap[name] = np.asarray(x, dtype=np.float64)

                model.block_apply(bp_dense, hs_o[s_i], window=win, tap=tap_o)
                model.block_apply(bp_u, hs_u[s_i], window=win, tap=tap_u)
                for name in wanted:
                    st = stats[name]
                    st.update_inputs(weights[name], cap_o[name], cap_u[name],
                                     cfg.lam)
                    if xs_store:
                        xs_store[name].append(
                            cap_u[name].reshape(-1, st.n_in))

            for info in group:
                name = "/".join(info.path)
                w = weights[name]
                r = target_rank(cfg, info.out_dim, info.in_dim,
                                name=f"block{bi}/{name}")
                xfb = (np.concatenate(xs_store[name], axis=0).T
                       if xs_store else None)
                u, vt = compress_matrix(cfg, w, r, stats[name], xfb)
                old = _get(bp_u, info.path)
                bias = old.get("b")
                new_lin = finalize_linear(cfg, u, vt, bias=bias)
                bp_u = _set(bp_u, info.path, new_lin)
            params_u["blocks"][bi] = bp_u
            note(f"block {bi} group {gi} done")

        # advance both flows past this block
        bp_u = params_u["blocks"][bi]
        if cfg.fold and cfg.final_repr == "pifa" and "mlp" in bp_u:
            mlp = dict(bp_u["mlp"])
            up, down, gate = fold_mlp(mlp["up"], mlp["down"], mlp.get("gate"))
            mlp["up"], mlp["down"] = up, down
            if gate is not None:
                mlp["gate"] = gate
            bp_u = dict(bp_u)
            bp_u["mlp"] = mlp
            params_u["blocks"][bi] = bp_u
        for s_i in range(len(calib_batches)):
            hs_o[s_i], _ = model.block_apply(bp_dense, hs_o[s_i], window=win)
            hs_u[s_i], _ = model.block_apply(bp_u, hs_u[s_i], window=win)
        note(f"block {bi} complete")
    return params_u


# ---------------------------------------------------------------------------
# Weight-level compression for arbitrary archs (MoE experts, mamba
# projections, ...): data-free or with caller-provided stats.
# ---------------------------------------------------------------------------

def compress_linear_params(cfg: MpifaConfig, p: Pytree,
                           stats: Optional[CalibStats] = None,
                           name: str = "") -> Pytree:
    w = np.asarray(linear_weight(p), dtype=np.float64)
    m, n = w.shape
    r = target_rank(cfg, m, n, name=name)
    u, vt = compress_matrix(cfg, w, r, stats)
    return finalize_linear(cfg, u, vt, bias=p.get("b"))


def compress_expert_params(cfg: MpifaConfig, p: Pytree, name: str = "") -> Pytree:
    """Stacked (E, out, in) expert weights -> stacked PIFA factors."""
    w = np.asarray(p["w"], dtype=np.float64)
    e, m, n = w.shape
    r = target_rank(cfg, m, n, name=name)
    wps, cs, invs = [], [], []
    for ei in range(e):
        u, vt = compress_matrix(cfg, w[ei], r)
        if cfg.final_repr == "pifa":
            f = pivoting_factorize(u @ vt, rank=r, dtype=cfg.factor_dtype)
            wps.append(f.wp); cs.append(f.c); invs.append(f.inv_perm)
        else:
            wps.append(jnp.asarray(u, dtype=cfg.factor_dtype))
            cs.append(jnp.asarray(vt, dtype=cfg.factor_dtype))
    if cfg.final_repr == "pifa":
        return {"wp": jnp.stack(wps), "c": jnp.stack(cs),
                "inv_perm": jnp.stack(invs)}
    return {"u": jnp.stack(wps), "vt": jnp.stack(cs)}
