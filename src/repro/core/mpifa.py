"""MPIFA — the end-to-end, retraining-free compression driver (Alg. 3).

Pipeline per compressible linear, in block order:

  1. capture calibration inputs under BOTH data flows:
       X_o  from the dense model  (error-accumulation-free),
       X_u  from the compressed model built so far (degraded flow);
     accumulate ``XX^T`` (from X_u) and ``Y_t X^T`` with the Eq. 7 mixed
     target ``Y_t = lam*W X_o + (1-lam)*W X_u`` -- online, constant
     memory in #samples.
  2. prune:   (U, Vt) <- whitened SVD of W at the module's target rank
     (SVD-LLM "W" step; vanilla SVD / ASVD selectable for ablations).
  3. reconstruct ("M"):  U via Eq. 5, then Vt via Eq. 9 (optional).
  4. PIFA:  W' = U_r Vt_r -> (idx, W_p, C); because PIFA spends
     ``r^2 - r`` fewer parameters, the target rank at equal *density* is
     strictly higher than the (U, Vt) rank -- that is where MPIFA's
     quality gain over W+M comes from (Tables 2/5).
  5. (beyond paper) fold the output permutation into the consumer where
     the topology allows (core/folding.py).

The driver works against the Transformer harness (`block_apply` +
`tap`); it is family-generic for decoder-only models.  Expert-stacked
MoE weights and other archs compress through
:func:`compress_weights_only` (data-free / stats-provided), since the
paper's calibration protocol is defined for dense decoder LMs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import density as D
from repro.core import lowrank as LR
from repro.core.folding import fold_mlp
from repro.core.pifa import PifaFactors, pivoting_factorize
from repro.core.reconstruct import CalibStats, reconstruct_uv, solve_u_fullbatch
from repro.models.linear import (linear_kind, linear_weight, lowrank_linear,
                                 pifa_linear)

Pytree = Any


@dataclasses.dataclass
class MpifaConfig:
    """Knobs of Algorithm 3 + ablation switches (Table 5 rows)."""

    density: float = 0.55
    lam: float = 0.25               # Eq. 7 mix ratio
    alpha: float = 1e-3             # Eq. 9 ridge
    update_v: bool = True           # False for very large models (70B recipe)
    prune: str = "whiten"           # whiten | svd | asvd  (W step)
    reconstruct: str = "m"          # m | fullbatch | none (M / W+U / W)
    final_repr: str = "pifa"        # pifa | lowrank       (PIFA / no-PIFA)
    fold: bool = True               # beyond-paper permutation folding
    sequential_within_block: bool = True
    module_density: Optional[Mapping[str, float]] = None  # MPIFA_NS
    factor_dtype: Any = jnp.float32


def target_rank(cfg: MpifaConfig, m: int, n: int, name: str = "") -> int:
    rho = cfg.density
    if cfg.module_density and name in cfg.module_density:
        rho = cfg.module_density[name]
    if cfg.final_repr == "pifa":
        return D.rank_for_density_pifa(m, n, rho)
    return D.rank_for_density_lowrank(m, n, rho)


def _get(tree: Pytree, path: Tuple[str, ...]):
    for k in path:
        tree = tree[k]
    return tree


def _set(tree: Pytree, path: Tuple[str, ...], value) -> Pytree:
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = _set(tree[path[0]], path[1:], value)
    return out


def compress_matrix(
    cfg: MpifaConfig,
    w: np.ndarray,
    rank: int,
    stats: Optional[CalibStats] = None,
    xs_fullbatch: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Steps 2-3 for one weight matrix: prune + reconstruct -> (U, Vt)."""
    if cfg.prune == "whiten" and stats is not None and stats.count > 0:
        u, vt = LR.whitened_svd(w, stats.xxt / max(stats.count, 1), rank)
    elif cfg.prune == "asvd" and stats is not None and stats.count > 0:
        act_scale = np.sqrt(np.clip(np.diag(stats.xxt) / max(stats.count, 1), 1e-12, None))
        u, vt = LR.activation_svd(w, act_scale, rank)
    else:
        u, vt = LR.svd_lowrank(w, rank)

    if cfg.reconstruct == "m" and stats is not None and stats.count > 0:
        u, vt = reconstruct_uv(w, u, vt, stats, update_v=cfg.update_v,
                               alpha=cfg.alpha)
    elif cfg.reconstruct == "fullbatch" and xs_fullbatch is not None:
        u = solve_u_fullbatch(w, vt, xs_fullbatch)
    return u, vt


def finalize_linear(cfg: MpifaConfig, u: np.ndarray, vt: np.ndarray,
                    bias=None) -> Pytree:
    """Step 4: store as PIFA (lossless re-encoding) or keep (U, Vt)."""
    if cfg.final_repr == "pifa":
        w_prime = u @ vt
        f = pivoting_factorize(w_prime, rank=u.shape[1], dtype=cfg.factor_dtype)
        return pifa_linear(f, bias=bias, dtype=cfg.factor_dtype)
    return lowrank_linear(u, vt, bias=bias, dtype=cfg.factor_dtype)


# ---------------------------------------------------------------------------
# The calibrated, flow-correct driver for the Transformer harness.
# ---------------------------------------------------------------------------

def compress_transformer(
    model,
    params: Pytree,
    calib_batches: Sequence[jax.Array],
    cfg: MpifaConfig,
    *,
    progress: Optional[Callable[[str], None]] = None,
) -> Pytree:
    """Run MPIFA over every block of a Transformer-harness model.

    calib_batches: list of token arrays (b, s) -- processed sequentially
    (the "online" property: stats are O(n^2), never O(samples)).
    Returns compressed params (blocks in list form).
    """
    note = progress or (lambda s: None)
    cfgm = model.cfg
    params_u = model.unstack_blocks(params)
    # hidden-state streams at the current block boundary, per calib batch
    hs_o = [model.embed_tokens(params, t) for t in calib_batches]
    hs_u = [h for h in hs_o]  # embeddings are not compressed: same start

    infos = model.linears_in_block()
    groups: List[List] = []
    if cfg.sequential_within_block:
        attn_in = [i for i in infos if i.path[0] == "attn" and i.path[1] != "o"]
        attn_o = [i for i in infos if i.path == ("attn", "o")]
        mlp_in = [i for i in infos if i.path[0] == "mlp" and i.path[1] != "down"]
        mlp_dn = [i for i in infos if i.path == ("mlp", "down")]
        groups = [g for g in (attn_in, attn_o, mlp_in, mlp_dn) if g]
    else:
        groups = [infos]

    for bi in range(model.num_blocks()):
        bp_dense = model.block_params(params, bi)
        win = jnp.int32(cfgm.window_for_layer(bi))
        for gi, group in enumerate(groups):
            wanted = {"/".join(i.path) for i in group}
            stats = {"/".join(i.path): CalibStats(i.in_dim, i.out_dim)
                     for i in group}
            weights = {"/".join(i.path):
                       np.asarray(linear_weight(_get(bp_dense, i.path)),
                                  dtype=np.float64) for i in group}
            xs_store: Dict[str, list] = {k: [] for k in wanted} \
                if cfg.reconstruct == "fullbatch" else {}

            bp_u = params_u["blocks"][bi]
            for s_i in range(len(calib_batches)):
                cap_o: Dict[str, np.ndarray] = {}
                cap_u: Dict[str, np.ndarray] = {}

                def tap_o(name, x, cap=cap_o):
                    if name in wanted:
                        cap[name] = np.asarray(x, dtype=np.float64)

                def tap_u(name, x, cap=cap_u):
                    if name in wanted:
                        cap[name] = np.asarray(x, dtype=np.float64)

                model.block_apply(bp_dense, hs_o[s_i], window=win, tap=tap_o)
                model.block_apply(bp_u, hs_u[s_i], window=win, tap=tap_u)
                for name in wanted:
                    st = stats[name]
                    st.update_inputs(weights[name], cap_o[name], cap_u[name],
                                     cfg.lam)
                    if xs_store:
                        xs_store[name].append(
                            cap_u[name].reshape(-1, st.n_in))

            for info in group:
                name = "/".join(info.path)
                w = weights[name]
                r = target_rank(cfg, info.out_dim, info.in_dim,
                                name=f"block{bi}/{name}")
                xfb = (np.concatenate(xs_store[name], axis=0).T
                       if xs_store else None)
                u, vt = compress_matrix(cfg, w, r, stats[name], xfb)
                old = _get(bp_u, info.path)
                bias = old.get("b")
                new_lin = finalize_linear(cfg, u, vt, bias=bias)
                bp_u = _set(bp_u, info.path, new_lin)
            params_u["blocks"][bi] = bp_u
            note(f"block {bi} group {gi} done")

        # advance both flows past this block
        bp_u = params_u["blocks"][bi]
        if cfg.fold and cfg.final_repr == "pifa" and "mlp" in bp_u:
            mlp = dict(bp_u["mlp"])
            up, down, gate = fold_mlp(mlp["up"], mlp["down"], mlp.get("gate"))
            mlp["up"], mlp["down"] = up, down
            if gate is not None:
                mlp["gate"] = gate
            bp_u = dict(bp_u)
            bp_u["mlp"] = mlp
            params_u["blocks"][bi] = bp_u
        for s_i in range(len(calib_batches)):
            hs_o[s_i], _ = model.block_apply(bp_dense, hs_o[s_i], window=win)
            hs_u[s_i], _ = model.block_apply(bp_u, hs_u[s_i], window=win)
        note(f"block {bi} complete")
    return params_u


# ---------------------------------------------------------------------------
# Weight-level compression for arbitrary archs (MoE experts, mamba
# projections, ...): data-free or with caller-provided stats.
# ---------------------------------------------------------------------------

def compress_linear_params(cfg: MpifaConfig, p: Pytree,
                           stats: Optional[CalibStats] = None,
                           name: str = "") -> Pytree:
    w = np.asarray(linear_weight(p), dtype=np.float64)
    m, n = w.shape
    r = target_rank(cfg, m, n, name=name)
    u, vt = compress_matrix(cfg, w, r, stats)
    return finalize_linear(cfg, u, vt, bias=p.get("b"))


# ---------------------------------------------------------------------------
# Rank padding + bucketed restacking (the MPIFA_NS serving fast path).
#
# Heterogeneous per-module densities (MPIFA_NS) give every block a
# different PIFA rank, so list-form blocks cannot be stacked for the
# scanned KV-cache serving path and decoding degraded to an O(T^2)
# full-recompute loop.  Zero-padding restores uniformity EXACTLY:
#
#   * wp gains zero rows        -> the extra y_p entries are exactly 0
#   * c  gains zero columns     -> the zero y_p entries contribute 0
#   * c  gains zero rows        -> the extra y_np entries are never
#                                  gathered (inv_perm only addresses
#                                  real outputs)
#   * inv_perm entries >= r shift by (R - r): y_np now starts at R
#
# (the same argument `kernels/pifa_matmul/ops.py` uses for MXU block
# alignment, applied at the layer level).  Blocks padded to a common
# per-path (R, M_np) share one pytree structure and re-stack; contiguous
# runs of blocks with similar ranks can form separate BUCKETS to bound
# the padding FLOP waste (DP-partitioned below).
# ---------------------------------------------------------------------------

def _pad2(a: jax.Array, rows: int, cols: int) -> jax.Array:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _cat_position_map(r: int, R: int, m: int) -> np.ndarray:
    """Old concat position k -> padded concat position (y_np shifts)."""
    pos = np.arange(m)
    return np.where(pos < r, pos, pos + (R - r))


def pifa_rank(p: Pytree) -> Tuple[int, int]:
    """(rank, non-pivot rows) of a pifa / pifa_folded linear."""
    return int(p["wp"].shape[0]), int(p["c"].shape[0])


def pad_pifa_rank(p: Pytree, R: int, Mnp: int) -> Pytree:
    """Zero-pad a pifa linear (with inv_perm) to rank R / Mnp c-rows."""
    r, mnp = pifa_rank(p)
    assert R >= r and Mnp >= mnp, (r, mnp, R, Mnp)
    q = dict(p)
    q["wp"] = jnp.pad(p["wp"], ((0, R - r), (0, 0)))
    q["c"] = _pad2(p["c"], Mnp, R)
    inv = np.asarray(p["inv_perm"])
    q["inv_perm"] = jnp.asarray(np.where(inv >= r, inv + (R - r), inv),
                                dtype=jnp.int32)
    return q


def pad_lowrank_rank(p: Pytree, R: int) -> Pytree:
    r = p["u"].shape[1]
    assert R >= r
    q = dict(p)
    q["u"] = jnp.pad(p["u"], ((0, 0), (0, R - r)))
    q["vt"] = jnp.pad(p["vt"], ((0, R - r), (0, 0)))
    return q


def _scatter_rows(a: jax.Array, posmap: np.ndarray, new_len: int) -> jax.Array:
    out = jnp.zeros((new_len,) + a.shape[1:], dtype=a.dtype)
    return out.at[jnp.asarray(posmap)].set(a)


def _scatter_cols(a: jax.Array, posmap: np.ndarray, new_len: int) -> jax.Array:
    out = jnp.zeros(a.shape[:-1] + (new_len,), dtype=a.dtype)
    return out.at[..., jnp.asarray(posmap)].set(a)


def _scatter_output_positions(p: Pytree, posmap: np.ndarray,
                              new_len: int) -> Pytree:
    """Producer now emits its outputs at scattered positions (length
    new_len, zeros/garbage-masked elsewhere).  Used for the gate of a
    folded MLP whose `up` grew padded concat slots."""
    k = linear_kind(p)
    q = dict(p)
    if k == "dense":
        q["w"] = _scatter_rows(p["w"], posmap, new_len)
    elif k == "lowrank":
        q["u"] = _scatter_rows(p["u"], posmap, new_len)
    elif k == "pifa":
        # inserted slots gather cat entry 0 — finite garbage, multiplied
        # by up's EXACT zero at the same slot, so the product is 0.0
        q["inv_perm"] = _scatter_rows(p["inv_perm"].astype(jnp.int32),
                                      posmap, new_len)
    else:
        raise ValueError("cannot scatter a folded pifa producer")
    if "b" in p:
        q["b"] = _scatter_rows(p["b"], posmap, new_len)
    return q


def _scatter_input_positions(p: Pytree, posmap: np.ndarray,
                             new_len: int) -> Pytree:
    """Consumer reads its inputs from scattered positions (padded slots
    hit zero weight columns)."""
    k = linear_kind(p)
    q = dict(p)
    if k == "dense":
        q["w"] = _scatter_cols(p["w"], posmap, new_len)
    elif k == "lowrank":
        q["vt"] = _scatter_cols(p["vt"], posmap, new_len)
    else:
        q["wp"] = _scatter_cols(p["wp"], posmap, new_len)
    return q


def _pad_linear(p: Pytree, target: Tuple[int, int]) -> Pytree:
    k = linear_kind(p)
    if k == "pifa":
        return pad_pifa_rank(p, target[0], target[1])
    if k == "lowrank":
        return pad_lowrank_rank(p, target[0])
    if k == "dense":
        return p
    raise ValueError("pad a folded layer through pad_mlp_group")


def _linear_target(p: Pytree) -> Optional[Tuple[int, int]]:
    k = linear_kind(p)
    if k in ("pifa", "pifa_folded"):
        return pifa_rank(p)
    if k == "lowrank":
        return (int(p["u"].shape[1]), 0)
    return (0, 0)  # dense: nothing to pad


def pad_mlp_group(mlp: Pytree, targets: Mapping[str, Tuple[int, int]]
                  ) -> Pytree:
    """Pad an MLP's linears coordinately when `up` is permutation-folded.

    A folded `up` emits concat order directly, so padding its rank
    inserts zero slots MID-STREAM (positions [r, R)); the gate's output
    scatter and down's input scatter must move in lockstep.  Lossless:
    inserted slots carry up==0.0 exactly, so gate garbage there
    multiplies to 0.0 and down's zero columns ignore them.
    """
    up = mlp["up"]
    out = dict(mlp)
    if linear_kind(up) != "pifa_folded":
        for name in ("up", "down", "gate"):
            if name in mlp:
                out[name] = _pad_linear(mlp[name], targets[name])
        return out

    r_u, mnp_u = pifa_rank(up)
    m_u = r_u + mnp_u
    R_u, Mnp_u = targets["up"]
    L = R_u + Mnp_u
    posmap = _cat_position_map(r_u, R_u, m_u)

    new_up = dict(up)
    new_up["wp"] = jnp.pad(up["wp"], ((0, R_u - r_u), (0, 0)))
    new_up["c"] = _pad2(up["c"], Mnp_u, R_u)
    if "b" in up:
        new_up["b"] = _scatter_rows(up["b"], posmap, L)
    out["up"] = new_up

    if "gate" in mlp:
        g = _pad_linear(mlp["gate"], targets["gate"]) \
            if linear_kind(mlp["gate"]) != "dense" else mlp["gate"]
        out["gate"] = _scatter_output_positions(g, posmap, L)

    down = _scatter_input_positions(mlp["down"], posmap, L)
    if linear_kind(down) != "dense":
        down = _pad_linear(down, targets["down"])
    out["down"] = down
    return out


def _walk_linears(tree: Pytree, prefix: Tuple[str, ...] = ()):
    """Yield (path, linear-params) for every linear dict in a block."""
    if isinstance(tree, Mapping):
        if any(k in tree for k in ("w", "u", "wp")):
            yield prefix, tree
            return
        for k in sorted(tree):
            yield from _walk_linears(tree[k], prefix + (k,))


def block_rank_signature(bp: Pytree) -> Dict[Tuple[str, ...], Tuple]:
    """{path: (kind, (r, mnp))} per linear; expert-stacked (3-D) weights
    are 'opaque:<shapes>' — not paddable, bucketable only when their
    shapes already agree across blocks (the kind string then matches)."""
    sig = {}
    for path, p in _walk_linears(bp):
        k = linear_kind(p)
        main = p["w"] if k == "dense" else (p["u"] if k == "lowrank"
                                            else p["wp"])
        if main.ndim != 2:
            shapes = tuple(sorted((kk, tuple(v.shape))
                                  for kk, v in p.items()))
            sig[path] = (f"opaque:{shapes}", (0, 0))
        else:
            sig[path] = (k, _linear_target(p))
    return sig


def pad_blocks_to_targets(blocks: Sequence[Pytree],
                          targets: Mapping[Tuple[str, ...], Tuple[int, int]]
                          ) -> List[Pytree]:
    """Pad every block's linears to per-path targets; MLPs with a
    folded `up` are padded as a coordinated group."""
    out = []
    for bp in blocks:
        new_bp = bp
        mlp_done = False
        for path, p in list(_walk_linears(bp)):
            if path and path[0] == "mlp":
                if mlp_done:
                    continue
                mlp_targets = {name: targets.get(("mlp", name), (0, 0))
                               for name in ("up", "down", "gate")}
                new_bp = _set(new_bp, ("mlp",),
                              pad_mlp_group(new_bp["mlp"], mlp_targets))
                mlp_done = True
            elif (linear_kind(p) in ("pifa", "lowrank")
                  and p[("u" if "u" in p else "wp")].ndim == 2):
                new_bp = _set(new_bp, path,
                              _pad_linear(_get(new_bp, path), targets[path]))
        out.append(new_bp)
    return out


def _segment_targets(signatures) -> Dict[Tuple[str, ...], Tuple[int, int]]:
    targets: Dict[Tuple[str, ...], Tuple[int, int]] = {}
    for sig in signatures:
        for path, (_, t) in sig.items():
            r0, m0 = targets.get(path, (0, 0))
            targets[path] = (max(r0, t[0]), max(m0, t[1]))
    return targets


def _segment_cost(signatures) -> float:
    """Padded parameter count of one bucket (proxy for FLOP waste)."""
    targets = _segment_targets(signatures)
    cost = 0.0
    for sig in signatures:
        for path, (kind, _) in sig.items():
            R, Mnp = targets[path]
            if kind in ("pifa", "pifa_folded"):
                cost += R * (Mnp + 1)  # wp rows scale with R; c is Mnp x R
            elif kind == "lowrank":
                cost += 2 * R
    return cost


def bucket_boundaries(blocks: Sequence[Pytree], max_buckets: int = 1,
                      granularity: int = 1
                      ) -> Optional[List[Tuple[int, int]]]:
    """Contiguous [start, end) segments minimizing padded-rank waste.

    ``granularity`` forces every boundary onto a multiple of that many
    layers — ring-cache (local:global) archs scan in stages of
    ``ratio + 1`` layers, so their buckets must be stage-aligned.  A
    layer count not divisible by ``granularity`` falls back to 1.

    Returns None when blocks cannot be unified (different pytree
    structure or mixed representation kinds at the same path).
    """
    sigs = []
    ref_paths = None
    for bp in blocks:
        sig = block_rank_signature(bp)
        if ref_paths is None:
            ref_paths = set(sig)
        elif set(sig) != ref_paths:
            return None
        sigs.append(sig)
    for path in ref_paths:
        kinds = {s[path][0] for s in sigs}
        if len(kinds) > 1:
            return None
    n = len(blocks)
    g = max(1, granularity)
    if n % g != 0:
        g = 1
    k_max = max(1, min(max_buckets, n // g))
    if k_max == 1:
        return [(0, n)]
    # DP over contiguous partitions; small per-bucket penalty prefers
    # fewer scan dispatches when the rank spread doesn't pay for a split.
    # Only granularity-aligned split points are considered.
    seg = {(i, j): _segment_cost(sigs[i:j])
           for i in range(0, n, g) for j in range(i + g, n + 1, g)}
    penalty = 0.02 * seg[(0, n)] / n
    best: Dict[Tuple[int, int], Tuple[float, List[Tuple[int, int]]]] = {}

    def solve(i: int, k: int):
        if i == n:
            return 0.0, []
        if (i, k) in best:
            return best[(i, k)]
        if k == 1:
            res = (seg[(i, n)] + penalty, [(i, n)])
        else:
            res = None
            for j in range(i + g, n + 1, g):
                tail_cost, tail = solve(j, k - 1) if j < n else (0.0, [])
                cand = (seg[(i, j)] + penalty + tail_cost,
                        [(i, j)] + tail)
                if res is None or cand[0] < res[0]:
                    res = cand
        best[(i, k)] = res
        return res

    _, parts = solve(0, k_max)
    return parts


def pad_blocks_bucketed(blocks: Sequence[Pytree], max_buckets: int = 1,
                        granularity: int = 1
                        ) -> Optional[List[List[Pytree]]]:
    """Partition list-form blocks into contiguous buckets and zero-pad
    each bucket to uniform per-path ranks; every bucket then stacks.
    Returns None when padding cannot unify the blocks."""
    parts = bucket_boundaries(blocks, max_buckets, granularity)
    if parts is None:
        return None
    out = []
    for (i, j) in parts:
        sigs = [block_rank_signature(b) for b in blocks[i:j]]
        targets = _segment_targets(sigs)
        out.append(pad_blocks_to_targets(blocks[i:j], targets))
    return out


def try_stack_blocks(blocks: Sequence[Pytree]) -> Optional[Pytree]:
    """Stack list-form blocks when structure and shapes already agree
    (uniform-density compression); None otherwise."""
    ref = jax.tree_util.tree_structure(blocks[0])
    shapes0 = [l.shape for l in jax.tree_util.tree_leaves(blocks[0])]
    for b in blocks[1:]:
        if (jax.tree_util.tree_structure(b) != ref
                or [l.shape for l in jax.tree_util.tree_leaves(b)] != shapes0):
            return None
    return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *blocks)


def pad_and_stack_blocks(blocks: Sequence[Pytree]) -> Optional[Pytree]:
    """Single-bucket restack: zero-pad heterogeneous-rank list-form
    blocks to uniform per-path ranks and stack along a new leading
    layer dim (the form every family's `lax.scan` serving path
    consumes).  None when the blocks cannot be unified."""
    buckets = pad_blocks_bucketed(blocks, 1)
    if buckets is None:
        return None
    try:
        return jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *buckets[0])
    except ValueError:
        return None  # non-factor leaves disagree; cannot unify


def compress_expert_params(cfg: MpifaConfig, p: Pytree, name: str = "") -> Pytree:
    """Stacked (E, out, in) expert weights -> stacked PIFA factors."""
    w = np.asarray(p["w"], dtype=np.float64)
    e, m, n = w.shape
    r = target_rank(cfg, m, n, name=name)
    wps, cs, invs = [], [], []
    for ei in range(e):
        u, vt = compress_matrix(cfg, w[ei], r)
        if cfg.final_repr == "pifa":
            f = pivoting_factorize(u @ vt, rank=r, dtype=cfg.factor_dtype)
            wps.append(f.wp); cs.append(f.c); invs.append(f.inv_perm)
        else:
            wps.append(jnp.asarray(u, dtype=cfg.factor_dtype))
            cs.append(jnp.asarray(vt, dtype=cfg.factor_dtype))
    if cfg.final_repr == "pifa":
        return {"wp": jnp.stack(wps), "c": jnp.stack(cs),
                "inv_perm": jnp.stack(invs)}
    return {"u": jnp.stack(wps), "vt": jnp.stack(cs)}
