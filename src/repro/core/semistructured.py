"""2:4 semi-structured pruning baselines (quality comparison, Table 3).

The paper compares MPIFA against N:M pruning: magnitude (Zhu & Gupta),
Wanda (|W| * ||x||) and RIA ((|W|/rowsum + |W|/colsum) * ||x||^0.5).

On TPU there is no sparse-tensor-core analogue of Ampere 2:4 -- these
masks give *zero* speedup here (the dense GEMM runs anyway), which is
exactly the portability argument of the paper's Table 1.  We implement
them as quality baselines only; see DESIGN.md section 2.
"""
from __future__ import annotations

from typing import Any, Optional

import numpy as np

__all__ = ["nm_mask", "magnitude_score", "wanda_score", "ria_score",
           "prune_nm", "check_nm"]


def magnitude_score(w: Any, act_norm: Optional[Any] = None) -> np.ndarray:
    return np.abs(np.asarray(w, dtype=np.float64))


def wanda_score(w: Any, act_norm: Any) -> np.ndarray:
    """|W_ij| * ||x_j||_2 (Sun et al., 2024)."""
    w = np.asarray(w, dtype=np.float64)
    a = np.asarray(act_norm, dtype=np.float64)
    return np.abs(w) * a[None, :]


def ria_score(w: Any, act_norm: Any, a: float = 0.5) -> np.ndarray:
    """Relative importance + activation (Zhang et al., 2024).

    score = (|W_ij| / sum_j |W_ij| + |W_ij| / sum_i |W_ij|) * ||x_j||^a
    """
    w = np.abs(np.asarray(w, dtype=np.float64))
    act = np.asarray(act_norm, dtype=np.float64)
    row = w.sum(axis=1, keepdims=True) + 1e-12
    col = w.sum(axis=0, keepdims=True) + 1e-12
    rel = w / row + w / col
    return rel * np.power(np.maximum(act, 1e-12), a)[None, :]


def nm_mask(score: np.ndarray, n: int = 2, m: int = 4) -> np.ndarray:
    """Keep the top-``n`` of every ``m`` consecutive input-dim entries."""
    out_dim, in_dim = score.shape
    pad = (-in_dim) % m
    if pad:
        score = np.pad(score, ((0, 0), (0, pad)), constant_values=-np.inf)
    g = score.reshape(out_dim, -1, m)
    kth = np.argsort(g, axis=-1)[..., ::-1][..., :n]
    mask = np.zeros_like(g, dtype=bool)
    np.put_along_axis(mask, kth, True, axis=-1)
    mask = mask.reshape(out_dim, -1)[:, :in_dim]
    return mask


def prune_nm(w: Any, scorer=magnitude_score, act_norm: Optional[Any] = None,
             n: int = 2, m: int = 4) -> np.ndarray:
    w = np.asarray(w, dtype=np.float64)
    return w * nm_mask(scorer(w, act_norm), n=n, m=m)


def check_nm(w: Any, n: int = 2, m: int = 4) -> bool:
    """Every group of m consecutive entries has <= n nonzeros."""
    w = np.asarray(w)
    out_dim, in_dim = w.shape
    pad = (-in_dim) % m
    if pad:
        w = np.pad(w, ((0, 0), (0, pad)))
    g = (w.reshape(out_dim, -1, m) != 0).sum(axis=-1)
    return bool((g <= n).all())
