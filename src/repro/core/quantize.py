"""Quantized PIFA: int8 factors on top of the lossless re-encoding.

Beyond-paper composition (the paper cites Saha et al. for low-rank +
low-precision): PIFA's factors `wp`/`c` quantize independently with
per-output-channel absmax scales.  Because PIFA is *lossless* given the
low-rank matrix, the only quantization error is the usual int8 rounding
of the factors — and `c`'s entries are O(1) combination coefficients,
which quantize gracefully.

Total bytes at density rho: ~rho * m*n * 1B + scales — i.e. another
~2x over bf16 PIFA (0.55 density -> 0.28x dense bf16 bytes).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.linear import Params, linear_kind

__all__ = ["quantize_pifa", "dequantize_pifa", "apply_linear_q8",
           "q8_param_bytes"]


def _q8(w: jax.Array):
    """Per-row (output-channel) absmax int8 quantization."""
    w = jnp.asarray(w, jnp.float32)
    scale = jnp.max(jnp.abs(w), axis=-1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def quantize_pifa(p: Params) -> Dict[str, jax.Array]:
    """PIFA params {wp, c[, inv_perm, b]} -> int8 variant."""
    assert linear_kind(p) in ("pifa", "pifa_folded"), linear_kind(p)
    out: Dict[str, jax.Array] = {}
    out["wp_q"], out["wp_s"] = _q8(p["wp"])
    out["c_q"], out["c_s"] = _q8(p["c"])
    for k in ("inv_perm", "b"):
        if k in p:
            out[k] = p[k]
    return out


def dequantize_pifa(q: Dict[str, jax.Array]) -> Params:
    p: Params = {
        "wp": q["wp_q"].astype(jnp.float32) * q["wp_s"],
        "c": q["c_q"].astype(jnp.float32) * q["c_s"],
    }
    for k in ("inv_perm", "b"):
        if k in q:
            p[k] = q[k]
    return p


def apply_linear_q8(q: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    """Algorithm 2 with on-the-fly dequantization (weights stay int8 in
    HBM; dequant fuses into the GEMM epilogue on TPU)."""
    dt = x.dtype
    wp = (q["wp_q"].astype(dt) * q["wp_s"].astype(dt))
    c = (q["c_q"].astype(dt) * q["c_s"].astype(dt))
    yp = x @ wp.T
    ynp = yp @ c.T
    y = jnp.concatenate([yp, ynp], axis=-1)
    if "inv_perm" in q:
        y = jnp.take(y, q["inv_perm"], axis=-1)
    if "b" in q:
        y = y + q["b"].astype(dt)
    return y


def q8_param_bytes(q: Dict[str, jax.Array]) -> int:
    return sum(int(np.prod(v.shape)) * v.dtype.itemsize
               for v in jax.tree.leaves(q))
