"""Permutation folding — beyond-paper optimization #1 (DESIGN.md §6).

A PIFA layer natively ends with a gather ``y = concat([y_p, y_np])[inv_perm]``
(Algorithm 2 steps 4-5).  That gather is pure data movement on the
layer-output channel dim; whenever the *consumer* of those channels is
itself a linear map (possibly through channel-wise elementwise ops), the
permutation can be absorbed into the consumer's weights at compression
time:

    y1 = ycat[inv_perm]             (producer gather)
    y2 = y1 @ Wq.T                  (consumer)
  ==>
    y2 = ycat @ Wq[:, perm].T       (gather deleted, Wq columns permuted)

because ``(Wq P)[: , k] = Wq[:, perm[k]]`` for the permutation matrix P
with ``(P ycat)[j] = ycat[inv_perm[j]]``.

We fold MLPs (the dominant parameter mass):

  * non-gated  ``down(act(up(x)))``      -> up's gather deleted.
  * gated      ``down(act(gate(x)) * up(x))`` -> up's gather deleted and
    gate's output re-indexed *into up's cat order* (its own gather is
    composed with ``perm_up`` -- still exactly one gather for the pair,
    or zero when gate is dense/lowrank, whose rows we permute directly).

Lossless by construction; validated in tests/test_folding.py against the
unfolded reference to float tolerance.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.models.linear import Params, linear_kind

__all__ = ["fold_mlp", "permute_input_dim", "permute_output_dim"]


def permute_input_dim(p: Params, perm) -> Params:
    """Return consumer params with input columns permuted by ``perm``."""
    perm = jnp.asarray(perm, dtype=jnp.int32)
    k = linear_kind(p)
    q = dict(p)
    if k == "dense":
        q["w"] = jnp.take(p["w"], perm, axis=1)
    elif k == "lowrank":
        q["vt"] = jnp.take(p["vt"], perm, axis=1)
    else:  # pifa / pifa_folded: wp holds the input dim
        q["wp"] = jnp.take(p["wp"], perm, axis=1)
    return q


def permute_output_dim(p: Params, perm) -> Params:
    """Return producer params emitting outputs in ``perm`` order.

    dense/lowrank producers: permute rows (free).  PIFA producers:
    compose the gather -- new_inv_perm[k] = inv_perm[perm[k]].
    """
    perm = jnp.asarray(perm, dtype=jnp.int32)
    k = linear_kind(p)
    q = dict(p)
    if k == "dense":
        q["w"] = jnp.take(p["w"], perm, axis=0)
    elif k == "lowrank":
        q["u"] = jnp.take(p["u"], perm, axis=0)
    elif k == "pifa":
        q["inv_perm"] = jnp.take(p["inv_perm"], perm, axis=0)
    else:
        raise ValueError("cannot re-permute an already-folded pifa layer")
    if "b" in p:
        q["b"] = jnp.take(p["b"], perm, axis=0)
    return q


def fold_mlp(
    up: Params,
    down: Params,
    gate: Optional[Params] = None,
) -> Tuple[Params, Params, Optional[Params]]:
    """Fold the up(-gate)->down permutation.  Returns (up, down, gate).

    No-op unless ``up`` is an unfolded PIFA layer.
    """
    if linear_kind(up) != "pifa":
        return up, down, gate
    perm = np.asarray(up["inv_perm"])
    # invert: perm_up[k] = original index emitted at cat position k
    perm_up = np.empty_like(perm)
    perm_up[perm] = np.arange(perm.shape[0], dtype=perm.dtype)
    perm_up = jnp.asarray(perm_up, dtype=jnp.int32)

    new_up = {kk: v for kk, v in up.items() if kk != "inv_perm"}
    if "b" in new_up:
        new_up["b"] = jnp.take(new_up["b"], perm_up, axis=0)
    new_down = permute_input_dim(down, perm_up)
    new_gate = permute_output_dim(gate, perm_up) if gate is not None else None
    return new_up, new_down, new_gate
