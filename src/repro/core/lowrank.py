"""Low-rank pruning front-ends: vanilla SVD, activation-scaled SVD
(ASVD-style) and the SVD-LLM truncation-aware *whitened* SVD (the "W"
step of the paper's ablation, Table 5).

All factorizations run host-side in float64 (one-shot compression work);
outputs are ``(U, Vt)`` pairs with ``W ~= U @ Vt``, ``U: (m, r)``,
``Vt: (r, n)`` -- the representation PIFA and the M reconstruction
consume.
"""
from __future__ import annotations

from typing import Any, Tuple

import numpy as np

__all__ = ["svd_lowrank", "activation_svd", "whitened_svd", "as_numpy64"]


def as_numpy64(w: Any) -> np.ndarray:
    return np.asarray(w, dtype=np.float64)


def svd_lowrank(w: Any, rank: int) -> Tuple[np.ndarray, np.ndarray]:
    """Vanilla truncated SVD: ``U = B_r E_r``, ``Vt = A_r^T`` (Sec. 3.1)."""
    w = as_numpy64(w)
    b, e, at = np.linalg.svd(w, full_matrices=False)
    r = int(min(rank, e.shape[0]))
    u = b[:, :r] * e[:r][None, :]
    vt = at[:r, :]
    return u, vt


def activation_svd(w: Any, act_scale: Any, rank: int, alpha: float = 0.5
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """ASVD-style scaled SVD.

    ``S = diag(act_scale ** alpha)``; factorize ``(W S)`` and return
    ``U, Vt S^{-1}`` so that ``U @ Vt ~= W`` with error weighted by the
    mean input-activation magnitude per channel (Yuan et al., 2023).
    """
    w = as_numpy64(w)
    s = np.power(np.maximum(as_numpy64(act_scale), 1e-8), alpha)
    u, vt = svd_lowrank(w * s[None, :], rank)
    return u, vt / s[None, :]


def whitened_svd(w: Any, xxt: Any, rank: int, eps: float = 1e-6
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """SVD-LLM truncation-aware data whitening (the paper's "W" step).

    Let ``S`` be a Cholesky factor of the calibration second moment
    ``XX^T`` (so ``XX^T = S S^T``).  Factorizing ``W S`` makes the
    truncation error directly proportional to the induced output error
    on the calibration distribution; we keep the top-``r`` components of
    ``W S`` and return ``U = B_r E_r``, ``Vt = A_r^T S^{-1}``.
    """
    w = as_numpy64(w)
    xxt = as_numpy64(xxt)
    n = xxt.shape[0]
    # Regularize to PSD: XX^T accumulators can be numerically indefinite.
    tr = max(float(np.trace(xxt)) / n, 1e-12)
    s = None
    jitter = eps * tr
    for _ in range(8):
        try:
            s = np.linalg.cholesky(xxt + jitter * np.eye(n))
            break
        except np.linalg.LinAlgError:
            jitter *= 10.0
    if s is None:
        # Fall back to eigen square root.
        ev, evec = np.linalg.eigh(xxt)
        ev = np.maximum(ev, eps * tr)
        s = evec * np.sqrt(ev)[None, :]
    ws = w @ s
    b, e, at = np.linalg.svd(ws, full_matrices=False)
    r = int(min(rank, e.shape[0]))
    u = b[:, :r] * e[:r][None, :]
    # Vt = A_r^T S^{-1}: solve  Vt @ S = A_r^T.
    vt = np.linalg.solve(s.T, at[:r, :].T).T
    return u, vt
