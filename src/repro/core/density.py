"""Density <-> rank maps (parameter accounting, Fig. 1 of the paper).

`density` is the paper's definition: proportion of parameters remaining
relative to the original (dense) module.  For an ``(m, n)`` layer:

  * low-rank (U, Vt):   params = r*(m+n)          -> r = rho*m*n/(m+n)
  * PIFA:               params = r*(m+n) - r^2+r  -> quadratic in r

Because PIFA spends ``r^2 - r`` fewer parameters, at *equal density* it
affords a strictly higher rank -- that higher rank is the mechanism by
which ``W+M+PIFA`` beats ``W+M`` throughout Tables 2/5.
"""
from __future__ import annotations

import math

__all__ = [
    "rank_for_density_lowrank",
    "rank_for_density_pifa",
    "density_of_rank_lowrank",
    "density_of_rank_pifa",
]


def rank_for_density_lowrank(m: int, n: int, density: float) -> int:
    """Largest r with r*(m+n) <= density*m*n (at least 1)."""
    r = int(density * m * n / (m + n))
    return max(1, min(r, min(m, n)))


def rank_for_density_pifa(m: int, n: int, density: float) -> int:
    """Largest r with r*(m+n) - r^2 + r <= density*m*n.

    Solve r^2 - r*(m+n+1) + density*m*n >= 0 for the smaller root:
    r = ((m+n+1) - sqrt((m+n+1)^2 - 4*density*m*n)) / 2.
    """
    s = m + n + 1
    disc = s * s - 4.0 * density * m * n
    if disc < 0:  # density > max achievable (cannot happen for density<=1)
        return min(m, n)
    r = (s - math.sqrt(disc)) / 2.0
    r = int(math.floor(r))
    return max(1, min(r, min(m, n)))


def density_of_rank_lowrank(m: int, n: int, r: int) -> float:
    return r * (m + n) / (m * n)


def density_of_rank_pifa(m: int, n: int, r: int) -> float:
    return (r * (m + n) - r * r + r) / (m * n)
