"""Pivoting Factorization (PIFA) — Algorithm 1 & 2 of the paper.

PIFA is a *lossless meta* low-rank representation: given any rank-``r``
matrix ``W' = U @ Vt`` of shape ``(m, n)``, it finds ``r`` linearly
independent *pivot rows* (via column-pivoted QR of ``W'.T``) and stores

  * ``idx``       -- the ``r`` pivot-row indices (Algorithm 1, step 1)
  * ``wp``        -- the pivot-row matrix  ``W'[idx, :]``      (r, n)
  * ``c``         -- coefficients with ``W'[non_pivot, :] = c @ wp``
                     ((m - r), r)

for a total of ``r*(m+n) - r**2 + r`` parameters versus ``r*(m+n)`` for
the ``(U, Vt)`` pair -- a saving of exactly ``r**2 - r`` with **zero**
additional approximation error (Section 3.2/3.3).

Factorization runs on the host in float64 (it is one-shot, offline,
compression-time work); the *apply* path is pure JAX and jit/pjit
compatible.  ``kernels/pifa_matmul`` provides the fused Pallas TPU
kernel used by the serving path; :func:`pifa_apply` here is the simple
jnp reference used everywhere else.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
import scipy.linalg

__all__ = [
    "PifaFactors",
    "pivoting_factorize",
    "pifa_apply",
    "pifa_reconstruct",
    "pifa_param_count",
    "lowrank_param_count",
    "dense_param_count",
    "pifa_flops",
    "lowrank_flops",
    "dense_flops",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PifaFactors:
    """The PIFA layer P (output of Algorithm 1).

    ``perm`` is ``concat([idx, non_pivot_idx])`` -- the row order in which
    the layer *produces* outputs; ``inv_perm`` is its inverse so that
    ``y = concat([y_p, y_np])[..., inv_perm]`` restores the original row
    order.  Both are stored because ``perm`` lets consumers *fold* the
    permutation away (see ``core/folding.py``).
    """

    wp: jax.Array        # (r, n)    pivot-row matrix
    c: jax.Array         # (m-r, r)  non-pivot coefficients
    perm: jax.Array      # (m,) int32, concat([pivot_idx, non_pivot_idx])
    inv_perm: jax.Array  # (m,) int32, inverse permutation

    @property
    def rank(self) -> int:
        return self.wp.shape[0]

    @property
    def out_dim(self) -> int:
        return self.perm.shape[0]

    @property
    def in_dim(self) -> int:
        return self.wp.shape[1]


def _pivot_rows(w: np.ndarray, r: int) -> np.ndarray:
    """Indices of ``r`` maximally linearly-independent rows of ``w``.

    Column-pivoted QR on ``w.T`` (Businger & Golub 1971): the first ``r``
    pivot columns of ``w.T`` are the pivot *rows* of ``w``.
    """
    # scipy returns the permutation ordered by decreasing |R_kk|; the
    # first r entries are the best-conditioned pivot set.
    _, _, piv = scipy.linalg.qr(w.T, mode="economic", pivoting=True)
    return np.asarray(piv[:r], dtype=np.int32)


def pivoting_factorize(
    w: Any,
    rank: Optional[int] = None,
    *,
    rtol: float = 1e-9,
    dtype: Any = None,
) -> PifaFactors:
    """Algorithm 1: factorize a (numerically) rank-``r`` matrix.

    Args:
      w: the singular matrix ``W' = U @ Vt`` of shape ``(m, n)``.
      rank: target rank.  If ``None`` it is detected from the QR
        diagonal with relative tolerance ``rtol``.
      dtype: dtype of the stored factors (defaults to ``w.dtype``).

    Returns:
      :class:`PifaFactors` with ``W'[perm] == concat([wp, c @ wp])`` to
      float64 round-off.
    """
    w_np = np.asarray(w, dtype=np.float64)
    m, n = w_np.shape
    q, rr, piv = scipy.linalg.qr(w_np.T, mode="economic", pivoting=True)
    if rank is None:
        diag = np.abs(np.diag(rr))
        if diag.size == 0 or diag[0] == 0.0:
            rank = 1
        else:
            rank = max(1, int(np.sum(diag > rtol * diag[0])))
    rank = int(min(rank, m, n))
    idx = np.asarray(piv[:rank], dtype=np.int32)
    mask = np.ones(m, dtype=bool)
    mask[idx] = False
    nonpivot = np.nonzero(mask)[0].astype(np.int32)

    wp = w_np[idx, :]                      # (r, n)
    wnp = w_np[nonpivot, :]                # (m-r, n)
    # Solve C @ wp = wnp  <=>  wp.T @ C.T = wnp.T  (least squares; exact
    # when rank(w) <= r).
    c_t, *_ = np.linalg.lstsq(wp.T, wnp.T, rcond=None)
    c = c_t.T                              # (m-r, r)

    perm = np.concatenate([idx, nonpivot]).astype(np.int32)
    inv_perm = np.empty(m, dtype=np.int32)
    inv_perm[perm] = np.arange(m, dtype=np.int32)

    out_dtype = dtype if dtype is not None else np.asarray(w).dtype
    return PifaFactors(
        wp=jnp.asarray(wp, dtype=out_dtype),
        c=jnp.asarray(c, dtype=out_dtype),
        perm=jnp.asarray(perm),
        inv_perm=jnp.asarray(inv_perm),
    )


def pifa_apply(f: PifaFactors, x: jax.Array, *, gather: bool = True) -> jax.Array:
    """Algorithm 2: ``y = W' @ x`` computed from the PIFA factors.

    ``x`` has shape ``(..., n)`` (row-vector convention, as used by every
    model in the zoo: ``y = x @ W.T``).

    With ``gather=False`` the *permuted* output ``concat([y_p, y_np])``
    is returned; consumers that folded ``inv_perm`` into their own
    weights (``core/folding.py``) use this to skip the gather entirely.
    """
    yp = x @ f.wp.T                      # (..., r)      first GEMM
    ynp = yp @ f.c.T                     # (..., m - r)  second GEMM
    ycat = jnp.concatenate([yp, ynp], axis=-1)
    if not gather:
        return ycat
    return jnp.take(ycat, f.inv_perm, axis=-1)


def pifa_reconstruct(f: PifaFactors) -> jax.Array:
    """Rebuild ``W'`` from the factors (testing / folding use)."""
    wcat = jnp.concatenate([f.wp, f.c @ f.wp], axis=0)  # rows in perm order
    return jnp.take(wcat, f.inv_perm, axis=0)


# --------------------------------------------------------------------------
# Parameter / FLOP accounting (Section 3.3).
# --------------------------------------------------------------------------

def dense_param_count(m: int, n: int) -> int:
    return m * n


def lowrank_param_count(m: int, n: int, r: int) -> int:
    return r * (m + n)


def pifa_param_count(m: int, n: int, r: int) -> int:
    """``r*(m+n) - r^2 + r``: wp(r*n) + c((m-r)*r) + idx(r)."""
    return r * n + (m - r) * r + r


def dense_flops(m: int, n: int, b: int) -> int:
    return 2 * m * n * b


def lowrank_flops(m: int, n: int, r: int, b: int) -> int:
    return 2 * b * r * (m + n)


def pifa_flops(m: int, n: int, r: int, b: int) -> int:
    """``2*b*r*(m + n - r)``: the chained GEMMs of Algorithm 2."""
    return 2 * b * r * (n + (m - r))
