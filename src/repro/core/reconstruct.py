"""Online Error-Accumulation-Minimization Reconstruction ("M", Sec. 4).

The reconstruction consumes only two accumulated second-moment
statistics, so memory is constant in the number of calibration samples
(the paper's "online" property, Eq. 5):

    xxt  = sum_i  x_u^i  (x_u^i)^T            in R^{n x n}
    ytxt = sum_i  y_t^i  (x_u^i)^T            in R^{m x n}
    y_t^i = lam * W x_o^i + (1 - lam) * W x_u^i   (Eq. 7, mix ratio lam)

where ``x_o`` is the *dense* data-flow input of the module and ``x_u``
the *compressed* data-flow input.  Closed forms:

    U_r  = (ytxt) V (V^T xxt V)^{-1}                      (Eq. 5)
    V_r^T = (U^T U)^{-1} U^T (ytxt + alpha*W)(xxt + alpha*I)^{-1}   (Eq. 9)

All solves are host-side float64.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import numpy as np

__all__ = [
    "CalibStats",
    "solve_u",
    "solve_vt",
    "solve_u_fullbatch",
    "reconstruct_uv",
]


@dataclasses.dataclass
class CalibStats:
    """Streaming second-moment accumulators for one linear module.

    ``update`` takes one (micro)batch of activations in row convention
    ``(tokens, dim)`` -- i.e. ``x_u[t]`` is the module input of token
    ``t`` under the compressed flow, ``y_t[t]`` the mixed target output
    (Eq. 7).  fp64 accumulation: the statistics are sums over up to
    millions of tokens and bf16/fp32 accumulation visibly degrades the
    solve conditioning (paper App. B.1 observes the same singularity
    problem and regularizes; we do both).
    """

    n_in: int
    n_out: int
    xxt: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]
    ytxt: np.ndarray = dataclasses.field(default=None)  # type: ignore[assignment]
    count: int = 0

    def __post_init__(self):
        if self.xxt is None:
            self.xxt = np.zeros((self.n_in, self.n_in), dtype=np.float64)
        if self.ytxt is None:
            self.ytxt = np.zeros((self.n_out, self.n_in), dtype=np.float64)

    def update(self, x_u: Any, y_t: Any) -> None:
        x_u = np.asarray(x_u, dtype=np.float64).reshape(-1, self.n_in)
        y_t = np.asarray(y_t, dtype=np.float64).reshape(-1, self.n_out)
        assert x_u.shape[0] == y_t.shape[0]
        self.xxt += x_u.T @ x_u
        self.ytxt += y_t.T @ x_u
        self.count += x_u.shape[0]

    def update_inputs(self, w: Any, x_o: Any, x_u: Any, lam: float) -> None:
        """Accumulate from raw inputs: y_t = lam*W x_o + (1-lam)*W x_u."""
        w = np.asarray(w, dtype=np.float64)
        x_o = np.asarray(x_o, dtype=np.float64).reshape(-1, self.n_in)
        x_u = np.asarray(x_u, dtype=np.float64).reshape(-1, self.n_in)
        x_mix = lam * x_o + (1.0 - lam) * x_u
        y_t = x_mix @ w.T
        self.update(x_u, y_t)


def solve_u(stats: CalibStats, vt: Any) -> np.ndarray:
    """Eq. 5: U_r = (Y_t X^T) V (V^T (XX^T) V)^{-1}."""
    v = np.asarray(vt, dtype=np.float64).T          # (n, r)
    g = v.T @ stats.xxt @ v                         # (r, r)
    rhs = stats.ytxt @ v                            # (m, r)
    # U_r = rhs @ g^{-1}  <=>  g^T U_r^T = rhs^T; g is symmetric PSD.
    r = g.shape[0]
    tr = max(float(np.trace(g)) / r, 1e-30)
    u = np.linalg.solve(g + 1e-10 * tr * np.eye(r), rhs.T).T
    return u


def solve_vt(stats: CalibStats, u: Any, w: Optional[Any] = None,
             alpha: float = 1e-3) -> np.ndarray:
    """Eq. 8 with the Eq. 9 ridge: V_r^T = (U^T U)^{-1} U^T (YtX^T + a W)(XX^T + a I)^{-1}.

    ``alpha`` pulls ``U Vt`` toward ``W`` (prior knowledge that the
    factorization should approximate the pretrained weight) and fixes
    the singular-``XX^T`` failure mode (paper App. B.1, alpha=1e-3).
    """
    u = np.asarray(u, dtype=np.float64)             # (m, r)
    n = stats.xxt.shape[0]
    target = stats.ytxt
    lhs_x = stats.xxt
    if alpha and w is not None:
        target = target + alpha * np.asarray(w, dtype=np.float64)
        lhs_x = lhs_x + alpha * np.eye(n)
    gu = u.T @ u                                    # (r, r)
    r = gu.shape[0]
    tru = max(float(np.trace(gu)) / r, 1e-30)
    left = np.linalg.solve(gu + 1e-10 * tru * np.eye(r), u.T @ target)  # (r, n)
    # right-multiply by (XX^T + a I)^{-1}: solve  Vt (X) = left.
    vt = np.linalg.solve(lhs_x.T, left.T).T
    return vt


def solve_u_fullbatch(w: Any, vt: Any, x: Any) -> np.ndarray:
    """Eq. 4 (SVD-LLM full-batch reconstruction), for tests/ablation.

    ``x``: (n, N) column-stacked calibration inputs.
    ``U_r = W X D^T (D D^T)^{-1}``, ``D = V^T X``.
    """
    w = np.asarray(w, dtype=np.float64)
    vt = np.asarray(vt, dtype=np.float64)
    x = np.asarray(x, dtype=np.float64)
    d = vt @ x                                      # (r, N)
    ddt = d @ d.T
    r = ddt.shape[0]
    tr = max(float(np.trace(ddt)) / r, 1e-30)
    return np.linalg.solve(ddt + 1e-10 * tr * np.eye(r), d @ (w @ x).T).T


def reconstruct_uv(
    w: Any,
    u: np.ndarray,
    vt: np.ndarray,
    stats: CalibStats,
    *,
    update_v: bool = True,
    alpha: float = 1e-3,
) -> Tuple[np.ndarray, np.ndarray]:
    """One full M step: refine (U, Vt) against the accumulated stats.

    Order follows Algorithm 3: U first (Eq. 5), then optionally Vt with
    the refined U (Eq. 9).  For very large models the paper reconstructs
    only U (LLaMA2-70B) -- ``update_v=False``.
    """
    u_r = solve_u(stats, vt)
    if not update_v:
        return u_r, np.asarray(vt, dtype=np.float64)
    vt_r = solve_vt(stats, u_r, w=w, alpha=alpha)
    return u_r, vt_r
