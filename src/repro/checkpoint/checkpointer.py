"""Sharded, async, atomic checkpointing with elastic restore.

Layout (one directory per step):

  <dir>/step_000120.tmp/          — written first
      meta.json                   — step, pytree structure, shapes/dtypes
      shard_00000.npz             — this process's param shards
  <dir>/step_000120/              — atomic rename after fsync (commit point)

Design points for the 1000+-node posture:
  * each process writes ONLY its local shards (addressable-shards API);
    here (single-process container) that is one file, but the format and
    code paths are per-process;
  * writes happen on a background thread (training continues; ``wait()``
    joins before the next save — checkpoint/compute overlap);
  * the atomic rename means a crash mid-write never corrupts the latest
    checkpoint; ``latest_step`` only sees committed directories;
  * ``restore`` RESHARDS: arrays are loaded and placed against the
    *current* mesh/sharding, so a 512-chip checkpoint restores onto 256
    chips or vice versa (elastic scaling);
  * data-pipeline state and the step counter ride along in meta.json, so
    a restart resumes on the exact batch.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

__all__ = ["Checkpointer", "CorruptCheckpoint", "commit_dir", "crc32_file"]


class CorruptCheckpoint(RuntimeError):
    """A committed checkpoint shard's bytes no longer match the CRC32
    recorded in meta.json at save time — bit rot, a torn write that slipped
    behind the commit rename, or tampering.  Distinct from IO errors so
    restore loops can fall back to an older step instead of crashing."""


def crc32_file(path: pathlib.Path, chunk: int = 1 << 20) -> int:
    """CRC32 of a file's bytes, streamed (shards can be large)."""
    crc = 0
    with open(path, "rb") as fh:
        while True:
            buf = fh.read(chunk)
            if not buf:
                break
            crc = zlib.crc32(buf, crc)
    return crc & 0xFFFFFFFF


def commit_dir(tmp: pathlib.Path, final: pathlib.Path) -> None:
    """Atomic directory commit: fsync every file in ``tmp``, rename to
    ``final``, fsync the parent.  The rename is the commit point — a crash
    at any instant leaves either the previous committed state or the new
    one, never a torn directory.  Shared by checkpoints and the serving
    durability snapshots (runtime/durability.py)."""
    for f in sorted(tmp.iterdir()):
        if f.is_file():
            fd = os.open(f, os.O_RDONLY)
            try:
                os.fsync(fd)
            finally:
                os.close(fd)
    os.replace(tmp, final)
    dfd = os.open(final.parent, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for kp, leaf in flat:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
                       for k in kp)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.process_index = jax.process_index()

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Pytree, extra: Optional[Dict] = None,
             blocking: bool = False) -> None:
        """Snapshot to host memory synchronously, write async."""
        self.wait()
        items = _flatten_with_paths(tree)
        host = {}
        meta_arrays = {}
        for key, leaf in items:
            arr = np.asarray(jax.device_get(leaf))
            host[key.replace("/", "__")] = arr
            meta_arrays[key] = {"shape": list(arr.shape),
                                "dtype": str(arr.dtype)}
        treedef = jax.tree_util.tree_structure(tree)
        meta = {
            "step": int(step),
            "treedef": str(treedef),
            "arrays": meta_arrays,
            "extra": extra or {},
            "format": 1,
        }

        def _write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if final.exists():  # idempotent re-save of the same step
                return
            tmp.mkdir(parents=True, exist_ok=True)
            shard = tmp / f"shard_{self.process_index:05d}.npz"
            np.savez(shard, **host)
            # Per-shard CRC of the bytes as written: restore verifies the
            # file survived the commit rename AND the time on disk intact.
            meta["shard_crcs"] = {shard.name: crc32_file(shard)}
            (tmp / "meta.json").write_text(json.dumps(meta))
            commit_dir(tmp, final)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            d = self.dir / f"step_{s:08d}"
            for f in d.iterdir():
                f.unlink()
            d.rmdir()

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.dir.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, like: Pytree,
                sharding_fn: Optional[Callable[[str], Any]] = None
                ) -> Tuple[Pytree, Dict]:
        """Load ``step`` shaped/placed like ``like`` (elastic reshard).

        ``like`` supplies the pytree structure; each loaded array is
        device_put against ``sharding_fn(path)`` (or ``like``'s own
        sharding when it carries one), so restoring onto a different
        mesh Just Works — the host array is resharded at placement.
        """
        d = self.dir / f"step_{step:08d}"
        meta = json.loads((d / "meta.json").read_text())
        crcs = meta.get("shard_crcs", {})  # absent on pre-CRC checkpoints
        host: Dict[str, np.ndarray] = {}
        for shard in sorted(d.glob("shard_*.npz")):
            want = crcs.get(shard.name)
            if want is not None and crc32_file(shard) != want:
                raise CorruptCheckpoint(
                    f"{shard} fails CRC32 (expected {want:#010x}); refusing "
                    f"to restore silently-corrupt parameters")
            with np.load(shard) as z:
                for k in z.files:
                    host[k] = z[k]

        items = _flatten_with_paths(like)
        leaves = []
        for key, leaf in items:
            arr = host[key.replace("/", "__")]
            target_dtype = getattr(leaf, "dtype", arr.dtype)
            arr = arr.astype(target_dtype)
            sharding = None
            if sharding_fn is not None:
                sharding = sharding_fn(key)
            elif hasattr(leaf, "sharding"):
                sharding = leaf.sharding
            leaves.append(jax.device_put(arr, sharding) if sharding is not None
                          else jax.device_put(arr))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves), meta["extra"]

    def restore_latest(self, like: Pytree, **kw):
        step = self.latest_step()
        if step is None:
            return None, None, None
        tree, extra = self.restore(step, like, **kw)
        return step, tree, extra
