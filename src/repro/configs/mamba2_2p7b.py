"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality) backbone [arXiv:2405.21060; unverified].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm", num_layers=64, d_model=2560,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    gated_mlp=False, tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=16,
    gated_mlp=False, tie_embeddings=True,
)
