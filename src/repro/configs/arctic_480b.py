"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual MLP [hf:Snowflake/snowflake-arctic-base; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
    num_experts=128, top_k=2, moe_dense_ff=4864, capacity_factor=1.25,
    source="hf:Snowflake/snowflake-arctic-base; hf",
)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=256,
    num_experts=8, top_k=2, moe_dense_ff=96, capacity_factor=1.25,
)
