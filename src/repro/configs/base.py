"""Config system: architecture configs + input-shape suites.

Every assigned architecture has a module ``configs/<id>.py`` exporting
``CONFIG`` (the exact full-scale published config) and ``SMOKE``
(a reduced same-family config for CPU smoke tests).  ``registry()``
collects them; ``--arch <id>`` in every launcher resolves through it.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCH_IDS", "registry",
           "get_config", "get_smoke_config"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None   # default: d_model // num_heads
    use_bias: bool = False
    gated_mlp: bool = True
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 2
    moe_dense_ff: int = 0            # arctic: dense residual MLP alongside MoE
    capacity_factor: float = 1.25
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # --- hybrid (zamba2): shared attention block every k mamba layers ---
    attn_every: int = 0
    # --- attention pattern (gemma3) ---
    sliding_window: int = 0          # window size for local layers
    local_global_ratio: int = 0      # N local layers per 1 global
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)
    # --- vlm (phi-3-vision) ---
    num_patches: int = 0             # precomputed patch embeddings (stub frontend)
    tie_embeddings: bool = True
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.num_heads

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context path exists (DESIGN.md skip list)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        return True  # all assigned archs have a decoder (whisper is enc-dec)

    def window_for_layer(self, i: int) -> int:
        """gemma3-style local:global pattern; 0 = global (full) attention."""
        if self.sliding_window and self.local_global_ratio:
            return 0 if (i + 1) % (self.local_global_ratio + 1) == 0 else self.sliding_window
        return self.sliding_window


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

ARCH_IDS: Tuple[str, ...] = (
    "mamba2_2p7b",
    "arctic_480b",
    "grok1_314b",
    "zamba2_1p2b",
    "stablelm_1p6b",
    "granite3_8b",
    "command_r_35b",
    "gemma3_12b",
    "whisper_medium",
    "phi3_vision_4p2b",
)


def _load(arch: str):
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE


def registry() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cell_is_runnable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) dry-run cell applies (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "skip: pure full-attention arch at 524k context (DESIGN.md §4)"
    return True, ""
