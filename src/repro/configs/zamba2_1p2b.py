"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192,
ssm_state=64 -- Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid", num_layers=38, d_model=2048,
    num_heads=32, num_kv_heads=32, d_ff=8192, vocab_size=32000,
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=128,
    attn_every=6,
    source="arXiv:2411.15242; hf",
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    ssm_state=16, ssm_headdim=16, ssm_expand=2, ssm_conv=4, ssm_chunk=16,
    attn_every=2,
)
