"""Tiny trainable LM used by the MPIFA validation pipeline, examples and
benchmarks: small enough to *train from scratch on CPU* in minutes, big
enough that low-rank pruning behaves qualitatively like the paper."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tiny-lm", family="dense", num_layers=4, d_model=128,
    num_heads=4, num_kv_heads=4, d_ff=384, vocab_size=512,
    tie_embeddings=True,
)

SMOKE = CONFIG
