"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144, 5:1 local:global sliding window (128k context)
[hf:google/gemma-3-1b-pt; unverified].  head_dim=256 (decoupled from
d_model/num_heads as in the released gemma-3 configs)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense", num_layers=48, d_model=3840,
    num_heads=16, num_kv_heads=8, d_ff=15360, vocab_size=262144,
    head_dim=256, sliding_window=1024, local_global_ratio=5,
    rope_theta=1000000.0,
    source="hf:google/gemma-3-1b-pt; unverified",
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense", num_layers=3, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=160, vocab_size=256,
    head_dim=16, sliding_window=8, local_global_ratio=2,
)
