"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8e top-2 [hf:xai-org/grok-1; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", num_layers=64, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=32768, vocab_size=131072,
    num_experts=8, top_k=2, capacity_factor=1.25,
    source="hf:xai-org/grok-1; unverified",
)

SMOKE = ModelConfig(
    name="grok1-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
    num_experts=4, top_k=2, capacity_factor=1.25,
)
