"""whisper-medium [audio]: 24L d_model=1024 16H d_ff=4096 vocab=51865,
enc-dec with conv frontend STUB (input_specs provides precomputed frame
embeddings, 1500 frames) [arXiv:2212.04356; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
    encoder_layers=24, encoder_seq=1500, use_bias=True, gated_mlp=False,
    tie_embeddings=True,
    source="arXiv:2212.04356; unverified",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    encoder_layers=2, encoder_seq=32, use_bias=True, gated_mlp=False,
)
