"""llama2-7b: the paper's own evaluation model (Tables 2-7).  Included
so the dry-run / roofline covers the paper's exact setting too."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=32000,
    tie_embeddings=False,
    source="arXiv:2307.09288",
)

SMOKE = ModelConfig(
    name="llama2-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=160, vocab_size=256,
)
