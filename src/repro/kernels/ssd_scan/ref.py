"""Pure-jnp oracle for the SSD chunk scan: delegates to the model's
`_ssd_chunk_scan` (the lax.scan formulation), reshaped to the kernel's
pre-chunked layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import _ssd_chunk_scan

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(x, b, c, dt, da):
    """Same layout as ssd_scan_call: x (B, NC, Q, H, P) etc."""
    bsz, nc, q, h, p = x.shape
    n = b.shape[-1]
    xf = x.reshape(bsz, nc * q, h, p)
    bf = b.reshape(bsz, nc * q, n)
    cf = c.reshape(bsz, nc * q, n)
    dtf = dt.reshape(bsz, nc * q, h)
    daf = da.reshape(bsz, nc * q, h)
    y, h_fin = _ssd_chunk_scan(xf, bf, cf, dtf, daf, chunk=q)
    return y.reshape(bsz, nc, q, h, p).astype(x.dtype), h_fin
