"""Pallas SSD chunk-scan kernel (Mamba2 inner loop).

Grid ``(batch, num_chunks)`` — the chunk dim is the minor/sequential
grid dim, so the recurrent state lives in a VMEM scratch that persists
across chunk steps (same trick as the PIFA kernel's two-stage scratch):

  per chunk c (length Q):
    cA   = cumsum(dt * A)                                  (Q, h)
    y    = ((C B^T) ⊙ L ⊙ dt) x      intra-chunk, (Q,Q) MXU matmuls
         + (C · H) ⊙ exp(cA)         inter-chunk carry-in
    H   <- exp(cA[-1]) H + (B ⊙ dt exp(cA[-1]-cA))^T x     state update

The (Q, Q) score matrix and the (h, n, p) state tile stay in VMEM; HBM
traffic is exactly the chunk inputs/outputs — this is the TPU-native
adaptation of the Mamba2 Triton kernel (DESIGN.md §2: VMEM-resident
state instead of SRAM warp tiles).

Head-batched formulation: all heads of one (batch, chunk) cell are
processed in-block (heads share B/C in the ngroups=1 layout).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel", "ssd_scan_call"]


def ssd_scan_kernel(x_ref, b_ref, c_ref, dt_ref, da_ref, y_ref, hfin_ref,
                    h_scratch, *, num_chunks: int):
    """One (batch, chunk) grid step.

    x_ref: (1, 1, Q, H, P); b/c_ref: (1, 1, Q, N); dt/da_ref: (1, 1, Q, H)
    y_ref: (1, 1, Q, H, P); hfin_ref: (1, H, N, P);
    h_scratch: (H, N, P) fp32, persistent across the chunk grid dim.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def init_state():
        h_scratch[...] = jnp.zeros_like(h_scratch)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, H, P)
    b = b_ref[0, 0].astype(jnp.float32)        # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)        # (Q, N)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, H)
    da = da_ref[0, 0].astype(jnp.float32)      # (Q, H)
    q = x.shape[0]

    ca = jnp.cumsum(da, axis=0)                # (Q, H)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (Q, Q)
    idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = idx >= jdx
    # decay L[i, j, h] = exp(ca[i] - ca[j]) on the lower triangle
    lmat = jnp.exp(ca[:, None, :] - ca[None, :, :])           # (Q, Q, H)
    scores = cb[:, :, None] * jnp.where(tri[:, :, None], lmat, 0.0)
    scores = scores * dt[None, :, :]                          # (i, j, h)
    # y_intra[i, h, p] = sum_j scores[i, j, h] * x[j, h, p]
    y = jnp.einsum("ijh,jhp->ihp", scores, x)
    # carry-in: y_inter[i, h, p] = sum_n c[i, n] * H[h, n, p] * exp(ca[i, h])
    h_prev = h_scratch[...]
    y = y + jnp.einsum("in,hnp->ihp", c, h_prev) * jnp.exp(ca)[:, :, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update
    decay_end = jnp.exp(ca[-1, :][None, :] - ca) * dt         # (Q, H)
    s_new = jnp.einsum("jh,jn,jhp->hnp", decay_end, b, x)
    h_scratch[...] = jnp.exp(ca[-1, :])[:, None, None] * h_prev + s_new
    hfin_ref[0] = h_scratch[...]


def ssd_scan_call(x, b, c, dt, da, *, interpret: bool = False):
    """x: (B, NC, Q, H, P); b/c: (B, NC, Q, N); dt/da: (B, NC, Q, H).

    Returns (y: like x, h_final: (B, H, N, P) fp32).
    """
    bsz, nc, q, h, p = x.shape
    n = b.shape[-1]
    kern = functools.partial(ssd_scan_kernel, num_chunks=nc)
    return pl.pallas_call(
        kern,
        grid=(bsz, nc),
        in_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, q, h), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, q, h, p), lambda i, j: (i, j, 0, 0, 0)),
            pl.BlockSpec((1, h, n, p), lambda i, j: (i, 0, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((h, n, p), jnp.float32)],
        interpret=interpret,
    )(x, b, c, dt, da)
