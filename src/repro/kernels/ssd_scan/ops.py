"""jit'd wrapper for the SSD chunk-scan kernel (padding + layout)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_call
from repro.kernels.ssd_scan.ref import ssd_scan_ref


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "use_kernel"))
def ssd_scan(x, b, c, dt, da, *, chunk: int = 128, interpret: bool = True,
             use_kernel: bool = True):
    """Flat layout: x (B, S, H, P); b/c (B, S, N); dt/da (B, S, H).

    Pads S to a chunk multiple (da=0 padding is exact: exp(0)=1 decay,
    dt=0 kills the padded tokens' contributions), chunks, dispatches.
    Returns (y (B, S, H, P), h_final (B, H, N, P)).
    """
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)
    dtc = dt.reshape(bsz, nc, chunk, h)
    dac = da.reshape(bsz, nc, chunk, h)
    if use_kernel:
        y, h_fin = ssd_scan_call(xc, bc, cc, dtc, dac, interpret=interpret)
    else:
        y, h_fin = ssd_scan_ref(xc, bc, cc, dtc, dac)
    y = y.reshape(bsz, nc * chunk, h, p)
    if pad:
        y = y[:, :s]
    return y, h_fin
