"""Per-bucket block-size selection for the fused PIFA kernel.

``select_block_sizes`` (ops.py) is a static heuristic keyed on the call
shape alone.  The serving engine knows strictly more: when rank-bucketed
restacking builds ``block_buckets`` the padded rank of every bucket AND
the decode batch (slot capacity) are fixed for the lifetime of the
serving process — so each bucket's decode matmul can be tuned once, at
restack time, and the winner pinned.

This module keeps a process-level registry mapping the flattened call
shape ``(B, n, r)`` to ``(block_b, block_o)``.  ``pifa_matmul_fused``
consults it before falling back to the heuristic, so tuning is a pure
side-channel: no model or engine code threads block sizes through call
sites, and an empty registry reproduces the old behaviour exactly.

Tuning itself times the real kernel over a small candidate grid — but
only where timing means anything: on TPU backends the compiled Mosaic
kernel runs; everywhere else (the CPU container runs the kernel in
interpreter mode, whose timings do not transfer) the registry is
seeded from the heuristic unless ``REPRO_PIFA_AUTOTUNE=force``.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "register_block_sizes",
    "lookup_block_sizes",
    "clear_block_size_registry",
    "registry_snapshot",
    "candidate_block_sizes",
    "autotune_block_sizes",
    "tune_pifa_params",
]

# (B, n, r) -> (block_b, block_o); B is the flattened leading dim of x.
_REGISTRY: Dict[Tuple[int, int, int], Tuple[int, int]] = {}


def register_block_sizes(b: int, n: int, r: int,
                         block_b: int, block_o: int) -> None:
    _REGISTRY[(int(b), int(n), int(r))] = (int(block_b), int(block_o))


def lookup_block_sizes(b: int, n: int, r: int) -> Optional[Tuple[int, int]]:
    return _REGISTRY.get((int(b), int(n), int(r)))


def clear_block_size_registry() -> None:
    _REGISTRY.clear()


def registry_snapshot() -> Dict[Tuple[int, int, int], Tuple[int, int]]:
    return dict(_REGISTRY)


def candidate_block_sizes(b: int, n: int, r: int, mnp: int
                          ) -> List[Tuple[int, int]]:
    """Small grid around the feasible tile shapes for this call.

    block_b tiles the (flattened) activation rows: anything past the
    smallest aligned tile covering B only pads.  block_o tiles both
    wp rows and c rows; values beyond the padded output dims waste a
    full MXU pass per grid step.
    """
    bbs = [c for c in (8, 16, 32, 64, 128) if c < b * 2 or c == 8]
    if not bbs:
        bbs = [8]
    out_dim = max(r, mnp, 1)
    bos = [c for c in (128, 256) if c <= max(128, out_dim)]
    return [(bb, bo) for bb in bbs for bo in bos]


def _time_candidate(x: jax.Array, wp: jax.Array, c: jax.Array,
                    block_b: int, block_o: int, repeats: int) -> float:
    from repro.kernels.pifa_matmul.ops import pifa_matmul_fused
    out = pifa_matmul_fused(x, wp, c, block_b=block_b, block_o=block_o)
    jax.block_until_ready(out)  # compile outside the timed region
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = pifa_matmul_fused(x, wp, c, block_b=block_b, block_o=block_o)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def _timing_enabled() -> bool:
    mode = os.environ.get("REPRO_PIFA_AUTOTUNE", "auto")
    if mode == "force":
        return True
    if mode == "0":
        return False
    return jax.default_backend() == "tpu"


def autotune_block_sizes(b: int, n: int, r: int, mnp: int, *,
                         dtype: Any = jnp.float32, repeats: int = 3,
                         force: bool = False) -> Tuple[int, int]:
    """Pick (block_b, block_o) for shape (B, n) x PIFA(r, mnp) and pin
    it in the registry.  Times real kernel dispatches on TPU; elsewhere
    registers the shape-keyed heuristic (still per-bucket: the decode
    batch and padded bucket rank key the entry)."""
    cached = lookup_block_sizes(b, n, r)
    if cached is not None and not force:
        return cached
    from repro.kernels.pifa_matmul.ops import select_block_sizes
    best = select_block_sizes(b, n, r, mnp)
    if _timing_enabled() or force:
        key = jax.random.PRNGKey(0)
        kx, kw, kc = jax.random.split(key, 3)
        x = jax.random.normal(kx, (b, n), dtype)
        wp = jax.random.normal(kw, (r, n), dtype)
        c = jax.random.normal(kc, (mnp, r), dtype)
        best_t = float("inf")
        for bb, bo in candidate_block_sizes(b, n, r, mnp):
            try:
                t = _time_candidate(x, wp, c, bb, bo, repeats)
            except Exception:
                continue  # infeasible tile on this backend: skip
            if t < best_t:
                best_t, best = t, (bb, bo)
    register_block_sizes(b, n, r, *best)
    return best


def _walk_pifa_shapes(tree: Any, out: set) -> None:
    if isinstance(tree, dict):
        if "wp" in tree and "c" in tree:
            wp, c = tree["wp"], tree["c"]
            if hasattr(wp, "shape") and wp.ndim >= 2:
                # stacked factors carry a leading layer dim; the matmul
                # shape is the trailing (r, n) / (mnp, r)
                r, n = int(wp.shape[-2]), int(wp.shape[-1])
                mnp = int(c.shape[-2])
                out.add((n, r, mnp))
            return
        for v in tree.values():
            _walk_pifa_shapes(v, out)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            _walk_pifa_shapes(v, out)


def tune_pifa_params(params: Any, batch: int, *, repeats: int = 3
                     ) -> Dict[Tuple[int, int, int], Tuple[int, int]]:
    """Walk (restacked) params, tune every distinct PIFA matmul shape
    for decode calls of ``batch`` rows, and return the chosen entries.

    Called by the generation engine / serving scheduler right after
    rank-bucketed restacking: each bucket's padded rank yields its own
    (B, n, r) key, so heterogeneous-rank MPIFA_NS models get per-bucket
    tuned decode kernels instead of one generic heuristic.
    """
    shapes: set = set()
    _walk_pifa_shapes(params, shapes)
    chosen = {}
    for (n, r, mnp) in sorted(shapes):
        chosen[(batch, n, r)] = autotune_block_sizes(batch, n, r, mnp,
                                                     repeats=repeats)
    return chosen
