"""Fused PIFA layer kernel (Algorithm 2) — the paper's hot loop, TPU-native.

Computes, in ONE pallas_call,

    y_cat = [ y_p ; y_np ],   y_p = x @ wp.T,   y_np = y_p @ c.T

with the intermediate ``y_p`` tile kept **resident in VMEM scratch**
between the two GEMM stages (the CUDA reference implementation launches
two kernels through global memory; on TPU the fusion removes one HBM
round-trip of ``y_p`` — (B, r) bytes per layer).

Grid: ``(B/bb, m/bo)`` — for a fixed batch tile ``i`` the TPU grid runs
the output tiles ``j`` sequentially: tiles ``j < r/bo`` are stage 1
(compute y_p, write it to the output AND stash it in VMEM scratch),
tiles ``j >= r/bo`` are stage 2 (consume the full scratch).  Scratch is
persistent across grid steps, so the dependency is honoured by grid
order (the last grid dim is the minor, sequential one on TPU).

BlockSpecs keep the full contraction dims (n, r) inside the block: the
working set per step is ``bb*n + bo*n + bb*r`` elements — choose ``bb``
so this fits VMEM (~16 MB/core); all tile dims are multiples of 128
(MXU lane alignment), padding handled by ``ops.py``.

The output permutation (Algorithm 2 steps 4-5) is deliberately NOT a
scatter inside the kernel: minor-dim scatters serialize on TPU.  The
wrapper applies it as one gather — or not at all, when the consumer
folded it away (core/folding.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pifa_matmul_kernel", "pifa_matmul_call",
           "pifa_fused_kernel", "pifa_fused_call"]


def pifa_matmul_kernel(x_ref, wp_ref, c_ref, out_ref, yp_scratch, *,
                       n_yp_tiles: int, block_o: int):
    """One (batch-tile, out-tile) grid step.

    x_ref:  (bb, n)      — batch tile, full reduction dim
    wp_ref: (bo, n)      — stage-1 weight tile (clamped on stage-2 steps)
    c_ref:  (bo, r)      — stage-2 weight tile (clamped on stage-1 steps)
    out_ref: (bb, bo)    — the y_cat tile this step owns
    yp_scratch: (bb, r)  — VMEM-persistent y_p for the current batch tile
    """
    j = pl.program_id(1)

    @pl.when(j < n_yp_tiles)
    def stage1():
        yp = jnp.dot(x_ref[...], wp_ref[...].T,
                     preferred_element_type=jnp.float32)
        out_ref[...] = yp.astype(out_ref.dtype)
        pl.store(yp_scratch, (slice(None), pl.dslice(j * block_o, block_o)),
                 yp)

    @pl.when(j >= n_yp_tiles)
    def stage2():
        ynp = jnp.dot(yp_scratch[...], c_ref[...].T,
                      preferred_element_type=jnp.float32)
        out_ref[...] = ynp.astype(out_ref.dtype)


def pifa_matmul_call(x, wp, c, *, block_b: int = 128, block_o: int = 128,
                     interpret: bool = False):
    """x: (B, n), wp: (r, n), c: (m-r, r) -> y_cat: (B, m).

    All dims must already be multiples of the block sizes (``ops.py``
    pads and un-pads).
    """
    bsz, n = x.shape
    r = wp.shape[0]
    mnp = c.shape[0]
    assert bsz % block_b == 0 and r % block_o == 0 and mnp % block_o == 0, (
        bsz, r, mnp, block_b, block_o)
    n_yp = r // block_o
    n_out = n_yp + mnp // block_o
    grid = (bsz // block_b, n_out)

    kern = functools.partial(pifa_matmul_kernel, n_yp_tiles=n_yp,
                             block_o=block_o)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i, j: (i, 0)),
            # stage-2 steps clamp to wp tile 0 (unused there)
            pl.BlockSpec((block_o, n),
                         lambda i, j: (jnp.minimum(j, n_yp - 1), 0)),
            # stage-1 steps clamp to c tile 0 (unused there)
            pl.BlockSpec((block_o, r),
                         lambda i, j: (jnp.maximum(j - n_yp, 0), 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, r + mnp), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, r), jnp.float32)],
        interpret=interpret,
    )(x, wp, c)


# ---------------------------------------------------------------------------
# Fused epilogue variant: bias + inverse-permutation gather in-kernel.
# ---------------------------------------------------------------------------

def pifa_fused_kernel(x_ref, wp_ref, c_ref, inv_ref, bias_ref, out_ref,
                      ycat_scratch, *, n_yp_tiles: int, n_np_tiles: int,
                      block_o: int):
    """One (batch-tile, stage-tile) grid step of the fully fused layer.

    Three stage bands over the minor (sequential) grid dim ``j``:

      j <  n_yp                    stage 1: y_p tile -> scratch
      j <  n_yp + n_np             stage 2: y_np tile -> scratch
      j >= n_yp + n_np             stage 3: gather + bias epilogue -> out

    The epilogue applies the output permutation as a ONE-HOT SELECTION
    MATMUL (``y_cat @ P_tile.T``) rather than a dynamic gather: a
    minor-dim gather serializes on the TPU VPU, whereas the (bo, L)
    one-hot contraction runs on the MXU and its FLOPs are negligible at
    decode batch sizes.  Bias lands in the same step, so the wrapper's
    per-call concat-then-gather-then-add chain disappears entirely.

    x_ref:    (bb, n)     batch tile, full reduction dim
    wp_ref:   (bo, n)     stage-1 weight tile (clamped elsewhere)
    c_ref:    (bo, r)     stage-2 weight tile (clamped elsewhere)
    inv_ref:  (1, bo)     int32 permutation tile for the owned out tile
    bias_ref: (1, bo)     f32 bias tile for the owned out tile
    out_ref:  (bb, bo)    final (permuted, biased) output tile
    ycat_scratch: (bb, r + mnp) VMEM-persistent concat buffer
    """
    j = pl.program_id(1)
    n_cat = n_yp_tiles + n_np_tiles

    @pl.when(j < n_yp_tiles)
    def stage1():
        yp = jnp.dot(x_ref[...], wp_ref[...].T,
                     preferred_element_type=jnp.float32)
        pl.store(ycat_scratch,
                 (slice(None), pl.dslice(j * block_o, block_o)), yp)

    @pl.when(jnp.logical_and(j >= n_yp_tiles, j < n_cat))
    def stage2():
        r = c_ref.shape[1]
        yp_full = pl.load(ycat_scratch, (slice(None), pl.dslice(0, r)))
        ynp = jnp.dot(yp_full, c_ref[...].T,
                      preferred_element_type=jnp.float32)
        pl.store(ycat_scratch,
                 (slice(None),
                  pl.dslice(r + (j - n_yp_tiles) * block_o, block_o)), ynp)

    @pl.when(j >= n_cat)
    def stage3():
        ycat = ycat_scratch[...]                       # (bb, L) f32
        idx = inv_ref[0, :]                            # (bo,) int32
        lanes = jax.lax.broadcasted_iota(jnp.int32,
                                         (idx.shape[0], ycat.shape[1]), 1)
        onehot = (idx[:, None] == lanes).astype(jnp.float32)
        y = jnp.dot(ycat, onehot.T, preferred_element_type=jnp.float32)
        out_ref[...] = (y + bias_ref[0, :][None, :]).astype(out_ref.dtype)


def pifa_fused_call(x, wp, c, inv_perm, bias, *, block_b: int = 8,
                    block_o: int = 128, interpret: bool = False):
    """x: (B, n), wp: (r, n), c: (m-r, r), inv_perm/bias: (1, m_out)
    -> y: (B, m_out), already permuted and biased.

    ``inv_perm`` indexes the PADDED concat buffer ``[y_p(r); y_np(m-r)]``
    (the wrapper remaps/pads indices); ``m_out`` is a multiple of
    ``block_o`` and every other dim is already block-aligned (``ops.py``
    pads and un-pads).  ``block_b`` may be small (8) — the decode-shaped
    GEMV variant — because the batch dim never feeds the MXU lane dim.
    """
    bsz, n = x.shape
    r = wp.shape[0]
    mnp = c.shape[0]
    m_out = inv_perm.shape[1]
    assert (bsz % block_b == 0 and r % block_o == 0 and mnp % block_o == 0
            and m_out % block_o == 0), (bsz, r, mnp, m_out, block_b, block_o)
    n_yp = r // block_o
    n_np = mnp // block_o
    n_out = m_out // block_o
    n_cat = n_yp + n_np
    grid = (bsz // block_b, n_cat + n_out)

    kern = functools.partial(pifa_fused_kernel, n_yp_tiles=n_yp,
                             n_np_tiles=n_np, block_o=block_o)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i, j: (i, 0)),
            # non-stage-1 steps clamp to wp tile 0 (unused there)
            pl.BlockSpec((block_o, n),
                         lambda i, j: (jnp.minimum(j, n_yp - 1), 0)),
            # non-stage-2 steps clamp to c tile 0 (unused there)
            pl.BlockSpec((block_o, r),
                         lambda i, j: (jnp.clip(j - n_yp, 0, n_np - 1), 0)),
            pl.BlockSpec((1, block_o),
                         lambda i, j: (0, jnp.clip(j - n_cat, 0, n_out - 1))),
            pl.BlockSpec((1, block_o),
                         lambda i, j: (0, jnp.clip(j - n_cat, 0, n_out - 1))),
        ],
        # stage-1/2 steps park on out tile 0; the first stage-3 step owns
        # and fully rewrites it before any block change flushes it.
        out_specs=pl.BlockSpec((block_b, block_o),
                               lambda i, j: (i, jnp.clip(j - n_cat, 0,
                                                         n_out - 1))),
        out_shape=jax.ShapeDtypeStruct((bsz, m_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_b, r + mnp), jnp.float32)],
        interpret=interpret,
    )(x, wp, c, inv_perm, bias)
