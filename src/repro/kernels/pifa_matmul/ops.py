"""jit'd public wrapper for the fused PIFA kernel.

Handles: flattening leading dims, padding every dim to MXU-aligned
block multiples (zero padding is exact: padded wp rows produce zero
y_p columns, padded c rows produce y_np rows that are sliced off),
kernel dispatch with an interpret-mode fallback on CPU, and the
optional output gather.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.pifa_matmul.kernel import pifa_matmul_call
from repro.kernels.pifa_matmul.ref import pifa_matmul_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o",
                                             "interpret", "use_kernel"))
def pifa_matmul(x: jax.Array, wp: jax.Array, c: jax.Array,
                inv_perm: Optional[jax.Array] = None, *,
                block_b: int = 128, block_o: int = 128,
                interpret: bool = True, use_kernel: bool = True) -> jax.Array:
    """PIFA layer forward: x (..., n) -> y (..., m).

    ``interpret=True`` is the CPU-container default (the kernel body runs
    in Python); on TPU pass ``interpret=False``.  ``use_kernel=False``
    routes to the jnp oracle (what the models use under jit on CPU).
    """
    lead = x.shape[:-1]
    n = x.shape[-1]
    r, mnp = wp.shape[0], c.shape[0]
    x2 = x.reshape(-1, n)
    if not use_kernel:
        ycat = pifa_matmul_ref(x2, wp, c)
    else:
        bsz = x2.shape[0]
        xp = _pad_to(_pad_to(x2, 0, block_b), 1, 128)
        wpp = _pad_to(_pad_to(wp, 0, block_o), 1, 128)
        cp = _pad_to(_pad_to(c, 0, block_o), 1, block_o)
        # c's reduction dim must match padded r
        rp = wpp.shape[0]
        if cp.shape[1] != rp:
            cp = _pad_to(cp, 1, rp)[:, :rp]
        ycat_p = pifa_matmul_call(xp, wpp, cp, block_b=block_b,
                                  block_o=block_o, interpret=interpret)
        # un-pad: y_p cols [0, r), y_np cols [rp, rp + mnp)
        ycat = jnp.concatenate(
            [ycat_p[:bsz, :r], ycat_p[:bsz, rp:rp + mnp]], axis=-1)
    if inv_perm is not None:
        ycat = jnp.take(ycat, inv_perm, axis=-1)
    return ycat.reshape(lead + (r + mnp,))
