"""jit'd public wrappers for the fused PIFA kernels.

Handles: flattening leading dims, padding every dim to MXU-aligned
block multiples (zero padding is exact: padded wp rows produce zero
y_p columns, padded c rows produce y_np rows that are sliced off),
kernel dispatch with an interpret-mode fallback on CPU, and the
output epilogue.

Two entry points:

  * :func:`pifa_matmul` — the two-stage kernel; returns the *concat*
    output ``[y_p; y_np]`` with an optional jnp gather outside the
    kernel (the original wrapper contract, kept for the oracle tests).
  * :func:`pifa_matmul_fused` — the single-dispatch layer: bias and the
    inverse-permutation gather run inside the kernel epilogue (one-hot
    selection matmul), so nothing is concatenated or gathered per call
    at the JAX level.  Block sizes are selected per ``(B, n, r)`` —
    small-batch (decode/GEMV) shapes get a narrow batch tile.

``interpret=None`` (the default) auto-detects the backend: the kernel
body runs compiled on TPU and in interpreter mode elsewhere (the
CPU-container case).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.pifa_matmul.kernel import pifa_fused_call, pifa_matmul_call
from repro.kernels.pifa_matmul.ref import pifa_matmul_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> backend auto-detect: compiled pallas on TPU, interpreter
    everywhere else (CPU containers, GPU hosts without Mosaic)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def select_block_sizes(b: int, n: int, r: int, mnp: int) -> Tuple[int, int]:
    """(block_b, block_o) keyed on the call shape.

    Decode steps present (B, n) activations with B of a few to a few
    dozen rows; tiling them at 128 would waste 90%+ of each MXU pass on
    zero padding.  The batch dim only ever feeds sublanes (f32 min tile
    8 x 128), so block_b drops to the smallest aligned tile covering B.
    block_o stays at the 128-lane MXU width; large uniform shapes widen
    to 256 to halve grid-step overhead.
    """
    block_b = 128
    for cand in (8, 16, 32, 64):
        if b <= cand:
            block_b = cand
            break
    block_o = 128
    if b >= 256 and r >= 256 and mnp >= 256 and n >= 256:
        block_o = 256
    return block_b, block_o


@functools.partial(jax.jit, static_argnames=("block_b", "block_o",
                                             "interpret", "use_kernel"))
def _pifa_matmul_impl(x, wp, c, inv_perm, *, block_b, block_o, interpret,
                      use_kernel):
    lead = x.shape[:-1]
    n = x.shape[-1]
    r, mnp = wp.shape[0], c.shape[0]
    x2 = x.reshape(-1, n)
    if not use_kernel:
        ycat = pifa_matmul_ref(x2, wp, c)
    else:
        bsz = x2.shape[0]
        xp = _pad_to(_pad_to(x2, 0, block_b), 1, 128)
        wpp = _pad_to(_pad_to(wp, 0, block_o), 1, 128)
        cp = _pad_to(_pad_to(c, 0, block_o), 1, block_o)
        # c's reduction dim must match padded r
        rp = wpp.shape[0]
        if cp.shape[1] != rp:
            cp = _pad_to(cp, 1, rp)[:, :rp]
        ycat_p = pifa_matmul_call(xp, wpp, cp, block_b=block_b,
                                  block_o=block_o, interpret=interpret)
        # un-pad: y_p cols [0, r), y_np cols [rp, rp + mnp)
        ycat = jnp.concatenate(
            [ycat_p[:bsz, :r], ycat_p[:bsz, rp:rp + mnp]], axis=-1)
    if inv_perm is not None:
        ycat = jnp.take(ycat, inv_perm, axis=-1)
    return ycat.reshape(lead + (r + mnp,))


def pifa_matmul(x: jax.Array, wp: jax.Array, c: jax.Array,
                inv_perm: Optional[jax.Array] = None, *,
                block_b: int = 128, block_o: int = 128,
                interpret: Optional[bool] = None,
                use_kernel: bool = True) -> jax.Array:
    """PIFA layer forward: x (..., n) -> y (..., m).

    ``interpret=None`` auto-detects: compiled on TPU, interpreter mode
    elsewhere.  ``use_kernel=False`` routes to the jnp oracle (what the
    models use under jit on CPU).
    """
    return _pifa_matmul_impl(x, wp, c, inv_perm, block_b=block_b,
                             block_o=block_o,
                             interpret=_resolve_interpret(interpret),
                             use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("block_b", "block_o",
                                             "interpret", "use_kernel"))
def _pifa_fused_impl(x, wp, c, inv_perm, bias, *, block_b, block_o,
                     interpret, use_kernel):
    lead = x.shape[:-1]
    n = x.shape[-1]
    r, mnp = wp.shape[0], c.shape[0]
    m = inv_perm.shape[0]
    x2 = x.reshape(-1, n)
    if not use_kernel:
        y = jnp.take(pifa_matmul_ref(x2, wp, c), inv_perm, axis=-1)
        if bias is not None:
            y = y + bias.astype(y.dtype)
        return y.reshape(lead + (m,))

    bsz = x2.shape[0]
    xp = _pad_to(_pad_to(x2, 0, block_b), 1, 128)
    wpp = _pad_to(_pad_to(wp, 0, block_o), 1, 128)
    cp = _pad_to(_pad_to(c, 0, block_o), 1, block_o)
    rp = wpp.shape[0]
    if cp.shape[1] != rp:
        cp = _pad_to(cp, 1, rp)[:, :rp]
    # inv_perm indexes the UNPADDED concat [y_p(r); y_np(mnp)]; in the
    # padded buffer y_np starts at rp, so non-pivot targets shift.
    inv_p = jnp.where(inv_perm >= r, inv_perm + (rp - r), inv_perm)
    inv_p = _pad_to(inv_p[None, :].astype(jnp.int32), 1, block_o)
    b_full = (bias if bias is not None
              else jnp.zeros((m,), jnp.float32)).astype(jnp.float32)
    b_p = _pad_to(b_full[None, :], 1, block_o)
    y_p = pifa_fused_call(xp, wpp, cp, inv_p, b_p, block_b=block_b,
                          block_o=block_o, interpret=interpret)
    return y_p[:bsz, :m].reshape(lead + (m,))


def pifa_matmul_fused(x: jax.Array, wp: jax.Array, c: jax.Array,
                      inv_perm: Optional[jax.Array] = None,
                      bias: Optional[jax.Array] = None, *,
                      block_b: Optional[int] = None,
                      block_o: Optional[int] = None,
                      interpret: Optional[bool] = None,
                      use_kernel: bool = True) -> jax.Array:
    """Single-dispatch PIFA layer: gather + bias fused into the kernel.

    x (..., n) -> y (..., m) in ORIGINAL row order, bias applied.  With
    ``inv_perm=None`` (a folded layer) the epilogue uses the identity
    permutation, so the output is the concat order — identical to
    ``apply_linear`` on a ``pifa_folded`` layer.

    Block sizes default to the restack-time autotune registry
    (per-bucket tuned entries keyed on the flattened call shape; see
    kernels/pifa_matmul/autotune.py) and fall back to
    :func:`select_block_sizes` — decode-shaped calls get the
    narrow-batch GEMV variant.
    """
    r, mnp = wp.shape[0], c.shape[0]
    m = r + mnp
    if inv_perm is None:
        inv_perm = jnp.arange(m, dtype=jnp.int32)
    bsz = 1
    for d in x.shape[:-1]:
        bsz *= d
    if block_b is None or block_o is None:
        from repro.kernels.pifa_matmul.autotune import lookup_block_sizes
        tuned = lookup_block_sizes(bsz, x.shape[-1], r)
        bb, bo = (tuned if tuned is not None
                  else select_block_sizes(bsz, x.shape[-1], r, mnp))
        block_b = bb if block_b is None else block_b
        block_o = bo if block_o is None else block_o
    return _pifa_fused_impl(x, wp, c, inv_perm, bias, block_b=block_b,
                            block_o=block_o,
                            interpret=_resolve_interpret(interpret),
                            use_kernel=use_kernel)
