"""Pure-jnp oracle for the fused PIFA kernel (Algorithm 2)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["pifa_matmul_ref", "pifa_layer_ref"]


def pifa_matmul_ref(x: jax.Array, wp: jax.Array, c: jax.Array) -> jax.Array:
    """y_cat = [x @ wp.T, (x @ wp.T) @ c.T] — fp32 accumulation."""
    yp = jnp.dot(x, wp.T, preferred_element_type=jnp.float32)
    ynp = jnp.dot(yp, c.astype(jnp.float32).T,
                  preferred_element_type=jnp.float32)
    return jnp.concatenate([yp, ynp], axis=-1).astype(x.dtype)


def pifa_layer_ref(x: jax.Array, wp: jax.Array, c: jax.Array,
                   inv_perm: jax.Array) -> jax.Array:
    """Full Algorithm 2 including the output permutation."""
    return jnp.take(pifa_matmul_ref(x, wp, c), inv_perm, axis=-1)
