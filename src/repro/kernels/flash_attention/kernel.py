"""Pallas flash-attention kernel (causal / windowed, GQA-agnostic head
batch) — the §Perf-backlog fix for the jnp blockwise path.

The jnp double-scan in ``models/layers._mha_blockwise`` is numerically
identical but its (m, l, acc) carries round-trip HBM on every kv-chunk
step (measured in EXPERIMENTS.md §Roofline).  Here the accumulators
live in VMEM scratch across the kv grid dim (same persistence trick as
pifa_matmul's two-stage scratch and ssd_scan's state):

  grid = (batch*heads, q_tiles, kv_tiles)      kv minor => sequential
  scratch: m (bq,), l (bq,), acc (bq, d)       persist across kv tiles

Each (b*h, i, j) step computes one (bq, bk) score tile on the MXU,
applies the causal/window mask from absolute positions, folds it into
the running softmax, and writes the normalized output only on the last
kv tile.  HBM traffic: q/k/v tiles in, out tile once — O(S*d) total
instead of O(S*d*nk) for the scan formulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel", "flash_attention_call"]

NEG_INF = -1e30


def flash_attention_kernel(qpos_ref, kpos_ref, q_ref, k_ref, v_ref, o_ref,
                           m_ref, l_ref, acc_ref, *,
                           n_kv_tiles: int, scale: float, causal: bool,
                           window: int):
    """One (head, q-tile, kv-tile) grid step.

    q_ref: (1, bq, d); k_ref/v_ref: (1, bk, d); o_ref: (1, bq, d)
    qpos_ref: (1, bq) absolute positions; kpos_ref: (1, bk)
    scratch: m/l (bq, 1) f32, acc (bq, d) f32.
    """
    j = pl.program_id(2)

    @pl.when(j == 0)
    def init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qpos_ref[0]                                  # (bq,)
    kpos = kpos_ref[0]                                  # (bk,)
    delta = qpos[:, None] - kpos[None, :]
    # convention: kpos < 0 marks padded/invalid keys (ops.py)
    mask = jnp.broadcast_to((kpos >= 0)[None, :], s.shape)
    if causal:
        mask = mask & (delta >= 0)
    if window > 0:
        mask = mask & (delta < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...][:, 0]                           # (bq,)
    l_prev = l_ref[...][:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[:, None]
                    + jnp.dot(p, v, preferred_element_type=jnp.float32))
    m_ref[...] = m_new[:, None]
    l_ref[...] = l_new[:, None]

    @pl.when(j == n_kv_tiles - 1)
    def finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_call(q, k, v, qpos, kpos, *, scale: float,
                         causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = False):
    """q: (H, Sq, d); k/v: (H, Sk, d); qpos: (H, Sq); kpos: (H, Sk).

    H is a flattened batch*kv-head*group dim (ops.py builds it); dims
    must be pre-padded to tile multiples (padding rows carry positions
    that the causal/window mask rejects).
    """
    h, sq, d = q.shape
    sk = k.shape[1]
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    nq, nk = sq // block_q, sk // block_k
    kern = functools.partial(flash_attention_kernel, n_kv_tiles=nk,
                             scale=scale, causal=causal, window=window)
    return pl.pallas_call(
        kern,
        grid=(h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q), lambda b, i, j: (b, i)),
            pl.BlockSpec((1, block_k), lambda b, i, j: (b, j)),
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qpos, kpos, q, k, v)
