"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q, k, v, qpos, kpos, *, scale, causal=True,
                        window: int = 0):
    """Direct softmax attention over flattened heads: q (H, Sq, d)."""
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    delta = qpos[:, :, None] - kpos[:, None, :]
    mask = jnp.ones_like(s, dtype=bool)
    if causal:
        mask = mask & (delta >= 0)
    if window > 0:
        mask = mask & (delta < window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
