"""jit'd wrapper: GQA layout flattening + padding for the flash kernel."""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_call
from repro.kernels.flash_attention.ref import flash_attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "use_kernel"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True, use_kernel: bool = True):
    """GQA layout: q (b, sq, h, d); k/v (b, sk, hkv, d) -> (b, sq, h, d).

    KV heads are repeated into the flattened head-batch (the kernel is
    head-agnostic); padding rows get positions the causal mask rejects.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    scale = 1.0 / math.sqrt(d)

    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sk, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(b * h, sk, d)
    qpos = jnp.broadcast_to(jnp.arange(sq)[None], (b * h, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk)[None], (b * h, sk))

    if not use_kernel:
        of = flash_attention_ref(qf, kf, vf, qpos, kpos, scale=scale,
                                 causal=causal, window=window)
    else:
        pq = (-sq) % block_q
        pk = (-sk) % block_k
        if pq:
            qf = jnp.pad(qf, ((0, 0), (0, pq), (0, 0)))
            qpos = jnp.pad(qpos, ((0, 0), (0, pq)), constant_values=-1)
        if pk:
            kf = jnp.pad(kf, ((0, 0), (0, pk), (0, 0)))
            vf = jnp.pad(vf, ((0, 0), (0, pk), (0, 0)))
            # kpos < 0 marks padded keys (kernel validity convention)
            kpos = jnp.pad(kpos, ((0, 0), (0, pk)), constant_values=-1)
        of = flash_attention_call(qf, kf, vf, qpos, kpos, scale=scale,
                                  causal=causal, window=window,
                                  block_q=block_q, block_k=block_k,
                                  interpret=interpret)[:, :sq]
    return of.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
