"""Tiled GEMM kernel: ``y = x @ w.T`` — the SVD low-rank baseline layer
is two of these back-to-back (through HBM), exactly like the two-GEMM
cuBLAS implementation the paper benchmarks PIFA against.  Comparing this
against ``pifa_matmul`` quantifies the fusion + r^2-r savings on TPU.

Grid ``(B/bb, M/bm)``; the contraction dim stays whole inside the block
(VMEM working set = bb*n + bm*n + bb*bm).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matmul_kernel", "matmul_call"]


def matmul_kernel(x_ref, w_ref, out_ref):
    out_ref[...] = jnp.dot(x_ref[...], w_ref[...].T,
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def matmul_call(x, w, *, block_b: int = 128, block_m: int = 128,
                interpret: bool = False):
    """x: (B, n), w: (M, n) -> (B, M). Dims pre-padded by ops.py."""
    bsz, n = x.shape
    m = w.shape[0]
    assert bsz % block_b == 0 and m % block_m == 0
    return pl.pallas_call(
        matmul_kernel,
        grid=(bsz // block_b, m // block_m),
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((block_m, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_m), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bsz, m), x.dtype),
        interpret=interpret,
    )(x, w)
