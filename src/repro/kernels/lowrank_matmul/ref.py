"""Pure-jnp oracle for the low-rank (U, Vt) layer."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["lowrank_matmul_ref", "matmul_ref"]


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w.T, preferred_element_type=jnp.float32).astype(x.dtype)


def lowrank_matmul_ref(x: jax.Array, u: jax.Array, vt: jax.Array) -> jax.Array:
    """y = (x @ vt.T) @ u.T — the 2*b*r*(m+n) FLOPs baseline (Sec. 3.3)."""
    t = jnp.dot(x, vt.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return jnp.dot(t, u.T, preferred_element_type=jnp.float32).astype(x.dtype)
