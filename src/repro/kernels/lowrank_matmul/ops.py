"""jit'd wrappers: padded tiled GEMM + the two-GEMM low-rank layer."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lowrank_matmul.kernel import matmul_call
from repro.kernels.lowrank_matmul.ref import lowrank_matmul_ref


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_b", "block_m",
                                             "interpret", "use_kernel"))
def matmul(x: jax.Array, w: jax.Array, *, block_b: int = 128,
           block_m: int = 128, interpret: bool = True,
           use_kernel: bool = True) -> jax.Array:
    lead, n = x.shape[:-1], x.shape[-1]
    m = w.shape[0]
    x2 = x.reshape(-1, n)
    if not use_kernel:
        y = jnp.dot(x2, w.T, preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        bsz = x2.shape[0]
        xp = _pad_to(_pad_to(x2, 0, block_b), 1, 128)
        wp = _pad_to(_pad_to(w, 0, block_m), 1, 128)
        y = matmul_call(xp, wp, block_b=block_b, block_m=block_m,
                        interpret=interpret)[:bsz, :m]
    return y.reshape(lead + (m,))


@functools.partial(jax.jit, static_argnames=("block_b", "block_m",
                                             "interpret", "use_kernel"))
def lowrank_matmul(x: jax.Array, u: jax.Array, vt: jax.Array, *,
                   block_b: int = 128, block_m: int = 128,
                   interpret: bool = True, use_kernel: bool = True
                   ) -> jax.Array:
    """The (U, Vt) baseline layer: two GEMM dispatches through HBM."""
    if not use_kernel:
        return lowrank_matmul_ref(x, u, vt)
    t = matmul(x, vt, block_b=block_b, block_m=block_m, interpret=interpret)
    return matmul(t, u, block_b=block_b, block_m=block_m, interpret=interpret)
