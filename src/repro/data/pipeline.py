"""Deterministic, shardable, exactly-resumable token pipeline.

Production posture: each data-parallel host reads only its shard
(``shard_id / num_shards``), batches are a pure function of
``(seed, step)``, and the iterator state is one integer — so a restart
from a checkpoint replays from the exact batch where training stopped
(fault tolerance requirement, tested in tests/test_data.py).

Two sources:
  * ``SyntheticLM``  — a seeded Markov-chain token stream.  Not random
    noise: it has learnable bigram structure, so the tiny-LM experiments
    (benchmarks/table2 etc.) show real PPL separation between
    compression methods, mirroring the paper's WikiText2 usage.
  * ``FileTokens``   — memory-mapped ``.npy`` token file, the real-data
    path (examples/train_tiny_lm.py can generate one).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "FileTokens", "TokenPipeline"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0        # sampling-stream seed (varies train/eval/calib)
    data_seed: int = 0   # DATASET identity (the Markov chain itself):
                         # train/eval/calibration must share this
    shard_id: int = 0
    num_shards: int = 1

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_shards == 0
        return self.global_batch // self.num_shards


class SyntheticLM:
    """Seeded Markov bigram stream: P(t | t-1) is a fixed sparse-ish
    random stochastic matrix => cross-entropy has a well-defined floor
    that a trained model approaches and a pruned model degrades from."""

    def __init__(self, vocab_size: int, seed: int = 0, branching: int = 8):
        rng = np.random.default_rng(seed)
        self.vocab = vocab_size
        self.next_tokens = rng.integers(
            0, vocab_size, size=(vocab_size, branching)).astype(np.int32)
        logits = rng.normal(size=(vocab_size, branching)) * 1.5
        p = np.exp(logits)
        self.next_probs = (p / p.sum(1, keepdims=True)).astype(np.float64)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        out = np.empty(n + 1, dtype=np.int32)
        out[0] = rng.integers(0, self.vocab)
        b = self.next_tokens.shape[1]
        choices = rng.random(n)
        for i in range(n):
            row = out[i]
            c = np.searchsorted(np.cumsum(self.next_probs[row]), choices[i])
            out[i + 1] = self.next_tokens[row, min(c, b - 1)]
        return out

    def entropy_floor(self) -> float:
        """Mean conditional entropy (nats) — the optimal PPL is exp(this)."""
        h = -(self.next_probs * np.log(self.next_probs + 1e-12)).sum(1)
        return float(h.mean())


class FileTokens:
    """Memory-mapped token archive."""

    def __init__(self, path: str):
        self.tokens = np.load(path, mmap_mode="r")

    def slice(self, start: int, n: int) -> np.ndarray:
        start = start % max(len(self.tokens) - n - 1, 1)
        return np.asarray(self.tokens[start:start + n + 1], dtype=np.int32)


class TokenPipeline:
    """Stateless batch function + one-integer iterator state."""

    def __init__(self, cfg: DataConfig, source: Optional[object] = None):
        self.cfg = cfg
        self.source = source or SyntheticLM(cfg.vocab_size, seed=cfg.data_seed)
        self.step = 0

    # -- pure batch function (resume == set step) ---------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        lb = cfg.local_batch
        toks = np.empty((lb, cfg.seq_len), dtype=np.int32)
        labels = np.empty((lb, cfg.seq_len), dtype=np.int32)
        for i in range(lb):
            # unique stream per (step, global row); global row encodes shard
            row = cfg.shard_id * lb + i
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, row]))
            if isinstance(self.source, FileTokens):
                seq = self.source.slice(
                    int(rng.integers(0, 2**31 - 1)), cfg.seq_len)
            else:
                seq = self.source.sample(rng, cfg.seq_len)
            toks[i] = seq[:-1]
            labels[i] = seq[1:]
        return {"tokens": toks, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self.step)
        self.step += 1
        return b

    # -- checkpointable state ------------------------------------------------
    def state_dict(self) -> Dict[str, int]:
        return {"step": self.step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self.step = int(state["step"])
