"""Calibration streams for the M reconstruction (Sec. 4).

The paper uses 128 WikiText2 sequences (512 for MPIFA_NS); we expose the
same knobs over any TokenPipeline source.  Samples are produced
*sequentially* (the whole point of the online algorithm: only one sample
is ever in memory)."""
from __future__ import annotations

from typing import Iterator, List

import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM, TokenPipeline

__all__ = ["calibration_batches"]


def calibration_batches(vocab_size: int, num_samples: int, seq_len: int,
                        seed: int = 1234, batch: int = 1,
                        data_seed: int = 0) -> List[jnp.ndarray]:
    """num_samples token arrays of shape (batch, seq_len).

    ``data_seed`` is the DATASET identity and must match training (the
    paper calibrates on the same corpus it evaluates, WikiText2)."""
    cfg = DataConfig(vocab_size=vocab_size, seq_len=seq_len,
                     global_batch=batch, seed=seed, data_seed=data_seed)
    pipe = TokenPipeline(cfg, SyntheticLM(vocab_size, seed=data_seed))
    out = []
    for i in range(num_samples):
        out.append(jnp.asarray(pipe.batch_at(i)["tokens"]))
    return out
