"""AdamW with global-norm clipping — minimal optax-like protocol.

``init(params) -> state``; ``update(grads, state, params) -> (updates,
state)`` where ``new_params = params + updates``.  Moments are fp32
regardless of param dtype (mixed-precision training keeps bf16 params +
fp32 optimizer state; state sharding mirrors param sharding leaf-wise,
which `parallel/sharding.py` exploits).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

Pytree = Any


class AdamWState(NamedTuple):
    count: jax.Array
    m: Pytree
    v: Pytree


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Any = 1e-3                   # float or callable(step) -> float
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: Optional[float] = 1.0
    # hook point for gradient compression (optim/compression.py): maps
    # the grad pytree before the moment update (e.g. int8 round-trip or
    # PowerSGD low-rank approximation, with error feedback kept outside)
    grad_transform: Optional[Callable[[Pytree], Pytree]] = None

    def init(self, params: Pytree) -> AdamWState:
        # integer leaves (PIFA inv_perm, positions) are structural, not
        # trainable: zero-size moment placeholders, zero updates.
        zeros = lambda p: (jnp.zeros(p.shape, dtype=jnp.float32)
                           if jnp.issubdtype(p.dtype, jnp.inexact)
                           else jnp.zeros((), jnp.float32))
        return AdamWState(
            count=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def update(self, grads: Pytree, state: AdamWState, params: Pytree):
        if self.grad_transform is not None:
            grads = self.grad_transform(grads)

        def trainable(p):
            return jnp.issubdtype(p.dtype, jnp.inexact)

        grads = jax.tree.map(
            lambda g, p: g.astype(jnp.float32) if trainable(p)
            else jnp.zeros((), jnp.float32), grads, params)
        if self.clip_norm is not None:
            gn = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        lr = self.lr(count) if callable(self.lr) else self.lr
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)

        new_m = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g,
                             state.m, grads)
        new_v = jax.tree.map(lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
                             state.v, grads)

        def upd(p, m, v):
            if not trainable(p):
                return jnp.zeros(p.shape, p.dtype)  # structural leaf
            step = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, params, new_m, new_v)
        return updates, AdamWState(count=count, m=new_m, v=new_v)


def global_norm(tree: Pytree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)
