"""Gradient compression for DCN-bound multi-pod reductions.

Two schemes, both with error feedback (the residual of this step's
compression is added back next step, preserving convergence):

  * int8 uniform quantization (per-leaf absmax scaling) — 4x wire
    reduction vs f32, 2x vs bf16;
  * PowerSGD-style rank-r approximation ``G ~= P Q^T`` — thematically a
    low-rank sibling of the paper: the all-reduce moves ``r*(m+n)``
    instead of ``m*n`` (the same Fig. 1 arithmetic PIFA exploits for
    weights, applied to gradient traffic).

Usage: wrap the grad pytree transform into AdamW.grad_transform, or call
``compress/decompress`` around an explicit psum in a shard_map step.
The error-feedback state lives outside jit (host pytree) for the simple
trainer; the jit-native variant threads it through opt_state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

__all__ = ["Int8Compressor", "PowerSGDCompressor"]


def _quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclasses.dataclass
class Int8Compressor:
    """Round-trip int8 with error feedback."""

    def init(self, grads: Pytree) -> Pytree:
        return jax.tree.map(lambda g: jnp.zeros_like(g, dtype=jnp.float32),
                            grads)

    def compress(self, grads: Pytree, error: Pytree
                 ) -> Tuple[Pytree, Pytree]:
        """-> (wire pytree of (q, scale), new error feedback)."""
        def one(g, e):
            gf = g.astype(jnp.float32) + e
            q, s = _quantize_int8(gf)
            deq = _dequantize_int8(q, s)
            return (q, s), gf - deq
        pairs = jax.tree.map(one, grads, error)
        wire = jax.tree.map(lambda p: p[0], pairs,
                            is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
        return wire, new_err

    def decompress(self, wire: Pytree) -> Pytree:
        return jax.tree.map(lambda qs: _dequantize_int8(*qs), wire,
                            is_leaf=lambda x: isinstance(x, tuple))

    def roundtrip(self, grads: Pytree, error: Pytree) -> Tuple[Pytree, Pytree]:
        wire, new_err = self.compress(grads, error)
        return self.decompress(wire), new_err

    @staticmethod
    def wire_bytes(grads: Pytree) -> int:
        return sum(int(g.size) for g in jax.tree.leaves(grads))  # 1B/elem


@dataclasses.dataclass
class PowerSGDCompressor:
    """Rank-r gradient factorization with warm-started Q and error
    feedback (Vogels et al., adapted to the pytree/pjit world).

    Only >=2D leaves are factorized (matrices reshape to (m, -1));
    vectors/scalars pass through (they are a negligible fraction).
    """

    rank: int = 4
    iters: int = 1  # subspace iterations per step

    def init(self, grads: Pytree) -> Pytree:
        def one(g):
            if g.ndim < 2:
                return {"err": jnp.zeros_like(g, jnp.float32)}
            m = g.shape[0]
            n = int(g.size // m)
            key = jax.random.PRNGKey(n * 7919 + m)
            return {
                "err": jnp.zeros((m, n), jnp.float32),
                "q": jax.random.normal(key, (n, self.rank), jnp.float32),
            }
        return jax.tree.map(one, grads)

    def roundtrip(self, grads: Pytree, state: Pytree) -> Tuple[Pytree, Pytree]:
        """-> (approximated grads, new state).  The wire tensors are the
        (m, r) P and (n, r) Q factors — r*(m+n) instead of m*n."""
        def one(g, st):
            if g.ndim < 2:
                return g, st
            shape = g.shape
            m = shape[0]
            gf = g.astype(jnp.float32).reshape(m, -1) + st["err"]
            q = st["q"]
            p = None
            for _ in range(self.iters):
                p = gf @ q                                   # (m, r)  [psum'd]
                p, _ = jnp.linalg.qr(p)
                q = gf.T @ p                                 # (n, r)  [psum'd]
            approx = p @ q.T
            new_st = {"err": gf - approx, "q": q}
            return approx.reshape(shape).astype(g.dtype), new_st
        out = jax.tree.map(one, grads, state,
                           is_leaf=lambda x: isinstance(x, dict) and
                           ("err" in x or "q" in x))
        approx = jax.tree.map(lambda pr: pr[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_state = jax.tree.map(lambda pr: pr[1], out,
                                 is_leaf=lambda x: isinstance(x, tuple))
        return approx, new_state

    def wire_bytes(self, grads: Pytree) -> int:
        total = 0
        for g in jax.tree.leaves(grads):
            if g.ndim < 2:
                total += g.size * 4
            else:
                m = g.shape[0]
                n = int(g.size // m)
                total += 4 * self.rank * (m + n)
        return total
