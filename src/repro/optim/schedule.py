"""LR schedules (callables of the int32 step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: lr


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1),
                        0.0, 1.0)
        cos = peak_lr * (final_frac + (1 - final_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(s < warmup_steps, warm, cos)
    return sched


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup_steps, 1)
        decay = peak_lr * jnp.clip(
            (total_steps - s) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, decay)
    return sched
