"""Sharding rules + a reduced-device dry-run through a subprocess
(device count must be set before jax initializes, hence subprocess)."""
import os
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import (ShardingRules, leaf_spec, sanitize_spec,
                                     batch_specs)


RULES = ShardingRules()


def test_attention_tp_rules():
    assert leaf_spec(("blocks", "attn", "q", "w"), 3, RULES) == \
        P(None, "model", "data")
    assert leaf_spec(("blocks", "attn", "o", "w"), 3, RULES) == \
        P(None, "data", "model")


def test_mlp_tp_rules():
    assert leaf_spec(("blocks", "mlp", "up", "w"), 3, RULES) == \
        P(None, "model", "data")
    assert leaf_spec(("blocks", "mlp", "down", "w"), 3, RULES) == \
        P(None, "data", "model")


def test_pifa_rules():
    # rank shards on model: y_p is the (smaller) TP-gathered activation
    assert leaf_spec(("blocks", "mlp", "up", "wp"), 3, RULES) == \
        P(None, "model", "data")
    assert leaf_spec(("blocks", "mlp", "up", "c"), 3, RULES) == \
        P(None, "model", None)
    assert leaf_spec(("blocks", "mlp", "up", "inv_perm"), 2, RULES) == \
        P(None, None)


def test_norm_and_bias_replicated():
    assert leaf_spec(("blocks", "ln1", "scale"), 2, RULES) == P(None, None)
    assert leaf_spec(("blocks", "attn", "q", "b"), 2, RULES) == P(None, None)


def test_moe_expert_parallel():
    assert leaf_spec(("blocks", "moe", "up", "w"), 4, RULES) == \
        P(None, None, "model", "data")
    assert leaf_spec(("blocks", "moe", "router", "w"), 3, RULES) == \
        P(None, None, None)


def test_multipod_adds_pod_axis():
    import jax
    # fake mesh via axis name introspection only
    class FakeMesh:
        axis_names = ("pod", "data", "model")
    r = RULES.for_mesh(FakeMesh())
    assert r.data_axes == ("pod", "data")


def test_sanitize_drops_nondividing_axes():
    from repro.launch.mesh import _axis_type_kwargs
    import jax
    mesh = jax.make_mesh((1,), ("model",), **_axis_type_kwargs(1))

    class M:
        axis_names = ("model",)
        class devices:
            shape = (4,)
            size = 4
    spec = sanitize_spec(P("model", None), (49155, 64), M)
    assert spec == P(None, None)
    spec = sanitize_spec(P("model", None), (49152, 64), M)
    assert spec == P("model", None)


def test_batch_specs_long_context():
    shapes = {"token": np.zeros((1, 1), np.int32)}
    specs = batch_specs(shapes, RULES, shard_batch=False)
    assert specs["token"] == P(None, None)


@pytest.mark.slow
def test_reduced_mesh_dryrun_subprocess(tmp_path):
    """A 2x2x2 multi-pod dry-run must lower+compile end to end."""
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "stablelm_1p6b", "--shape", "decode_32k",
           "--mesh-spec", "2x2x2", "--out", str(tmp_path)]
    p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       cwd="/root/repo", timeout=900)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "'status': 'ok'" in p.stdout


@pytest.mark.slow
def test_pifa_compressed_dryrun_subprocess(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "stablelm_1p6b", "--shape", "decode_32k",
           "--mesh-spec", "2x4", "--compression", "pifa",
           "--out", str(tmp_path)]
    p = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       cwd="/root/repo", timeout=900)
    assert p.returncode == 0, p.stderr[-2000:]
    assert "'status': 'ok'" in p.stdout
