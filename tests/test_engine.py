"""Single-dispatch generation engine vs the legacy per-token loop.

Covers the PR's acceptance bar: scanned decode is token-for-token
identical to the legacy Python loop (dense, PIFA, bucketed MPIFA_NS),
MPIFA_NS no longer takes the O(T^2) full-recompute path, rank padding
is exact, and the fused bias+gather kernel epilogue matches
``apply_linear`` on unpadded shapes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.mpifa import (MpifaConfig, compress_linear_params,
                              pad_blocks_bucketed)
from repro.launch.serve import generate
from repro.models.model import build_model, restack_for_serving
from repro.runtime.engine import GenerationEngine

# shared session-scoped fixtures (tiny, tiny_pifa, tiny_ns) live in
# tests/conftest.py; PROMPT mirrors the fixture's prompt length
MAX_NEW = 8
PROMPT = 12
CACHE = PROMPT + MAX_NEW + 1


def test_engine_matches_legacy_dense(tiny):
    cfg, model, params, calib, prompts = tiny
    toks_l, _ = generate(model, params, prompts, MAX_NEW, CACHE)
    res = GenerationEngine(model).generate(params, prompts, MAX_NEW, CACHE)
    assert res.tokens.shape == toks_l.shape
    assert bool(jnp.all(res.tokens == toks_l))  # bit-identical greedy


def test_engine_matches_legacy_pifa(tiny, tiny_pifa):
    cfg, model, params, calib, prompts = tiny
    toks_l, _ = generate(model, tiny_pifa, prompts, MAX_NEW, CACHE,
                         unstacked=True)
    res = GenerationEngine(model).generate(tiny_pifa, prompts, MAX_NEW,
                                           CACHE)
    assert bool(jnp.all(res.tokens == toks_l))


def test_mpifa_ns_takes_scan_path(tiny, tiny_ns):
    """The NS acceptance assertion: heterogeneous ranks no longer hit
    the O(T^2) forward_unstacked fallback — the engine restacks them
    (padded, possibly bucketed) and matches the fallback's tokens."""
    cfg, model, params, calib, prompts = tiny
    # legacy restack (no padding) cannot unify these blocks ...
    assert model.restack_blocks(tiny_ns) is None
    engine = GenerationEngine(model, max_buckets=4)
    prepared = engine.prepare_params(tiny_ns)
    # ... the engine's padded restack can: no list-form blocks survive,
    # so no code path can reach forward_unstacked.
    assert not isinstance(prepared.get("blocks"), list)
    assert ("blocks" in prepared) != ("block_buckets" in prepared)
    toks_fallback, _ = generate(model, tiny_ns, prompts, MAX_NEW, CACHE,
                                unstacked=True)
    res = engine.generate(tiny_ns, prompts, MAX_NEW, CACHE)
    assert bool(jnp.all(res.tokens == toks_fallback))


@pytest.mark.parametrize("max_buckets", [1, 2])
def test_ns_bucket_counts_agree(tiny, tiny_ns, max_buckets):
    cfg, model, params, calib, prompts = tiny
    ref = GenerationEngine(model, max_buckets=4).generate(
        tiny_ns, prompts, MAX_NEW, CACHE)
    res = GenerationEngine(model, max_buckets=max_buckets).generate(
        tiny_ns, prompts, MAX_NEW, CACHE)
    assert bool(jnp.all(res.tokens == ref.tokens))


def test_rank_padding_is_exact(tiny, tiny_ns):
    """Padded+restacked prefill logits == list-form forward logits."""
    cfg, model, params, calib, prompts = tiny
    stacked = restack_for_serving(model, tiny_ns, max_buckets=1)
    logits_ref = model.forward_unstacked(tiny_ns, prompts)
    cache = model.init_cache(prompts.shape[0], CACHE, dtype=jnp.float32)
    logits_st, _ = model.prefill(stacked, prompts, cache)
    np.testing.assert_allclose(np.asarray(logits_st[:, 0, :]),
                               np.asarray(logits_ref[:, -1, :]),
                               rtol=1e-5, atol=1e-5)


def test_bucket_partition_structure(tiny_ns):
    blocks = tiny_ns["blocks"]
    buckets = pad_blocks_bucketed(blocks, 2)
    assert buckets is not None
    assert sum(len(b) for b in buckets) == len(blocks)
    for seg in buckets:
        sig0 = [(l.shape) for l in jax.tree_util.tree_leaves(seg[0])]
        for b in seg[1:]:
            assert [(l.shape) for l in jax.tree_util.tree_leaves(b)] == sig0


def test_engine_sampling(tiny):
    cfg, model, params, calib, prompts = tiny
    eng = GenerationEngine(model)
    k = jax.random.PRNGKey(7)
    r1 = eng.generate(params, prompts, MAX_NEW, CACHE, temperature=0.8,
                      top_k=4, key=k)
    r2 = eng.generate(params, prompts, MAX_NEW, CACHE, temperature=0.8,
                      top_k=4, key=k)
    # deterministic given the key ...
    assert bool(jnp.all(r1.tokens == r2.tokens))
    # ... different with another key (overwhelmingly likely)
    r3 = eng.generate(params, prompts, MAX_NEW, CACHE, temperature=0.8,
                      top_k=4, key=jax.random.PRNGKey(8))
    assert not bool(jnp.all(r1.tokens == r3.tokens))
    assert r1.tokens.shape == (prompts.shape[0], PROMPT + MAX_NEW)


def test_engine_eos_early_stop(tiny):
    cfg, model, params, calib, prompts = tiny
    eng = GenerationEngine(model)
    greedy = eng.generate(params, prompts, MAX_NEW, CACHE)
    # pick the token greedy emits at step 2 of row 0 as the fake eos
    eos = int(greedy.tokens[0, PROMPT + 1])
    res = eng.generate(params, prompts, MAX_NEW, CACHE, eos_id=eos)
    gen = np.asarray(res.tokens[:, PROMPT:])
    for row in gen:
        hits = np.nonzero(row == eos)[0]
        if hits.size:
            assert np.all(row[hits[0]:] == eos)  # masked after stop
    assert res.generated <= gen.size


def test_engine_hybrid_and_ssm_families():
    """The scan engine serves every family, not just transformers."""
    for arch in ("mamba2_2p7b", "zamba2_1p2b"):
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        prompts = jnp.asarray(
            np.random.default_rng(1).integers(0, cfg.vocab_size, (2, 8)),
            jnp.int32)
        toks_l, _ = generate(model, params, prompts, 4, 13)
        res = GenerationEngine(model).generate(params, prompts, 4, 13)
        assert bool(jnp.all(res.tokens == toks_l)), arch


def test_mamba_restack_hooks_padded():
    """Heterogeneous-rank compressed mamba blocks re-enter the scan."""
    cfg = get_smoke_config("mamba2_2p7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lst = model.unstack_blocks(params)
    blocks = list(lst["blocks"])
    mc_lo = MpifaConfig(density=0.4, prune="svd", reconstruct="none")
    mc_hi = MpifaConfig(density=0.7, prune="svd", reconstruct="none")
    for i, bp in enumerate(blocks):
        mc = mc_lo if i % 2 == 0 else mc_hi
        bp = dict(bp)
        bp["in_proj"] = compress_linear_params(mc, bp["in_proj"])
        bp["out_proj"] = compress_linear_params(mc, bp["out_proj"])
        blocks[i] = bp
    lst = dict(lst)
    lst["blocks"] = blocks
    assert model.restack_blocks(lst) is None  # heterogeneous
    stacked = model.restack_blocks(lst, pad=True)
    assert stacked is not None
    assert not isinstance(stacked["blocks"], list)
    # ground truth: eager per-block loop over the list form
    from repro.models import layers as L
    from repro.models.mamba2 import mamba_block_apply
    toks = jnp.asarray(
        np.random.default_rng(2).integers(0, cfg.vocab_size, (2, 8)),
        jnp.int32)
    h = L.embed(lst["embed"], toks)
    for bp in blocks:
        h, _ = mamba_block_apply(bp, h, cfg)
    h = L.apply_norm(lst["final_norm"], h, cfg.norm_eps)
    ref = L.unembed(lst["embed"], h)
    got = model.forward(stacked, toks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_encdec_restack_roundtrip():
    cfg = get_smoke_config("whisper_medium")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lst = model.unstack_blocks(params)
    assert isinstance(lst["dec_blocks"], list)
    back = model.restack_blocks(lst)
    assert back is not None
    rng = np.random.default_rng(3)
    batch = {"frames": jnp.asarray(rng.normal(size=(1, cfg.encoder_seq,
                                                    cfg.d_model)) * 0.1,
                                   jnp.float32),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)),
                                   jnp.int32)}
    ref = model.forward(params, batch)
    got = model.forward(back, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# Fused kernel epilogue vs apply_linear (unpadded shapes).
# ---------------------------------------------------------------------------

def _mk_pifa_linear(rng, m, n, r, bias=True, folded=False):
    from repro.core.pifa import pivoting_factorize
    from repro.models.linear import pifa_linear
    w = rng.normal(size=(m, r)) @ rng.normal(size=(r, n))
    f = pivoting_factorize(w, r, dtype=jnp.float32)
    return pifa_linear(f, bias=rng.normal(size=(m,)) if bias else None,
                       dtype=jnp.float32, folded=folded)


@pytest.mark.parametrize("shape", [(5, 48, 96, 17), (1, 33, 70, 9),
                                   (16, 128, 128, 40)])
@pytest.mark.parametrize("bias", [True, False])
def test_fused_epilogue_matches_apply_linear(shape, bias):
    from repro.kernels.pifa_matmul.ops import pifa_matmul_fused
    from repro.models.linear import apply_linear
    b, n, m, r = shape
    rng = np.random.default_rng(b * 3 + m)
    p = _mk_pifa_linear(rng, m, n, r, bias=bias)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    y_ref = apply_linear(p, x)
    y = pifa_matmul_fused(x, p["wp"], p["c"], p["inv_perm"], p.get("b"),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_fused_epilogue_folded():
    from repro.kernels.pifa_matmul.ops import pifa_matmul_fused
    from repro.models.linear import apply_linear
    rng = np.random.default_rng(0)
    p = _mk_pifa_linear(rng, 64, 48, 12, bias=True, folded=True)
    x = jnp.asarray(rng.normal(size=(3, 48)), jnp.float32)
    y_ref = apply_linear(p, x)
    y = pifa_matmul_fused(x, p["wp"], p["c"], None, p.get("b"),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_apply_linear_kernel_mode():
    """The REPRO_PIFA_KERNEL switch routes apply_linear through the
    fused kernel and matches the jnp path on unpadded shapes."""
    from repro.models.linear import apply_linear, set_pifa_kernel
    rng = np.random.default_rng(4)
    p = _mk_pifa_linear(rng, 80, 56, 21, bias=True)
    x = jnp.asarray(rng.normal(size=(2, 7, 56)), jnp.float32)
    y_jnp = apply_linear(p, x)
    prev = set_pifa_kernel(True)
    try:
        y_krn = apply_linear(p, x)
    finally:
        set_pifa_kernel(prev)
    np.testing.assert_allclose(np.asarray(y_krn), np.asarray(y_jnp),
                               rtol=1e-5, atol=1e-5)


def test_select_block_sizes():
    from repro.kernels.pifa_matmul.ops import select_block_sizes
    assert select_block_sizes(1, 4096, 512, 3584) == (8, 128)
    assert select_block_sizes(8, 4096, 512, 3584) == (8, 128)
    assert select_block_sizes(33, 4096, 512, 3584) == (64, 128)
    assert select_block_sizes(512, 4096, 512, 3584) == (128, 256)
    assert select_block_sizes(512, 128, 64, 64) == (128, 128)
