"""Trip-count-aware HLO cost model (parallel/hlo_cost.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.parallel.hlo_cost import analyze_hlo_text
from repro.parallel.hlo_stats import collective_bytes as legacy_collective


def _scan_fn(n_layers, unroll=1):
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws, unroll=unroll)
        return c
    return f


def test_scan_flops_scale_with_trip_count():
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    vals = {}
    for L in (2, 8):
        ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
        txt = jax.jit(_scan_fn(L)).lower(ws, x).compile().as_text()
        vals[L] = analyze_hlo_text(txt)
    expect = lambda L: 2 * 64 * 128 * 128 * L
    for L, r in vals.items():
        assert abs(r.flops - expect(L)) / expect(L) < 0.05
        assert r.num_whiles == 1
        assert r.max_trip_count == L


def test_scan_equals_unroll():
    L = 4
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
    scan_r = analyze_hlo_text(
        jax.jit(_scan_fn(L)).lower(ws, x).compile().as_text())
    unroll_r = analyze_hlo_text(
        jax.jit(_scan_fn(L, unroll=L)).lower(ws, x).compile().as_text())
    assert abs(scan_r.flops - unroll_r.flops) / unroll_r.flops < 0.05


def test_nested_scan_multiplies():
    def f(x):
        def outer(c, _):
            def inner(ci, __):
                return jnp.tanh(ci @ ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    r = analyze_hlo_text(jax.jit(f).lower(x).compile().as_text())
    expect = 2 * 32 * 32 * 32 * 15  # 5 * 3 dots
    assert abs(r.flops - expect) / expect < 0.1


def test_dot_contraction_dims_parsed():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jax.ShapeDtypeStruct((4, 8, 16), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 16, 32), jnp.float32)
    r = analyze_hlo_text(jax.jit(f).lower(a, b).compile().as_text())
    expect = 2 * 4 * 8 * 32 * 16
    assert abs(r.flops - expect) / expect < 0.05


def test_dus_counts_slice_not_buffer():
    def f(buf, x):
        return jax.lax.dynamic_update_slice(buf, x, (0, 0))
    buf = jax.ShapeDtypeStruct((4096, 256), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    r = analyze_hlo_text(jax.jit(f, donate_argnums=(0,)).lower(buf, x)
                         .compile().as_text())
    # in-place: ~2 * slice bytes, NOT the 4 MB buffer
    assert r.bytes_accessed < 64 * 1024


def test_parser_handles_synthetic_collectives():
    txt = """
HloModule test

ENTRY %main (p0: f32[128,64]) -> f32[128,64] {
  %p0 = f32[128,64]{1,0} parameter(0)
  %ag = f32[512,64]{1,0} all-gather(%p0), replica_groups={}, dimensions={0}
  %ar = f32[128,64]{1,0} all-reduce(%p0), to_apply=%add
  ROOT %out = f32[128,64]{1,0} add(%ar, %ar)
}
"""
    r = analyze_hlo_text(txt)
    assert r.collective_breakdown["all-gather"] == 128 * 64 * 4
    assert r.collective_breakdown["all-reduce"] == 128 * 64 * 4


def test_slice_fusion_counted_as_slice():
    """Per-layer weight slicing out of stacked scan xs must cost the
    slice, not the stack (x trip count)."""
    def f(ws, x):
        def body(c, w):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, ws)
        return c

    L = 16
    ws = jax.ShapeDtypeStruct((L, 128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    r = analyze_hlo_text(jax.jit(f).lower(ws, x).compile().as_text())
    stack_bytes = L * 128 * 128 * 4
    # if each of the L iterations were charged the full stack, bytes
    # would exceed L * stack; the slice accounting keeps it ~2x stack.
    assert r.bytes_accessed < 6 * stack_bytes


def test_dus_under_convert_root():
    """Cache updates fused under a convert root still count as slices."""
    def f(buf, x):
        out = jax.lax.dynamic_update_slice(buf.astype(jnp.float32),
                                           x, (0, 0))
        return out.astype(jnp.bfloat16)

    buf = jax.ShapeDtypeStruct((8192, 256), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((1, 256), jnp.float32)
    r = analyze_hlo_text(jax.jit(f, donate_argnums=(0,)).lower(buf, x)
                         .compile().as_text())
    assert r.bytes_accessed < 1024 * 1024  # not the 4 MB buffer
