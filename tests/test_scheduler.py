"""Continuous-batching scheduler: slot-allocator invariants, bit-exact
generation under staggered admission, chunk-boundary edges, ring-cache
bucketed restacking, and per-bucket kernel block-size registration.

The acceptance bar mirrors ISSUE 2: every request served through the
slot-allocated cache must be token-for-token identical to a
single-request ``GenerationEngine.generate`` of the same prompt under
greedy decoding — padding, per-slot positions and mid-flight admission
must all be invisible in the output.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.core.mpifa import (MpifaConfig, bucket_boundaries,
                              compress_linear_params)
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.scheduler import Request, ServingScheduler


# shared session-scoped fixtures (tiny, engine, tiny_ns) live in
# tests/conftest.py


def _requests(cfg, lens, budgets, seed=0, arrivals=None):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m),
                    arrival_time=0.0 if arrivals is None else arrivals[i])
            for i, (l, m) in enumerate(zip(lens, budgets))]


def _assert_bit_identical(engine, params, run, requests, eos_id):
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = requests[r.request_id]
        ref = np.asarray(engine.generate(
            params, jnp.asarray(req.prompt[None, :]), req.max_new,
            eos_id=eos_id).tokens[0])
        n = r.prompt_len + r.generated
        assert r.generated >= 1
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from single-request engine")


# --------------------------------------------------------------- allocator

def test_slot_allocator_invariants(tiny):
    """No double-assign (per-slot residency intervals never overlap),
    every request served exactly once, all slots free after the drain."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 7, 12, 4, 10], budgets=[4, 2, 6, 3, 5, 2])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8, 16))
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == list(range(6))
    assert all(0 <= r.slot < 2 for r in run.results)
    assert len(sched._free) == sched.capacity          # all freed
    assert all(st.request is None for st in sched._slots)
    by_slot = {}
    for r in run.results:
        by_slot.setdefault(r.slot, []).append((r.admitted_at, r.finished_at))
    for intervals in by_slot.values():
        intervals.sort()
        for (a0, f0), (a1, _) in zip(intervals, intervals[1:]):
            assert f0 <= a1, "slot re-assigned while still occupied"
    assert all(occ <= 2 for _, occ in run.occupancy)
    assert run.generated == sum(r.generated for r in run.results)


def test_free_on_eos_and_reuse(tiny, engine):
    """A request stopping early on eos frees its slot for the queue."""
    cfg, model, params = tiny[:3]
    probe = _requests(cfg, lens=[8], budgets=[16])[0]
    ref = np.asarray(engine.generate(
        params, jnp.asarray(probe.prompt[None, :]), 16).tokens[0])
    eos = int(ref[8 + 2])       # third generated token => stops at 3
    reqs = _requests(cfg, lens=[8, 6, 11], budgets=[16, 4, 4], seed=0)
    sched = ServingScheduler(model, params, capacity=1, chunk=4,
                             eos_id=eos, prompt_buckets=(8, 16))
    run = sched.run(reqs)
    r0 = next(r for r in run.results if r.request_id == 0)
    assert r0.generated == 3                      # eos cut the budget
    assert int(r0.tokens[-1]) == eos
    # later requests were admitted into the freed single slot
    assert sorted(r.request_id for r in run.results) == [0, 1, 2]
    _assert_bit_identical(engine, params, run, reqs, eos)


# ------------------------------------------------------------ bit identity

def test_bit_identity_staggered_admission(tiny, engine):
    """Mixed prompt lengths/budgets through 2 slots: every request's
    tokens match the single-request engine bit-for-bit (greedy)."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 12, 9, 16, 3], budgets=[6, 3, 8, 2, 7])
    sched = ServingScheduler(model, params, capacity=2, chunk=3,
                             eos_id=1, prompt_buckets=(8, 16))
    run = sched.run(reqs)
    assert len(run.results) == 5
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)


def test_bit_identity_compressed_ns(tiny, tiny_ns):
    """MPIFA_NS (heterogeneous ranks -> bucketed restack) serves through
    the scheduler bit-identically to the engine."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[6, 11, 4], budgets=[5, 3, 6])
    sched = ServingScheduler(model, tiny_ns, capacity=2, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16))
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, tiny_ns, run, reqs, eos_id=1)


def test_drain_mode_same_tokens(tiny, engine):
    """Run-to-completion admission changes scheduling, never tokens."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 13, 7], budgets=[4, 6, 2, 5])
    runs = {}
    for mode in ("continuous", "drain"):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 admission=mode, prompt_buckets=(8, 16))
        runs[mode] = {r.request_id: r.tokens
                      for r in sched.run(list(reqs)).results}
    for rid in runs["continuous"]:
        assert np.array_equal(runs["continuous"][rid], runs["drain"][rid])


# ----------------------------------------------------------- chunk edges

def test_finish_exactly_at_chunk_boundary(tiny, engine):
    """Budgets that are exact chunk multiples finish at a boundary; the
    slot frees and refills without dropping or duplicating tokens."""
    cfg, model, params = tiny[:3]
    chunk = 4
    reqs = _requests(cfg, lens=[6, 8, 10, 5], budgets=[4, 8, 4, 8])
    sched = ServingScheduler(model, params, capacity=2, chunk=chunk,
                             prompt_buckets=(8, 16))
    run = sched.run(reqs)
    assert len(run.results) == 4
    for r in run.results:
        assert r.generated == reqs[r.request_id].max_new
    _assert_bit_identical(engine, params, run, reqs, eos_id=None)
    # deterministic timeline (arrivals at 0, FIFO admission):
    #   chunk 1: slots (r0 b4, r1 b8) -> r0 finishes AT the boundary
    #   chunk 2: (r2 b4, r1) -> both finish at the boundary
    #   chunks 3-4: r3 (b8) alone
    assert run.chunks == 4


def test_oversized_request_leaves_state_intact(tiny):
    """A request that cannot fit the cache raises BEFORE its queue
    entry and any free slot are consumed: the scheduler stays usable
    after dropping the offender."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 6], budgets=[4, 4])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,), cache_len=16)
    big = Request(request_id=9, prompt=np.zeros(5, np.int32), max_new=50)
    with pytest.raises(ValueError, match="exceeds cache_len"):
        sched.run([big] + list(reqs))
    assert len(sched._free) == sched.capacity      # no slot leaked
    assert len(sched._queue) == 3                  # nothing lost
    # drop the offender by id (queue order is (-priority, arrival, id),
    # so the late-submitted big request is not necessarily the head)
    sched._queue = type(sched._queue)(
        r for r in sched._queue if r.request_id != big.request_id)
    run = sched.run()
    assert sorted(r.request_id for r in run.results) == [0, 1]


def test_deferral_reasons_reported(tiny):
    """An arrived request that cannot be admitted is counted in
    SchedulerRun.deferrals with WHY (here: all slots busy -> no_slot)
    instead of a bare retry; an unconstrained run reports none."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 6, 7], budgets=[8, 8, 8])
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(8,))
    run = sched.run(reqs)
    assert run.deferrals.get("no_slot", 0) > 0
    assert "no_pages" not in run.deferrals     # contiguous: never pages
    roomy = ServingScheduler(model, params, capacity=4, chunk=2,
                             prompt_buckets=(8,))
    assert roomy.run(_requests(cfg, [5, 6], [4, 4])).deferrals == {}


def test_arrival_times_respected(tiny):
    """A request with a future arrival_time is not admitted before it."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[6, 6], budgets=[4, 4],
                     arrivals=[0.0, 0.15])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,))
    run = sched.run(reqs)
    r1 = next(r for r in run.results if r.request_id == 1)
    assert r1.admitted_at >= 0.15


@pytest.mark.parametrize("arch", ["mamba2_2p7b", "zamba2_1p2b"])
def test_bit_identity_ssm_families(arch):
    """mamba2/hybrid serve through the same scheduler (exact-length
    prefills — the SSM state integrates every token, so prompt buckets
    are disabled for these families) bit-identically to the engine."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5, 11], budgets=[4, 2, 5, 3])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1)
    assert sched.prompt_buckets is None
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, params, run, reqs, eos_id=1)


def test_bit_identity_ring_arch_scheduler():
    """Ring-cache (local:global) archs get exact-length slot prefills
    forced (padded prompts would plant pad k/v in the circular buffer
    at slots the decode position formula treats as real past) and then
    serve bit-identically through the scheduler."""
    cfg = get_smoke_config("gemma3_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[10, 6, 13], budgets=[8, 4, 6])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,),
                             cache_len=13 + 8 + 1)   # > window: ring engages
    assert sched.prompt_buckets is None    # forced exact for ring archs
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, params, run, reqs, eos_id=None)


# ------------------------------------------------- ring-cache bucketing

def test_ring_bucketed_restack_decodes():
    """gemma3-style local:global arch with heterogeneous PIFA ranks:
    restacking now produces stage-aligned rank buckets and the RING
    decode path consumes them — bit-identical to the unstacked loop
    (previously ring archs were forced to a single uniform stack)."""
    from repro.launch.serve import generate
    cfg = dataclasses.replace(get_smoke_config("gemma3_12b"), num_layers=6)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    lp = model.unstack_blocks(params)
    blocks = []
    for i, bp in enumerate(lp["blocks"]):
        mc = MpifaConfig(density=0.35 if i < 3 else 0.75)
        nb = dict(bp)
        nb["attn"] = dict(bp["attn"])
        nb["attn"]["q"] = compress_linear_params(mc, bp["attn"]["q"])
        nb["mlp"] = dict(bp["mlp"])
        nb["mlp"]["up"] = compress_linear_params(mc, bp["mlp"]["up"])
        blocks.append(nb)
    lp = dict(lp)
    lp["blocks"] = blocks
    restacked = model.restack_blocks(lp, pad=True, max_buckets=4)
    assert "block_buckets" in restacked, "expected stage-aligned buckets"
    seg_sizes = [jax.tree_util.tree_leaves(s)[0].shape[0]
                 for s in restacked["block_buckets"]]
    stage = cfg.local_global_ratio + 1
    assert all(s % stage == 0 for s in seg_sizes)
    prompts = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12)), jnp.int32)
    cache_len = 12 + 8 + 1          # > sliding_window: ring caches engage
    toks_l, _ = generate(model, lp, prompts, 8, cache_len, unstacked=True)
    res = GenerationEngine(model, max_buckets=4).generate(
        lp, prompts, 8, cache_len)
    assert bool(jnp.all(res.tokens == toks_l))


def test_bucket_boundaries_granularity():
    """Boundaries only land on multiples of ``granularity``."""
    def blk(r):
        return {"lin": {"wp": np.zeros((r, 16), np.float32),
                        "c": np.zeros((16 - r, r), np.float32),
                        "inv_perm": np.arange(16, dtype=np.int32)}}

    blocks = [blk(r) for r in (4, 4, 12, 12, 4, 4)]
    parts = bucket_boundaries(blocks, max_buckets=4)
    assert len(parts) > 1                     # rank spread pays for splits
    parts_g = bucket_boundaries(blocks, max_buckets=4, granularity=3)
    assert all((i % 3, j % 3) == (0, 0) for i, j in parts_g)
    # indivisible layer count falls back to granularity 1
    parts_f = bucket_boundaries(blocks[:5], max_buckets=2, granularity=3)
    assert parts_f is not None


# --------------------------------------------------- per-slot sampling

def test_scheduler_sampling_deterministic_per_seed(tiny):
    """Temperature/top-k decoding draws from per-slot PRNG keys split
    at admission: the same seed reproduces every request's stream, a
    different seed changes it, tokens stay in-vocab."""
    cfg, model, params = tiny[:3]

    def run_with(seed):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 prompt_buckets=(8, 16),
                                 temperature=0.8, top_k=4,
                                 sample_seed=seed)
        reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 5])
        return {r.request_id: r.tokens.tolist()
                for r in sched.run(reqs).results}

    r1, r2, r3 = run_with(7), run_with(7), run_with(8)
    assert r1 == r2
    assert r1 != r3
    assert all(t < cfg.vocab_size for toks in r1.values() for t in toks)


def test_scheduler_sampling_unaffected_by_slot_placement(tiny):
    """A request's sample stream comes from its admission-split key,
    NOT from which slot or chunk boundary it lands on: serving the same
    request alone or behind a queue yields the same tokens."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[6, 6, 6], budgets=[5, 5, 5])

    def serve(queue):
        sched = ServingScheduler(model, params, capacity=1, chunk=2,
                                 prompt_buckets=(8,), temperature=0.6,
                                 sample_seed=3)
        return {r.request_id: r.tokens.tolist()
                for r in sched.run(queue).results}

    # per-request keys are fold_in(scheduler key, request_id), so
    # request 0 sees the same key whether or not others queue behind it
    alone = serve([reqs[0]])
    queued = serve(list(reqs))
    assert queued[0] == alone[0]


def test_scheduler_greedy_rejects_top_k(tiny):
    cfg, model, params = tiny[:3]
    with pytest.raises(ValueError, match="top_k"):
        ServingScheduler(model, params, top_k=8)


# --------------------------------------------------- batched admission

def test_batched_admission_bit_identity(tiny, engine):
    """A simultaneous same-bucket burst admits through grouped batch-k
    prefills (k in ADMIT_BATCH) — one dispatch per group, outputs still
    bit-identical to the single-request engine."""
    from repro.runtime.scheduler import ADMIT_BATCH
    cfg, model, params = tiny[:3]
    # 7 same-bucket arrivals into 8 free slots -> groups of 4 + 2 + 1
    reqs = _requests(cfg, lens=[5, 6, 7, 5, 8, 6, 4],
                     budgets=[4, 6, 3, 5, 4, 2, 6])
    sched = ServingScheduler(model, params, capacity=8, chunk=2,
                             prompt_buckets=(8,))
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == list(range(7))
    _assert_bit_identical(engine, params, run, reqs, eos_id=None)
    # jit-cache key space stays capped at (bucket, k, shared-prefix)
    # triples — sh is 0 everywhere without a prefix cache
    assert set(sched._admit_fns) == {(8, 4, 0), (8, 2, 0), (8, 1, 0)}
    assert all(kb in ADMIT_BATCH for _, kb, _sh in sched._admit_fns)


def test_batched_admission_mixed_buckets(tiny, engine):
    """Admissions spanning buckets group per bucket; each group pays
    its own batch-k prefill and every request still serves exactly."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 14, 6, 12, 7, 3],
                     budgets=[4, 3, 5, 6, 2, 4])
    sched = ServingScheduler(model, params, capacity=6, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16))
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == list(range(6))
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)
    assert all(kb in (1, 2, 4) for _, kb, _sh in sched._admit_fns)


# ------------------------------------------------- per-bucket block sizes

def test_autotune_registry_and_numerics():
    from repro.kernels.pifa_matmul.autotune import (
        clear_block_size_registry, lookup_block_sizes, register_block_sizes)
    from repro.kernels.pifa_matmul.ops import pifa_matmul_fused
    key = jax.random.PRNGKey(0)
    kx, kw, kc = jax.random.split(key, 3)
    b, n, r, mnp = 4, 32, 16, 16
    x = jax.random.normal(kx, (b, n))
    wp = jax.random.normal(kw, (r, n))
    c = jax.random.normal(kc, (mnp, r))
    ref = pifa_matmul_fused(x, wp, c, use_kernel=False)
    clear_block_size_registry()
    try:
        y_default = pifa_matmul_fused(x, wp, c)
        register_block_sizes(b, n, r, 16, 128)   # non-heuristic choice
        assert lookup_block_sizes(b, n, r) == (16, 128)
        y_tuned = pifa_matmul_fused(x, wp, c)
        np.testing.assert_allclose(np.asarray(y_default), np.asarray(ref),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_tuned), np.asarray(ref),
                                   atol=1e-4)
    finally:
        clear_block_size_registry()


def test_tune_pifa_params_registers_buckets(tiny, tiny_ns):
    """Restacked NS params expose one tuned entry per bucket rank."""
    from repro.kernels.pifa_matmul.autotune import (
        clear_block_size_registry, registry_snapshot, tune_pifa_params)
    cfg, model, params = tiny[:3]
    restacked = model.restack_blocks(tiny_ns, pad=True, max_buckets=4)
    clear_block_size_registry()
    try:
        chosen = tune_pifa_params(restacked, batch=4)
        snap = registry_snapshot()
        assert chosen and set(chosen) == set(snap)
        assert all(k[0] == 4 for k in snap)        # keyed on decode batch
        ranks = {k[2] for k in snap}
        assert len(ranks) > 1, "expected distinct per-bucket ranks"
    finally:
        clear_block_size_registry()
