"""Heartbeats, straggler detection, restart backoff, fault plans
(runtime/).  Every component runs against the shared ``fake_clock``
fixture (conftest.py) — the same injectable clock the scheduler's
admission backoff uses — so no robustness test sleeps on wall-clock
time."""
import pytest

from repro.runtime.elastic import plan_mesh
from repro.runtime.fault_tolerance import (FaultPlan, HeartbeatRegistry,
                                           InjectedFault, RestartPolicy,
                                           SchedulerCrash,
                                           StragglerDetector)


def test_heartbeat_detects_dead_host(fake_clock):
    clock = fake_clock
    hb = HeartbeatRegistry(timeout_s=10, clock=clock)
    for h in ("h0", "h1", "h2"):
        hb.beat(h)
    clock.t = 5
    hb.beat("h0")
    hb.beat("h1")
    clock.t = 12  # h2 silent for 12s > 10s
    assert hb.check() == ["h2"]
    assert sorted(hb.alive()) == ["h0", "h1"]
    # recovery
    hb.beat("h2")
    assert hb.check() == []
    assert "h2" in hb.alive()


def test_straggler_needs_patience():
    sd = StragglerDetector(threshold=1.5, patience=2)
    for step in range(3):
        for h in ("a", "b", "c", "d"):
            sd.record(h, 1.0 if h != "d" else 3.0)
        flagged = sd.stragglers()
    assert flagged == ["d"]


def test_straggler_single_spike_not_flagged():
    sd = StragglerDetector(threshold=1.5, patience=3, ewma=1.0)
    for h in ("a", "b", "c", "d"):
        sd.record(h, 1.0)
    sd.record("d", 5.0)
    assert sd.stragglers() == []          # strike 1 of 3
    sd.record("d", 1.0)
    assert sd.stragglers() == []          # recovered, strikes reset
    sd.record("d", 1.0)
    assert sd.stragglers() == []


def test_straggler_poll_does_not_double_count():
    """Regression: polling ``stragglers()`` twice between records must
    not burn patience at 2x.  Strikes advance at most once per new
    fleet observation, and an already-flagged host stays flagged while
    no new data arrives (its strike count frozen, not drifting)."""
    sd = StragglerDetector(threshold=1.5, patience=2, ewma=1.0)
    for h in ("a", "b", "c"):
        sd.record(h, 1.0)
    sd.record("d", 4.0)
    assert sd.stragglers() == []           # strike 1 of 2
    # a second poll with NO new observation must not add strike 2
    assert sd.stragglers() == []
    assert sd.strikes["d"] == 1
    sd.record("d", 4.0)
    assert sd.stragglers() == ["d"]        # strike 2: flagged
    # stays flagged across data-free polls without strike drift
    assert sd.stragglers() == ["d"]
    assert sd.strikes["d"] == 2


def test_heartbeat_register_opens_silence_window(fake_clock):
    """A host that dies BEFORE its first beat is still reported dead:
    ``register()`` opens the silence window at expected-join time."""
    clock = fake_clock
    hb = HeartbeatRegistry(timeout_s=10, clock=clock)
    hb.beat("h0")
    hb.register("h1")                      # expected to join, never beats
    clock.t = 5
    hb.beat("h0")
    hb.register("h0")                      # no-op: must NOT reset h0's seen
    assert hb.last_seen["h0"] == 5
    clock.t = 12
    assert hb.check() == ["h1"]
    assert hb.alive() == ["h0"]


def test_restart_backoff_and_budget(fake_clock):
    clock = fake_clock
    rp = RestartPolicy(max_restarts=3, window_s=100, base_backoff_s=1,
                       max_backoff_s=8, clock=clock)
    assert rp.on_failure() == 1
    assert rp.on_failure() == 2
    assert rp.on_failure() == 4
    assert rp.on_failure() is None       # budget exhausted
    clock.t = 200                        # window expired: budget refills
    assert rp.on_failure() == 1


def test_restart_window_prunes_old_crashes(fake_clock):
    """Crashes older than the window stop counting against the budget:
    a slow trickle of failures never escalates past base backoff."""
    clock = fake_clock
    rp = RestartPolicy(max_restarts=3, window_s=100, base_backoff_s=1,
                       max_backoff_s=64, clock=clock)
    assert rp.on_failure() == 1            # t=0
    clock.advance(60)
    assert rp.on_failure() == 2            # t=60: both in window
    clock.advance(60)
    assert rp.on_failure() == 2            # t=120: t=0 crash pruned
    assert len(rp.crashes) == 2


def test_restart_gives_up_then_recovers(fake_clock):
    """Budget exhaustion is not permanent: once the crash storm ages out
    of the window, the policy restarts again from base backoff."""
    clock = fake_clock
    rp = RestartPolicy(max_restarts=2, window_s=50, base_backoff_s=1,
                       max_backoff_s=8, clock=clock)
    assert rp.on_failure() == 1            # t=0
    clock.advance(1)
    assert rp.on_failure() == 2            # t=1
    clock.advance(1)
    assert rp.on_failure() is None         # t=2: 3 crashes > budget of 2
    clock.advance(60)                      # storm ages out of the window
    assert rp.on_failure() == 1
    assert len(rp.crashes) == 1


def test_crash_fault_kind():
    """``crash`` is a plannable kind and SchedulerCrash carries the
    boundary step (durability tests drive the full recovery path)."""
    plan = FaultPlan().at(3, "crash")
    assert plan.take(3) == [("crash", None)]
    err = SchedulerCrash(3)
    assert isinstance(err, RuntimeError) and err.step == 3
    assert "crash" in FaultPlan.KINDS


def test_fault_plan_actions_fire_once():
    plan = (FaultPlan().at(2, "cancel", 7).at(2, "clock_skew", 1.5)
            .at(5, "dispatch_error"))
    assert plan.pending() == 3
    assert plan.take(0) == []
    acts = plan.take(2)
    assert ("cancel", 7) in acts and ("clock_skew", 1.5) in acts
    assert plan.take(2) == []          # a retried boundary won't re-fire
    assert plan.take(5) == [("dispatch_error", None)]
    assert plan.pending() == 0
    assert [(s, k) for s, k, _ in plan.fired] == [(2, "cancel"),
                                                  (2, "clock_skew"),
                                                  (5, "dispatch_error")]
    with pytest.raises(ValueError):
        plan.at(0, "meteor_strike")
    assert isinstance(InjectedFault("x"), RuntimeError)


def test_allocator_fault_injection():
    from repro.runtime.paging import PageAllocator, PoolExhausted
    alloc = PageAllocator(num_pages=8, page_size=4, capacity=2, n_logical=4)
    alloc.inject_fault()
    with pytest.raises(PoolExhausted):
        alloc.admit(0, 4, 8)
    # armed fault consumed; state untouched — the same call now works
    alloc.admit(0, 4, 8)
    alloc.check_invariants()
    alloc.inject_fault()
    with pytest.raises(PoolExhausted):
        alloc.extend(0, 8)
    alloc.extend(0, 8)
    alloc.check_invariants()
    alloc.free(0)
    assert alloc.free_pages == 8


def test_elastic_plan_shrink_grow():
    full = plan_mesh(256, model_parallel=16, global_batch=256)
    assert full.mesh_shape == (16, 16)
    shrunk = plan_mesh(192, model_parallel=16, global_batch=256)
    assert shrunk.mesh_shape[1] == 16
    assert shrunk.chips_used <= 192
    assert 256 % shrunk.mesh_shape[0] == 0   # batch still divides
    pods = plan_mesh(512, model_parallel=16, global_batch=256, pods=2)
    assert pods.mesh_shape == (2, 16, 16)
    with pytest.raises(ValueError):
        plan_mesh(8, model_parallel=16)
