"""Heartbeats, straggler detection, restart backoff, fault plans
(runtime/).  Every component runs against the shared ``fake_clock``
fixture (conftest.py) — the same injectable clock the scheduler's
admission backoff uses — so no robustness test sleeps on wall-clock
time."""
import pytest

from repro.runtime.elastic import plan_mesh
from repro.runtime.fault_tolerance import (FaultPlan, HeartbeatRegistry,
                                           InjectedFault, RestartPolicy,
                                           StragglerDetector)


def test_heartbeat_detects_dead_host(fake_clock):
    clock = fake_clock
    hb = HeartbeatRegistry(timeout_s=10, clock=clock)
    for h in ("h0", "h1", "h2"):
        hb.beat(h)
    clock.t = 5
    hb.beat("h0")
    hb.beat("h1")
    clock.t = 12  # h2 silent for 12s > 10s
    assert hb.check() == ["h2"]
    assert sorted(hb.alive()) == ["h0", "h1"]
    # recovery
    hb.beat("h2")
    assert hb.check() == []
    assert "h2" in hb.alive()


def test_straggler_needs_patience():
    sd = StragglerDetector(threshold=1.5, patience=2)
    for step in range(3):
        for h in ("a", "b", "c", "d"):
            sd.record(h, 1.0 if h != "d" else 3.0)
        flagged = sd.stragglers()
    assert flagged == ["d"]


def test_straggler_single_spike_not_flagged():
    sd = StragglerDetector(threshold=1.5, patience=3, ewma=1.0)
    for h in ("a", "b", "c", "d"):
        sd.record(h, 1.0)
    sd.record("d", 5.0)
    assert sd.stragglers() == []          # strike 1 of 3
    sd.record("d", 1.0)
    assert sd.stragglers() == []          # recovered, strikes reset
    sd.record("d", 1.0)
    assert sd.stragglers() == []


def test_restart_backoff_and_budget(fake_clock):
    clock = fake_clock
    rp = RestartPolicy(max_restarts=3, window_s=100, base_backoff_s=1,
                       max_backoff_s=8, clock=clock)
    assert rp.on_failure() == 1
    assert rp.on_failure() == 2
    assert rp.on_failure() == 4
    assert rp.on_failure() is None       # budget exhausted
    clock.t = 200                        # window expired: budget refills
    assert rp.on_failure() == 1


def test_fault_plan_actions_fire_once():
    plan = (FaultPlan().at(2, "cancel", 7).at(2, "clock_skew", 1.5)
            .at(5, "dispatch_error"))
    assert plan.pending() == 3
    assert plan.take(0) == []
    acts = plan.take(2)
    assert ("cancel", 7) in acts and ("clock_skew", 1.5) in acts
    assert plan.take(2) == []          # a retried boundary won't re-fire
    assert plan.take(5) == [("dispatch_error", None)]
    assert plan.pending() == 0
    assert [(s, k) for s, k, _ in plan.fired] == [(2, "cancel"),
                                                  (2, "clock_skew"),
                                                  (5, "dispatch_error")]
    with pytest.raises(ValueError):
        plan.at(0, "meteor_strike")
    assert isinstance(InjectedFault("x"), RuntimeError)


def test_allocator_fault_injection():
    from repro.runtime.paging import PageAllocator, PoolExhausted
    alloc = PageAllocator(num_pages=8, page_size=4, capacity=2, n_logical=4)
    alloc.inject_fault()
    with pytest.raises(PoolExhausted):
        alloc.admit(0, 4, 8)
    # armed fault consumed; state untouched — the same call now works
    alloc.admit(0, 4, 8)
    alloc.check_invariants()
    alloc.inject_fault()
    with pytest.raises(PoolExhausted):
        alloc.extend(0, 8)
    alloc.extend(0, 8)
    alloc.check_invariants()
    alloc.free(0)
    assert alloc.free_pages == 8


def test_elastic_plan_shrink_grow():
    full = plan_mesh(256, model_parallel=16, global_batch=256)
    assert full.mesh_shape == (16, 16)
    shrunk = plan_mesh(192, model_parallel=16, global_batch=256)
    assert shrunk.mesh_shape[1] == 16
    assert shrunk.chips_used <= 192
    assert 256 % shrunk.mesh_shape[0] == 0   # batch still divides
    pods = plan_mesh(512, model_parallel=16, global_batch=256, pods=2)
    assert pods.mesh_shape == (2, 16, 16)
    with pytest.raises(ValueError):
        plan_mesh(8, model_parallel=16)
