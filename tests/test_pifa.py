"""PIFA core: losslessness, parameter counts, FLOPs (paper Sec. 3)."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False

from repro.core.pifa import (dense_flops, lowrank_flops, lowrank_param_count,
                             pifa_apply, pifa_flops, pifa_param_count,
                             pifa_reconstruct, pivoting_factorize)


def lowrank(rng, m, n, r):
    return rng.normal(size=(m, r)) @ rng.normal(size=(r, n))


def test_lossless_reconstruction():
    rng = np.random.default_rng(0)
    w = lowrank(rng, 64, 48, 16)
    f = pivoting_factorize(w, 16)
    rec = np.asarray(pifa_reconstruct(f))
    assert np.abs(rec - w).max() < 1e-4 * np.abs(w).max()


def test_apply_matches_matmul():
    rng = np.random.default_rng(1)
    w = lowrank(rng, 40, 56, 12)
    f = pivoting_factorize(w, 12)
    x = rng.normal(size=(7, 56))
    y = np.asarray(pifa_apply(f, jnp.asarray(x, jnp.float32)))
    np.testing.assert_allclose(y, x @ w.T, rtol=2e-4, atol=2e-4)


def test_apply_unfolded_vs_folded_order():
    rng = np.random.default_rng(2)
    w = lowrank(rng, 32, 32, 8)
    f = pivoting_factorize(w, 8)
    x = jnp.asarray(rng.normal(size=(5, 32)), jnp.float32)
    ycat = pifa_apply(f, x, gather=False)
    y = pifa_apply(f, x, gather=True)
    np.testing.assert_allclose(np.asarray(ycat[:, np.asarray(f.inv_perm)]),
                               np.asarray(y), rtol=1e-5, atol=1e-5)


def test_param_count_formula():
    # wp(r*n) + c((m-r)*r) + idx(r) == r(m+n) - r^2 + r  (Sec. 3.3)
    for m, n, r in [(64, 48, 16), (100, 100, 50), (10, 20, 3)]:
        f = pivoting_factorize(np.random.default_rng(0).normal(size=(m, r))
                               @ np.random.default_rng(1).normal(size=(r, n)), r)
        stored = f.wp.size + f.c.size + f.perm.shape[0]  # idx == perm len m?
        # the paper stores only the r pivot indices; perm is derived.
        stored = f.wp.size + f.c.size + r
        assert stored == pifa_param_count(m, n, r)
        assert pifa_param_count(m, n, r) == r * (m + n) - r * r + r
        assert pifa_param_count(m, n, r) < lowrank_param_count(m, n, r)


def test_rank_autodetect():
    rng = np.random.default_rng(3)
    w = lowrank(rng, 50, 60, 7)
    f = pivoting_factorize(w)  # rank=None -> detect
    assert f.rank == 7


def test_flops_ordering():
    m = n = 1024
    b = 32
    for r in [128, 256, 512]:
        assert pifa_flops(m, n, r, b) < lowrank_flops(m, n, r, b)
        assert pifa_flops(m, n, r, b) == 2 * b * r * (m + n - r)
    # PIFA beats dense whenever its param count does (Eq. 3)
    r = 512
    assert pifa_flops(m, n, r, b) < dense_flops(m, n, b)


def test_pivot_rows_are_exact_rows():
    rng = np.random.default_rng(4)
    w = lowrank(rng, 30, 40, 10)
    f = pivoting_factorize(w, 10)
    perm = np.asarray(f.perm)
    # factors are stored in float32: compare at f32 resolution
    np.testing.assert_allclose(np.asarray(f.wp), w[perm[:10]],
                               rtol=1e-5, atol=1e-5)


def _check_lossless(m, n, rfrac):
    """Property: PIFA is lossless for ANY rank-r matrix (Sec. 3.2)."""
    r = max(1, min(int(min(m, n) * rfrac), m - 1, n - 1))
    rng = np.random.default_rng(m * 1000 + n)
    w = lowrank(rng, m, n, r)
    f = pivoting_factorize(w, r)
    rec = np.asarray(pifa_reconstruct(f))
    assert np.abs(rec - w).max() <= 5e-4 * max(np.abs(w).max(), 1.0)
    # exact storage arithmetic
    assert f.wp.shape == (r, n)
    assert f.c.shape == (m - r, r)
    inv = np.asarray(f.inv_perm)
    assert sorted(inv.tolist()) == list(range(m))


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(8, 96), n=st.integers(8, 96),
           rfrac=st.floats(0.1, 0.9))
    def test_lossless_property(m, n, rfrac):
        _check_lossless(m, n, rfrac)


_LL_RNG = np.random.default_rng(3)
_LL_CASES = [(8, 8, 0.1), (96, 96, 0.9), (8, 96, 0.5), (96, 8, 0.5)] + [
    (int(_LL_RNG.integers(8, 97)), int(_LL_RNG.integers(8, 97)),
     float(_LL_RNG.uniform(0.1, 0.9))) for _ in range(8)]


@pytest.mark.parametrize("m,n,rfrac", _LL_CASES)
def test_lossless_sweep(m, n, rfrac):
    _check_lossless(m, n, rfrac)


def test_degenerate_rank_one():
    rng = np.random.default_rng(5)
    w = np.outer(rng.normal(size=16), rng.normal(size=24))
    f = pivoting_factorize(w, 1)
    rec = np.asarray(pifa_reconstruct(f))
    np.testing.assert_allclose(rec, w, rtol=1e-5, atol=1e-6)
