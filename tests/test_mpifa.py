"""End-to-end MPIFA pipeline on the tiny model (Alg. 3 + Table 5 logic)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config
from repro.core.mpifa import (MpifaConfig, compress_expert_params,
                              compress_linear_params, compress_transformer)
from repro.models.linear import linear_param_count, linear_weight
from repro.models.model import build_model


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 48), 0,
                                cfg.vocab_size) for i in range(3)]
    test = jax.random.randint(jax.random.PRNGKey(99), (4, 48), 0,
                              cfg.vocab_size)
    ref = model.forward(params, test)
    return cfg, model, params, calib, test, ref


def _block_params(tree):
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def _kl(ref, logits):
    lp = jax.nn.log_softmax(ref, -1)
    lq = jax.nn.log_softmax(logits, -1)
    return float(jnp.mean(jnp.sum(jnp.exp(lp) * (lp - lq), -1)))


def test_density_accounting(tiny):
    cfg, model, params, calib, test, ref = tiny
    mc = MpifaConfig(density=0.5, reconstruct="none", prune="svd")
    cp = compress_transformer(model, params, calib, mc)
    dense_blocks = _block_params(params["blocks"])
    comp_blocks = _block_params(cp["blocks"])
    assert abs(comp_blocks / dense_blocks - 0.5) < 0.02


def test_pifa_is_lossless_vs_lowrank_same_rank(tiny):
    """W+M+PIFA at the SAME RANK == W+M (PIFA adds zero loss)."""
    cfg, model, params, calib, test, ref = tiny
    base = MpifaConfig(density=0.5, final_repr="lowrank")
    lr = compress_transformer(model, params, calib, base)
    # same ranks, re-encoded as PIFA: force identical rank via lowrank map
    import repro.core.mpifa as M
    orig = M.target_rank
    try:
        M.target_rank = lambda cfg_, m, n, name="": orig(
            base, m, n, name)  # lowrank-rank for both
        pf = compress_transformer(
            model, params, calib,
            MpifaConfig(density=0.5, final_repr="pifa", fold=False))
    finally:
        M.target_rank = orig
    out_lr = model.forward_unstacked(lr, test)
    out_pf = model.forward_unstacked(pf, test)
    np.testing.assert_allclose(np.asarray(out_pf), np.asarray(out_lr),
                               rtol=2e-3, atol=2e-3)


def test_mpifa_beats_lowrank_at_equal_density(tiny):
    """At equal density PIFA's extra rank must not hurt (Tables 2/5)."""
    cfg, model, params, calib, test, ref = tiny
    kl_lr = _kl(ref, model.forward_unstacked(
        compress_transformer(model, params, calib,
                             MpifaConfig(density=0.5, final_repr="lowrank")),
        test))
    kl_pf = _kl(ref, model.forward_unstacked(
        compress_transformer(model, params, calib,
                             MpifaConfig(density=0.5, final_repr="pifa")),
        test))
    assert kl_pf <= kl_lr * 1.05  # PIFA >= lowrank at equal budget


def test_folding_is_lossless(tiny):
    cfg, model, params, calib, test, ref = tiny
    kw = dict(density=0.5, final_repr="pifa")
    folded = compress_transformer(model, params, calib,
                                  MpifaConfig(fold=True, **kw))
    unfolded = compress_transformer(model, params, calib,
                                    MpifaConfig(fold=False, **kw))
    yf = model.forward_unstacked(folded, test)
    yu = model.forward_unstacked(unfolded, test)
    np.testing.assert_allclose(np.asarray(yf), np.asarray(yu),
                               rtol=2e-3, atol=2e-3)
    # and strictly fewer stored parameters (inv_perm dropped for up)
    assert _block_params(folded["blocks"]) < _block_params(unfolded["blocks"])


def test_whiten_beats_vanilla_svd(tiny):
    cfg, model, params, calib, test, ref = tiny
    kl_svd = _kl(ref, model.forward_unstacked(
        compress_transformer(model, params, calib,
                             MpifaConfig(density=0.5, prune="svd",
                                         reconstruct="none",
                                         final_repr="lowrank")), test))
    kl_w = _kl(ref, model.forward_unstacked(
        compress_transformer(model, params, calib,
                             MpifaConfig(density=0.5, prune="whiten",
                                         reconstruct="none",
                                         final_repr="lowrank")), test))
    assert kl_w <= kl_svd * 1.05


def test_compress_expert_params():
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4, 24, 16)), jnp.float32)}
    mc = MpifaConfig(density=0.5, prune="svd", reconstruct="none")
    cp = compress_expert_params(mc, p)
    assert set(cp) == {"wp", "c", "inv_perm"}
    assert cp["wp"].shape[0] == 4
    # PIFA is lossless: per-expert effective weight == the SVD truncation
    from repro.core.lowrank import svd_lowrank
    r = cp["wp"].shape[1]
    for e in range(4):
        w = np.asarray(p["w"][e], np.float64)
        u, vt = svd_lowrank(w, r)
        eff = np.concatenate(
            [np.asarray(cp["wp"][e]),
             np.asarray(cp["c"][e]) @ np.asarray(cp["wp"][e])])
        eff = eff[np.asarray(cp["inv_perm"][e])]
        np.testing.assert_allclose(eff, u @ vt, rtol=2e-3, atol=2e-3)
    # round-trip apply check
    from repro.models.layers import apply_expert_linear
    x = jnp.asarray(rng.normal(size=(4, 5, 16)), jnp.float32)
    y = apply_expert_linear(cp, x)
    assert y.shape == (4, 5, 24)
    assert bool(jnp.isfinite(y).all())


def test_compress_linear_params_data_free():
    rng = np.random.default_rng(1)
    p = {"w": jnp.asarray(rng.normal(size=(32, 20)), jnp.float32),
         "b": jnp.zeros((32,), jnp.float32)}
    cp = compress_linear_params(
        MpifaConfig(density=0.6, prune="svd", reconstruct="none"), p)
    assert "wp" in cp and "b" in cp
    assert linear_param_count(cp) <= linear_param_count(p)
