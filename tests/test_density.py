"""Density <-> rank maps (Fig. 1 arithmetic)."""
import numpy as np
import pytest

from repro.core.density import (density_of_rank_lowrank, density_of_rank_pifa,
                                rank_for_density_lowrank,
                                rank_for_density_pifa)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(m=st.integers(16, 4096), n=st.integers(16, 4096),
           rho=st.floats(0.05, 0.95))
    def test_rank_within_budget_property(m, n, rho):
        _check_rank_within_budget(m, n, rho)


def _check_rank_within_budget(m, n, rho):
    rl = rank_for_density_lowrank(m, n, rho)
    rp = rank_for_density_pifa(m, n, rho)
    assert density_of_rank_lowrank(m, n, rl) <= rho + 1e-9 or rl == 1
    assert density_of_rank_pifa(m, n, rp) <= rho + 1e-9 or rp == 1
    # PIFA affords at least the low-rank rank at equal density — the
    # mechanism behind MPIFA < W+M in Tables 2/5.
    assert rp >= rl


# Non-hypothesis fallback: a deterministic sweep over the same domain,
# so a clean container (no hypothesis) still covers the arithmetic.
_RNG = np.random.default_rng(0)
_CASES = [(int(_RNG.integers(16, 4097)), int(_RNG.integers(16, 4097)),
           float(_RNG.uniform(0.05, 0.95))) for _ in range(40)]
_CASES += [(16, 16, 0.05), (4096, 4096, 0.95), (16, 4096, 0.5),
           (4096, 16, 0.5), (128, 96, 0.55)]


@pytest.mark.parametrize("m,n,rho", _CASES)
def test_rank_within_budget(m, n, rho):
    _check_rank_within_budget(m, n, rho)


def test_pifa_always_below_dense():
    # Eq. 3: r(m+n) - r^2 < mn for all r < min(m, n).  The paper's claim
    # neglects the r-entry pivot-index vector (its own caveat in §3.3),
    # so subtract the index term before comparing.
    m, n = 128, 96
    for r in range(1, 96):
        assert density_of_rank_pifa(m, n, r) - r / (m * n) < 1.0 + 1e-12


def test_halfdim_savings_match_paper():
    """At r/d = 0.5 on square d x d, PIFA stores ~24-25% less than
    (U, Vt) — the paper's 24.2% memory-saving headline."""
    d = 4096
    r = d // 2
    lr = r * 2 * d
    pf = r * 2 * d - r * r + r
    saving = 1 - pf / lr
    assert abs(saving - 0.25) < 0.01
