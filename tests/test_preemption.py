"""Preemptible, deadline-aware serving (ISSUE 6).

The acceptance bar: preempt-and-resume must be INVISIBLE in the token
stream.  A request evicted at a chunk boundary via the paged
save/restore path and re-admitted later emits bit-identical tokens to
an uninterrupted run — across {transformer, mamba2, hybrid} x
{dense, pifa, ns} and for speculative slots (greedy and sampled).
Around that core: priority preemption under slot pressure,
mid-flight cancellation and deadlines (pages freed immediately),
bounded-backoff backpressure whose rejections PARTITION the submitted
set, FIFO-within-priority (no starvation), and a fault-injection
harness whose interleavings never leak pages or corrupt untouched
requests.
"""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.serve import compress_generic
from repro.models.model import build_model
from repro.runtime.scheduler import (CancelReason, FaultPlan, Request,
                                     ServingScheduler)

PAGE_SIZE = 4
ARCHS = {"mamba2": "mamba2_2p7b", "hybrid": "zamba2_1p2b"}


def _mk_reqs(cfg, n, seed=0, max_new=6, lens=None, **kw):
    rng = np.random.default_rng(seed)
    lens = lens or [6 + (i % 3) for i in range(n)]
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(lens[i])).astype(np.int32),
                    max_new=max_new, **kw)
            for i in range(n)]


def _tokens(run):
    return {r.request_id: r.tokens.tolist() for r in run.results}


def _assert_pool_clean(sched):
    """Zero page leaks / aliasing after the drain."""
    if getattr(sched, "_alloc", None) is not None:
        sched._alloc.check_invariants()
        assert sched._alloc.free_pages == sched._alloc.num_pages
    if getattr(sched, "_dalloc", None) is not None:
        sched._dalloc.check_invariants()
        assert sched._dalloc.free_pages == sched._dalloc.num_pages


# ------------------------------------------------- save/restore identity

class _PreemptZoo:
    """Lazy (family, comp) model/params cache for the identity matrix."""

    def __init__(self, tiny, tiny_pifa, tiny_ns):
        self._tiny = tiny
        self._tp = {"dense": tiny[2], "pifa": tiny_pifa, "ns": tiny_ns}
        self._base = {}
        self._params = {}

    def base(self, family):
        if family == "transformer":
            return self._tiny[0], self._tiny[1]
        if family not in self._base:
            cfg = get_smoke_config(ARCHS[family])
            self._base[family] = (cfg, build_model(cfg))
        return self._base[family]

    def params_for(self, family, comp):
        if family == "transformer":
            return self._tp[comp]
        key = (family, comp)
        if key not in self._params:
            cfg, model = self.base(family)
            if comp == "dense":
                p = model.init(jax.random.PRNGKey(0))
            elif comp == "pifa":
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)), 0.6)
            else:
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)), 0.6,
                                     per_block=(0.45, 0.7))
            self._params[key] = p
        return self._params[key]


@pytest.fixture(scope="module")
def pzoo(tiny, tiny_pifa, tiny_ns):
    return _PreemptZoo(tiny, tiny_pifa, tiny_ns)


@pytest.mark.parametrize("comp", ["dense", "pifa", "ns"])
@pytest.mark.parametrize("family", ["transformer", "mamba2", "hybrid"])
def test_preempt_resume_bit_identity(pzoo, family, comp):
    """Forced eviction + paged save/restore re-admission reproduces the
    uninterrupted paged run token-for-token, with zero page leaks."""
    cfg, model = pzoo.base(family)
    params = pzoo.params_for(family, comp)
    reqs = _mk_reqs(cfg, 2, seed=11)

    def serve(plan):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(16,), cache_len=32,
                                 cache="paged", page_size=PAGE_SIZE,
                                 preemption="save_restore",
                                 fault_plan=plan)
        run = sched.run(list(reqs))
        _assert_pool_clean(sched)
        return run

    ref = _tokens(serve(None))
    run = serve(FaultPlan().at(1, "preempt", 0))
    assert run.preemptions >= 1 and run.resumes >= 1
    victim = next(r for r in run.results if r.request_id == 0)
    assert victim.preemptions >= 1 and victim.cancel_reason is None
    got = _tokens(run)
    for rid, toks in ref.items():
        assert got[rid] == toks, (
            f"{family}/{comp}: request {rid} diverged across "
            "preempt/resume")


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_preempt_resume_speculative(tiny, tiny_draft, temperature):
    """Speculative slots page BOTH pools through save/restore: a
    preempted spec request (greedy and sampled) resumes its round
    counter and key stream bit-identically."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 2, seed=5, max_new=6)

    def serve(plan):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(16,), cache_len=32,
                                 cache="paged", page_size=PAGE_SIZE,
                                 draft_params=tiny_draft, spec_k=2,
                                 temperature=temperature, sample_seed=3,
                                 preemption="save_restore",
                                 fault_plan=plan)
        run = sched.run(list(reqs))
        _assert_pool_clean(sched)
        return run

    ref = _tokens(serve(None))
    run = serve(FaultPlan().at(1, "preempt", 0))
    assert run.preemptions >= 1 and run.resumes >= 1
    assert _tokens(run) == ref
    assert run.drafted > 0


def test_recompute_preemption_contiguous(tiny, engine):
    """Contiguous caches preempt via save-prefix-and-recompute: the
    resumed request re-prefills prompt+prefix and continues the same
    greedy stream."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=7, max_new=8)

    def serve(plan, preemption="off"):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(16,), cache_len=32,
                                 preemption=preemption, fault_plan=plan)
        return sched.run(list(reqs))

    ref = _tokens(serve(None))
    run = serve(FaultPlan().at(1, "preempt", 0), preemption="recompute")
    assert run.preemptions >= 1 and run.resumes >= 1
    assert _tokens(run) == ref


def test_mode_cache_pairing_refusals(tiny):
    """save_restore without a paged cache (and recompute WITH one)
    refuse loudly at construction — never a silent fallback."""
    cfg, model, params = tiny[:3]
    with pytest.raises(ValueError, match="save_restore"):
        ServingScheduler(model, params, preemption="save_restore")
    with pytest.raises(ValueError, match="recompute"):
        ServingScheduler(model, params, cache="paged",
                         page_size=PAGE_SIZE, preemption="recompute")
    with pytest.raises(ValueError, match="preemption"):
        ServingScheduler(model, params, preemption="sometimes")


# ------------------------------------------------------------- priority

def test_priority_preemption_under_pressure(tiny):
    """A higher-priority latecomer evicts the lowest-priority victim at
    a chunk boundary; the victim resumes and still completes its full
    budget."""
    cfg, model, params = tiny[:3]
    lows = _mk_reqs(cfg, 2, seed=3, max_new=24)
    high = _mk_reqs(cfg, 1, seed=4, max_new=4)[0]
    # arrive after the first boundary (compile dominates chunk 1) but
    # well before the lows finish their 24-token budgets
    high = Request(request_id=10, prompt=high.prompt, max_new=4,
                   arrival_time=0.05, priority=1)
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=48,
                             cache="paged", page_size=PAGE_SIZE,
                             preemption="save_restore")
    run = sched.run(lows + [high])
    assert run.preemptions >= 1 and run.resumes >= 1
    by_id = {r.request_id: r for r in run.results}
    assert by_id[10].generated == 4
    assert by_id[10].preemptions == 0           # the high class never waits
    assert all(by_id[i].generated == 24 for i in (0, 1))
    assert sum(by_id[i].preemptions for i in (0, 1)) >= 1
    _assert_pool_clean(sched)


def test_fifo_within_priority_no_starvation(tiny):
    """A page-blocked request sets a ceiling for its priority class:
    later same-priority small arrivals cannot leapfrog it, so a big
    request admits as soon as pages free instead of starving under a
    stream of small ones."""
    cfg, model, params = tiny[:3]
    rng = np.random.default_rng(9)

    def req(rid, max_new, arrival):
        return Request(request_id=rid,
                       prompt=rng.integers(0, cfg.vocab_size,
                                           6).astype(np.int32),
                       max_new=max_new, arrival_time=arrival)

    # pool of 10 pages; r0 holds 4 while running; big r1 needs 8 (must
    # wait for r0); small r2/r3 need 4 each (would fit immediately)
    reqs = [req(0, 10, 0.0), req(1, 24, 1e-5), req(2, 4, 2e-5),
            req(3, 4, 3e-5)]
    sched = ServingScheduler(model, params, capacity=3, chunk=2,
                             prompt_buckets=(16,), cache_len=48,
                             cache="paged", page_size=PAGE_SIZE,
                             num_pages=10)
    run = sched.run(reqs)
    by_id = {r.request_id: r for r in run.results}
    assert sorted(by_id) == [0, 1, 2, 3]        # nobody starves
    assert all(by_id[i].generated == reqs[i].max_new for i in by_id)
    # FIFO within the class: the blocked big request admits first
    assert by_id[1].admitted_at <= by_id[2].admitted_at
    assert by_id[1].admitted_at <= by_id[3].admitted_at
    _assert_pool_clean(sched)


# ---------------------------------------------------- cancel / deadline

def test_cancel_mid_flight_frees_pages(tiny):
    """A FaultPlan cancel lands at the next chunk boundary: the result
    carries CANCELLED with the tokens emitted so far, and the freed
    slot + pages serve the rest of the queue."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=0, max_new=12)
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             cache="paged", page_size=PAGE_SIZE,
                             fault_plan=FaultPlan().at(1, "cancel", 1))
    run = sched.run(reqs)
    by_id = {r.request_id: r for r in run.results}
    assert sorted(by_id) == [0, 1, 2, 3]
    assert by_id[1].cancel_reason is CancelReason.CANCELLED
    assert 0 < by_id[1].generated < 12
    assert all(by_id[i].cancel_reason is None and by_id[i].generated == 12
               for i in (0, 2, 3))
    _assert_pool_clean(sched)


def test_cancel_queued_request(tiny):
    """Cancelling a not-yet-admitted request resolves it from the queue
    (slot -1, zero generated) without disturbing the others."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=2, max_new=6)
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             fault_plan=FaultPlan().at(0, "cancel", 2))
    run = sched.run(reqs)
    by_id = {r.request_id: r for r in run.results}
    assert by_id[2].cancel_reason is CancelReason.CANCELLED
    assert by_id[2].generated == 0 and by_id[2].slot == -1
    assert all(by_id[i].generated == 6 for i in (0, 1))


def test_deadline_exceeded(tiny):
    """Deadlines are checked at chunk boundaries against arrival time:
    an expired request finishes early with DEADLINE, budget untouched
    requests run to completion."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 2, seed=6, max_new=16)
    reqs[0] = Request(request_id=0, prompt=reqs[0].prompt, max_new=16,
                      deadline_s=0.0)
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=48)
    run = sched.run(reqs)
    by_id = {r.request_id: r for r in run.results}
    assert by_id[0].cancel_reason is CancelReason.DEADLINE
    assert by_id[0].generated < 16
    assert by_id[1].cancel_reason is None and by_id[1].generated == 16


# -------------------------------------------------------- backpressure

def test_backpressure_partition_no_slot(tiny):
    """Bounded admission retries: every submitted request ends EITHER
    completed OR Rejected (disjoint, exhaustive) when slots stay
    scarce."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=1, max_new=8)
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             admit_retries=1)
    run = sched.run(reqs)
    done = {r.request_id for r in run.results}
    rej = {r.request_id for r in run.rejected}
    assert done | rej == {0, 1, 2} and not (done & rej)
    assert rej, "expected at least one bounded-backoff rejection"
    assert all(r.reason == "no_slot" and r.attempts >= 1
               for r in run.rejected)


def test_backpressure_partition_no_pages(tiny):
    """The same partition property under PAGE scarcity: a pool too
    small for the full mix rejects the overflow with reason no_pages
    and leaks nothing."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=8, max_new=8, lens=[6, 6, 6, 6])
    # each request reserves max(16, 14) = 16 tokens -> 4 pages; a pool
    # of 6 pages serves exactly one at a time through 2 free slots
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             cache="paged", page_size=PAGE_SIZE,
                             num_pages=6, admit_retries=1)
    run = sched.run(reqs)
    done = {r.request_id for r in run.results}
    rej = {r.request_id for r in run.rejected}
    assert done | rej == {0, 1, 2, 3} and not (done & rej)
    assert any(r.reason == "no_pages" for r in run.rejected)
    _assert_pool_clean(sched)


def test_backoff_honored_with_fake_clock(tiny, fake_clock):
    """Admission backoff consults the injected clock: a deferred
    request is not retried before its backoff expires, and time only
    moves when the (injected) sleep advances it."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 2, seed=4, max_new=4)
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             backoff_base_s=0.05, clock=fake_clock,
                             sleep_fn=fake_clock.sleep)
    run = sched.run(reqs)
    by_id = {r.request_id: r for r in run.results}
    assert sorted(by_id) == [0, 1]
    assert by_id[1].admitted_at >= 0.05         # waited out the backoff
    assert not run.rejected                     # budget was unbounded


def test_preempted_unresumed_returns_partial(tiny, engine):
    """A victim whose re-admission retry budget exhausts is resolved
    with PREEMPTED_UNRESUMED carrying the tokens generated before
    eviction — a true prefix of its uninterrupted stream."""
    import jax.numpy as jnp
    cfg, model, params = tiny[:3]
    low = _mk_reqs(cfg, 1, seed=12, max_new=8)[0]
    high = Request(request_id=1,
                   prompt=np.asarray(low.prompt, np.int32), max_new=16,
                   arrival_time=0.05, priority=1)
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(16,), cache_len=48,
                             cache="paged", page_size=PAGE_SIZE,
                             preemption="save_restore",
                             admit_retries=1, backoff_base_s=1e-6)
    run = sched.run([low, high])
    by_id = {r.request_id: r for r in run.results}
    assert by_id[1].generated == 16             # the high class finished
    r0 = by_id[0]
    assert r0.cancel_reason is CancelReason.PREEMPTED_UNRESUMED
    assert 0 < r0.generated < 8 and r0.preemptions >= 1
    ref = np.asarray(engine.generate(
        params, jnp.asarray(low.prompt[None, :]), 8).tokens[0])
    n = r0.prompt_len + r0.generated
    assert np.array_equal(r0.tokens[:n], ref[:n])
    _assert_pool_clean(sched)


# ------------------------------------------------------ fault injection

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fault_interleaving_preserves_everything(tiny, seed):
    """Randomized FaultPlan interleavings (allocator faults, dispatch
    errors, clock skew, forced preemptions) across chunk boundaries:
    every request still completes with the fault-free token stream,
    and the page pool comes back whole — no leaks, no aliasing."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=20, max_new=6)

    def serve(plan):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(16,), cache_len=32,
                                 cache="paged", page_size=PAGE_SIZE,
                                 preemption="save_restore",
                                 fault_plan=plan)
        run = sched.run(list(reqs))
        _assert_pool_clean(sched)
        return run

    ref = _tokens(serve(None))
    rng = np.random.default_rng(seed)
    plan = FaultPlan()
    n_dispatch = 0
    for step in sorted(rng.choice(np.arange(1, 7), size=3, replace=False)):
        kind = rng.choice(["pool_exhausted", "dispatch_error",
                           "clock_skew", "preempt"])
        if kind == "dispatch_error":
            if n_dispatch >= 2:          # stay under the retry budget
                kind = "pool_exhausted"
            else:
                n_dispatch += 1
        arg = {"clock_skew": 1e-3, "preempt": int(rng.integers(0, 4)),
               "pool_exhausted": None, "dispatch_error": None}[kind]
        plan.at(int(step), kind, arg)
    run = serve(plan)
    assert _tokens(run) == ref, f"seed {seed}: faults corrupted a stream"
    done = {r.request_id for r in run.results}
    assert done == {0, 1, 2, 3} and not run.rejected
    assert all(r.cancel_reason is None for r in run.results)


def test_mid_admission_allocator_fault_leaves_state_intact(tiny):
    """An allocator fault injected DURING admission hands back the slot
    and any partial pages: the request stays deferred (not lost) and
    admits cleanly on a later boundary."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=15, max_new=6)
    plan = FaultPlan().at(0, "pool_exhausted").at(1, "pool_exhausted")
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             cache="paged", page_size=PAGE_SIZE,
                             fault_plan=plan)
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == [0, 1, 2]
    assert all(r.generated == 6 for r in run.results)
    assert run.deferrals.get("no_pages", 0) >= 1   # the faults surfaced
    assert plan.pending() == 0
    _assert_pool_clean(sched)


def test_slow_chunk_flagging(tiny):
    """Per-chunk dispatch wall-times feed the straggler detector; a
    threshold of ~0 flags chunks, the default does not flood (at most
    the compile chunk)."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 2, seed=30, max_new=8)
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache_len=32,
                             straggler_threshold=1e-9)
    run = sched.run(reqs)
    assert run.chunks >= 2
    # an absurdly low threshold flags steady-state chunks too
    assert len(run.slow_chunks) >= 1
    assert all(0 <= c < run.chunks for c in run.slow_chunks)
