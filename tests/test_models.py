"""Per-architecture smoke tests: reduced configs, one forward/train step
on CPU, output shapes + no NaNs; decode-vs-teacher-forcing consistency.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, ShapeConfig, get_smoke_config
from repro.models.model import (build_model, example_batch, loss_fn,
                                make_train_step)
from repro.optim.adamw import AdamW

SHAPE = ShapeConfig("t", 32, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = example_batch(cfg, SHAPE)

    loss = loss_fn(model, cfg, params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    optim = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, cfg, optim))
    opt_state = optim.init(params)
    loss2, params2, _ = step(params, opt_state, batch)
    assert bool(jnp.isfinite(loss2))
    # params actually moved
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["stablelm_1p6b", "gemma3_12b",
                                  "mamba2_2p7b", "zamba2_1p2b",
                                  "whisper_medium", "phi3_vision_4p2b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    kwargs = {}
    offset = 0
    if cfg.family == "encdec":
        frames = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, cfg.encoder_seq, cfg.d_model)) * 0.1
        full = model.forward(params, {"frames": frames, "tokens": toks})
        cache = model.init_cache(b, 32, dtype=jnp.float32)
        logits, cache = model.prefill(
            params, {"frames": frames, "tokens": toks[:, :8]}, cache)
    elif cfg.family == "vlm":
        patches = jax.random.normal(jax.random.PRNGKey(2),
                                    (b, cfg.num_patches, cfg.d_model)) * 0.1
        full = model.forward(params, toks, patches=patches)
        offset = cfg.num_patches
        cache = model.init_cache(b, 64, dtype=jnp.float32)
        logits, cache = model.prefill(params, toks[:, :8], cache,
                                      patches=patches)
    else:
        full = model.forward(params, toks)
        cache = model.init_cache(b, 32, dtype=jnp.float32)
        logits, cache = model.prefill(params, toks[:, :8], cache)
    errs = [float(jnp.abs(logits[:, 0] - full[:, offset + 7]).max())]
    for t in range(8, s):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.abs(logits[:, 0] - full[:, offset + t]).max()))
    assert max(errs) < 2e-3, errs


def test_moe_exact_when_capacity_sufficient():
    cfg = dataclasses.replace(get_smoke_config("arctic_480b"),
                              capacity_factor=8.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                              cfg.vocab_size)
    full = model.forward(params, toks)
    cache = model.init_cache(2, 32, dtype=jnp.float32)
    logits, cache = model.prefill(params, toks[:, :8], cache)
    errs = [float(jnp.abs(logits[:, 0] - full[:, 7]).max())]
    for t in range(8, 12):
        logits, cache = model.decode_step(params, toks[:, t:t + 1], cache)
        errs.append(float(jnp.abs(logits[:, 0] - full[:, t]).max()))
    assert max(errs) < 2e-3


def test_gemma3_local_global_pattern():
    cfg = get_smoke_config("gemma3_12b")  # ratio 2 -> L,L,G
    wins = [cfg.window_for_layer(i) for i in range(cfg.num_layers)]
    assert wins == [cfg.sliding_window, cfg.sliding_window, 0]


def test_grok_moe_has_no_dense_mlp():
    cfg = get_smoke_config("grok1_314b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bp = model.block_params(params, 0)
    assert "moe" in bp and "mlp" not in bp


def test_arctic_has_dense_residual_and_moe():
    cfg = get_smoke_config("arctic_480b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    bp = model.block_params(params, 0)
    assert "moe" in bp and "mlp" in bp


def test_mamba_decode_state_is_constant_size():
    cfg = get_smoke_config("mamba2_2p7b")
    model = build_model(cfg)
    c1 = model.init_cache(2, 16)
    c2 = model.init_cache(2, 4096)
    # attention-free: cache size independent of context length
    assert c1["ssm"].shape == c2["ssm"].shape
    assert c1["conv"].shape == c2["conv"].shape
