"""Durable serving: WAL + snapshots + crash recovery (ISSUE 7).

The acceptance bar: a ``SchedulerCrash`` injected at an arbitrary chunk
boundary must be INVISIBLE in the token streams.  Recovery (fresh
scheduler <- journal + latest committed snapshot) re-emits every
journaled prefix bitwise identically and the merged results match an
uninterrupted run token-for-token — across {transformer, mamba2,
hybrid} x {dense, pifa, ns}, for paged and contiguous caches, for
sampled speculative slots, and for shared-prefix (refcounted page)
mixes, whose restored slots re-seed the prefix index so the cache
stays warm across the crash.  Around that core: journal framing (CRC per
record, torn-tail truncation), snapshot atomicity (.tmp invisible,
per-slot CRCs), graceful degradation (corrupt slot payload -> recompute
from the journaled prefix; corrupt meta -> older snapshot -> journal-
only), replayed cancels, config-mismatch refusal, and dispatch faults
during the resumed drain riding the existing RestartPolicy."""
import jax
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.serve import compress_generic
from repro.models.model import build_model
from repro.runtime.durability import (CorruptSnapshot, Durability,
                                      RequestJournal, SnapshotStore,
                                      finish_recovered, recover_into)
from repro.runtime.fault_tolerance import FaultPlan, SchedulerCrash
from repro.runtime.scheduler import (CancelReason, Request,
                                     ServingScheduler)

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

PAGE_SIZE = 4
ARCHS = {"mamba2": "mamba2_2p7b", "hybrid": "zamba2_1p2b"}


def _mk_reqs(cfg, n, seed=0, max_new=6, lens=None, **kw):
    rng = np.random.default_rng(seed)
    lens = lens or [6 + (i % 3) for i in range(n)]
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(lens[i])).astype(np.int32),
                    max_new=max_new, **kw)
            for i in range(n)]


def _tokens(run):
    return {r.request_id: r.tokens.tolist() for r in run.results}


def _assert_pool_clean(sched):
    if getattr(sched, "_alloc", None) is not None:
        sched._alloc.check_invariants()
        # index-aware accounting: prefix entries PIN their pages past
        # the requests that produced them — that is the cache working,
        # not a leak; everything else must be back on the free list
        idx = getattr(sched, "_prefix", None)
        resident = idx.resident_pages() if idx is not None else 0
        assert (sched._alloc.free_pages + resident
                == sched._alloc.num_pages)
    if getattr(sched, "_dalloc", None) is not None:
        sched._dalloc.check_invariants()
        assert sched._dalloc.free_pages == sched._dalloc.num_pages


def _crash_and_recover(model, params, reqs, tmp, *, crash_step=2,
                       snapshot_every=2, mutate=None, resume_plan=None,
                       extra_plan=None, **kw):
    """Reference run, journaled run crashed at ``crash_step``, recovery.

    ``mutate(dir)`` runs between crash and recovery (disk corruption
    hooks); ``extra_plan(plan)`` arms extra faults on the crashing run;
    ``resume_plan`` is a FaultPlan for the resumed drain."""
    ref = ServingScheduler(model, params, **kw).run(list(reqs))
    dur = Durability(tmp, snapshot_every=snapshot_every)
    plan = FaultPlan().at(crash_step, "crash")
    if extra_plan is not None:
        extra_plan(plan)
    sched = ServingScheduler(model, params, durability=dur,
                             fault_plan=plan, **kw)
    with pytest.raises(SchedulerCrash):
        sched.run(list(reqs))
    dur.close()
    if mutate is not None:
        mutate(dur)
    dur2 = Durability(tmp, snapshot_every=snapshot_every)
    sched2 = ServingScheduler(model, params, durability=dur2,
                              fault_plan=resume_plan, **kw)
    info = recover_into(sched2)
    rec = finish_recovered(sched2, info)
    dur2.close()
    _assert_pool_clean(sched2)
    return ref, rec, info


def _assert_identical(ref, rec):
    assert rec.mismatches == 0, "journaled prefix replay diverged"
    ref_t, got_t = _tokens(ref), _tokens(rec.run)
    assert set(got_t) == set(ref_t)
    for rid, toks in ref_t.items():
        assert got_t[rid] == toks, f"request {rid} diverged across crash"


# ----------------------------------------------------- journal framing

def test_journal_roundtrip_and_lsn(tmp_path):
    path = tmp_path / "j.wal"
    j = RequestJournal(path)
    assert j.lsn == 0 and j.truncated_bytes == 0
    l1 = j.append("submit", rid=1, prompt=[3, 4])
    l2 = j.append("emit", rid=1, at=0, toks=[7])
    assert 0 < l1 < l2 == j.lsn
    j.close()
    # re-open appends after the committed tail
    j2 = RequestJournal(path)
    assert j2.lsn == l2 and j2.truncated_bytes == 0
    j2.append("finalize", rid=1)
    j2.close()
    recs, torn = RequestJournal.read(path)
    assert torn == 0
    assert [r["kind"] for r in recs] == ["submit", "emit", "finalize"]
    assert recs[0]["prompt"] == [3, 4] and recs[1]["toks"] == [7]


def test_journal_torn_tail_truncated(tmp_path):
    path = tmp_path / "j.wal"
    j = RequestJournal(path)
    j.append("submit", rid=1)
    l2 = j.append("submit", rid=2)
    j.close()
    # a crash mid-write leaves a partial record at EOF
    with open(path, "ab") as fh:
        fh.write(b"\xff\x00\x00\x00\x12")
    recs, torn = RequestJournal.read(path)
    assert len(recs) == 2 and torn == 5
    j3 = RequestJournal(path)          # open truncates the torn tail
    assert j3.truncated_bytes == 5 and j3.lsn == l2
    j3.append("submit", rid=3)
    j3.close()
    recs, torn = RequestJournal.read(path)
    assert torn == 0 and [r["rid"] for r in recs] == [1, 2, 3]


def test_journal_corrupt_record_drops_suffix(tmp_path):
    path = tmp_path / "j.wal"
    j = RequestJournal(path)
    l1 = j.append("submit", rid=1)
    j.append("submit", rid=2)
    j.append("submit", rid=3)
    j.close()
    data = bytearray(path.read_bytes())
    data[l1 + 10] ^= 0xFF              # flip a byte inside record 2
    path.write_bytes(bytes(data))
    recs, torn = RequestJournal.read(path)
    # CRC fails at record 2: it AND everything after it is dropped —
    # the journal is a consistent prefix, never a gapped sequence
    assert [r["rid"] for r in recs] == [1] and torn > 0


# --------------------------------------------------- snapshot framing

def test_snapshot_store_atomicity_and_degradation(tmp_path):
    store = SnapshotStore(tmp_path, keep=2)
    arrays = {0: {"rows__k": np.arange(6, dtype=np.float32)},
              1: {"rows__k": np.ones(3, np.float32)}}
    meta = {"step": 4, "slots": {"0": {"count": 1}, "1": {"count": 2}}}
    store.save(100, arrays, meta, blocking=True)
    # a torn .tmp (crash mid-snapshot before rename) is never listed
    (tmp_path / "snap_000000000200.tmp").mkdir()
    assert store.tags() == [100]
    m, arrs, corrupt = store.load(100)
    assert m["step"] == 4 and corrupt == []
    np.testing.assert_array_equal(arrs[0]["rows__k"],
                                  arrays[0]["rows__k"])
    # bit-flip ONE slot's payload: that slot degrades (None + corrupt
    # list), the other still loads
    f = tmp_path / "snap_000000000100" / "slot_000.npz"
    data = bytearray(f.read_bytes())
    data[len(data) // 2] ^= 0xFF
    f.write_bytes(bytes(data))
    m, arrs, corrupt = store.load(100)
    assert corrupt == [0] and arrs[0] is None and arrs[1] is not None
    # unreadable meta.json kills the whole snapshot
    (tmp_path / "snap_000000000100" / "meta.json").write_text("{garbage")
    with pytest.raises(CorruptSnapshot):
        store.load(100)
    # gc keeps the newest `keep`
    store.save(300, {}, {"step": 5}, blocking=True)
    store.save(400, {}, {"step": 6}, blocking=True)
    store.save(500, {}, {"step": 7}, blocking=True)
    assert store.tags() == [400, 500]


# ----------------------------------------- crash-recovery bit-identity

class _DurZoo:
    """Lazy (family, comp) model/params cache (mirrors test_preemption)."""

    def __init__(self, tiny, tiny_pifa, tiny_ns):
        self._tiny = tiny
        self._tp = {"dense": tiny[2], "pifa": tiny_pifa, "ns": tiny_ns}
        self._base = {}
        self._params = {}

    def base(self, family):
        if family == "transformer":
            return self._tiny[0], self._tiny[1]
        if family not in self._base:
            cfg = get_smoke_config(ARCHS[family])
            self._base[family] = (cfg, build_model(cfg))
        return self._base[family]

    def params_for(self, family, comp):
        if family == "transformer":
            return self._tp[comp]
        key = (family, comp)
        if key not in self._params:
            cfg, model = self.base(family)
            if comp == "dense":
                p = model.init(jax.random.PRNGKey(0))
            elif comp == "pifa":
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)), 0.6)
            else:
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)), 0.6,
                                     per_block=(0.45, 0.7))
            self._params[key] = p
        return self._params[key]


@pytest.fixture(scope="module")
def dzoo(tiny, tiny_pifa, tiny_ns):
    return _DurZoo(tiny, tiny_pifa, tiny_ns)


@pytest.mark.parametrize("comp", ["dense", "pifa", "ns"])
@pytest.mark.parametrize("family", ["transformer", "mamba2", "hybrid"])
def test_crash_recovery_bit_identity(dzoo, family, comp, tmp_path):
    """Crash mid-run, recover from snapshot + journal suffix onto a
    fresh scheduler: merged streams bit-equal the fault-free run."""
    cfg, model = dzoo.base(family)
    params = dzoo.params_for(family, comp)
    reqs = _mk_reqs(cfg, 4, seed=11)
    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=2, snapshot_every=2,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    assert info.restored, "snapshot should have covered live slots"
    _assert_identical(ref, rec)


@pytest.mark.parametrize("temperature", [0.0, 0.7])
def test_crash_recovery_paged_speculative(tiny, tiny_draft, temperature,
                                          tmp_path):
    """Paged + speculative (greedy AND sampled): restored slots resume
    their page payloads, draft pool, PRNG key and round counter — the
    sample stream continues exactly."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=5, max_new=6)
    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=2, snapshot_every=1,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32,
        cache="paged", page_size=PAGE_SIZE, draft_params=tiny_draft,
        spec_k=2, temperature=temperature, sample_seed=3,
        top_k=(5 if temperature else 0))
    assert info.restored
    _assert_identical(ref, rec)
    assert rec.run.drafted > 0


def _sweep_body(tiny, tmp, crash_step):
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 5, seed=23, max_new=5)
    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp, crash_step=crash_step, snapshot_every=2,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    _assert_identical(ref, rec)


if HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(crash_step=st.integers(min_value=1, max_value=5))
    def test_crash_step_sweep(tiny, tmp_path_factory, crash_step):
        """The crash boundary is arbitrary: every step recovers exactly
        (snapshot-covered, journal-only, and near-drained cases).  A
        fresh directory per example — a shared one would make the second
        example resume the first's journal."""
        _sweep_body(tiny, tmp_path_factory.mktemp("sweep"), crash_step)
else:
    @pytest.mark.parametrize("crash_step", [1, 2, 3, 5])
    def test_crash_step_sweep(tiny, tmp_path, crash_step):
        """Parametrized fallback when hypothesis is unavailable."""
        _sweep_body(tiny, tmp_path, crash_step)


# -------------------------------------------------- graceful degradation

def test_corrupt_slot_payload_recomputes(tiny, tmp_path):
    """A slot whose snapshot .npz fails its CRC is NOT lost: it degrades
    to recompute-from-journaled-prefix and still matches the greedy
    reference bit-for-bit."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=11)

    def flip_slot(dur):
        tag = dur.store.tags()[-1]
        f = dur.store.dir / f"snap_{tag:012d}" / "slot_000.npz"
        data = bytearray(f.read_bytes())
        data[len(data) // 2] ^= 0xFF
        f.write_bytes(bytes(data))

    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=2, snapshot_every=2,
        mutate=flip_slot,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    assert info.corrupt_slots and info.recomputed
    _assert_identical(ref, rec)


def test_corrupt_meta_falls_back_to_older_snapshot(tiny, tmp_path):
    """An unreadable meta.json skips to the PREVIOUS snapshot; its
    staleness is safe — the resumed slots regenerate the journaled
    suffix identically (the replay audit proves it)."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=3, max_new=8)

    def kill_meta(dur):
        tags = dur.store.tags()
        assert len(tags) >= 2, "need two snapshots for the fallback"
        (dur.store.dir / f"snap_{tags[-1]:012d}"
         / "meta.json").write_text("not json")

    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=3, snapshot_every=1,
        mutate=kill_meta,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    assert info.snapshot_tag is not None
    assert rec.replayed > 0
    _assert_identical(ref, rec)


def test_journal_only_recovery(tiny, tmp_path):
    """snapshot_every=0 (or every snapshot lost): everything re-queues
    from scratch and the fold_in(key, rid) streams regenerate the
    journaled prefixes exactly — slower, never wrong."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=7)
    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=2, snapshot_every=0,
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    assert info.snapshot_tag is None and not info.restored
    assert info.requeued and rec.replayed > 0
    _assert_identical(ref, rec)


def test_double_crash_recovery(tiny, tmp_path):
    """Crash the RESUMED run too: LSN-tagged snapshots stay monotone
    across restarts (step counters reset, LSNs don't), so the second
    recovery still picks the newest state."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=9, max_new=8)
    ref = ServingScheduler(model, params, capacity=2, chunk=2,
                           prompt_buckets=(16,),
                           cache_len=32).run(list(reqs))
    kw = dict(capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    dur = Durability(tmp_path, snapshot_every=1)
    sched = ServingScheduler(model, params, durability=dur,
                             fault_plan=FaultPlan().at(2, "crash"), **kw)
    with pytest.raises(SchedulerCrash):
        sched.run(list(reqs))
    dur.close()
    dur2 = Durability(tmp_path, snapshot_every=1)
    sched2 = ServingScheduler(model, params, durability=dur2,
                              fault_plan=FaultPlan().at(1, "crash"), **kw)
    info2 = recover_into(sched2)
    with pytest.raises(SchedulerCrash):
        finish_recovered(sched2, info2)
    dur2.close()
    dur3 = Durability(tmp_path, snapshot_every=1)
    sched3 = ServingScheduler(model, params, durability=dur3, **kw)
    info3 = recover_into(sched3)
    rec = finish_recovered(sched3, info3)
    dur3.close()
    _assert_identical(ref, rec)


# ------------------------------------------------- semantics under faults

def test_unhonoured_cancel_replays(tiny, tmp_path):
    """A cancel journaled at the crash boundary but never honoured
    (the crash beat the sweep) is re-applied on recovery — the request
    resolves CANCELLED with the same partial tokens as a crash-free
    run with the same cancel."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=13, max_new=8)
    kw = dict(capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    plan_ref = FaultPlan().at(2, "cancel", 0)
    ref = ServingScheduler(model, params, fault_plan=plan_ref,
                           **kw).run(list(reqs))
    dur = Durability(tmp_path, snapshot_every=1)
    plan = FaultPlan().at(2, "cancel", 0).at(2, "crash")
    sched = ServingScheduler(model, params, durability=dur,
                             fault_plan=plan, **kw)
    with pytest.raises(SchedulerCrash):
        sched.run(list(reqs))
    dur.close()
    dur2 = Durability(tmp_path, snapshot_every=1)
    sched2 = ServingScheduler(model, params, durability=dur2, **kw)
    info = recover_into(sched2)
    rec = finish_recovered(sched2, info)
    dur2.close()
    got = {r.request_id: r for r in rec.run.results}
    assert got[0].cancel_reason == CancelReason.CANCELLED
    ref0 = next(r for r in ref.results if r.request_id == 0)
    assert got[0].tokens.tolist() == ref0.tokens.tolist()
    for rid, toks in _tokens(ref).items():
        assert got[rid].tokens.tolist() == toks


def test_dispatch_fault_during_resume_retried(tiny, tmp_path):
    """An injected dispatch error during the resumed drain rides the
    existing RestartPolicy retry (pre-donation, so the retried chunk
    emits identical tokens) — recovery composes with fault injection."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 4, seed=17)
    ref, rec, info = _crash_and_recover(
        model, params, reqs, tmp_path, crash_step=2, snapshot_every=2,
        resume_plan=FaultPlan().at(1, "dispatch_error"),
        capacity=2, chunk=2, prompt_buckets=(16,), cache_len=32)
    _assert_identical(ref, rec)


def test_config_mismatch_refused(tiny, tmp_path):
    """Recovering into a scheduler whose config fingerprint disagrees
    with the journal raises — the resumed streams would not be
    bit-identical, so refusing loudly beats silent divergence."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=19)
    dur = Durability(tmp_path, snapshot_every=2)
    sched = ServingScheduler(model, params, durability=dur,
                             fault_plan=FaultPlan().at(1, "crash"),
                             capacity=2, chunk=2, prompt_buckets=(16,),
                             cache_len=32)
    with pytest.raises(SchedulerCrash):
        sched.run(list(reqs))
    dur.close()
    dur2 = Durability(tmp_path, snapshot_every=2)
    other = ServingScheduler(model, params, durability=dur2, capacity=3,
                             chunk=2, prompt_buckets=(16,), cache_len=32)
    with pytest.raises(ValueError, match="config mismatch"):
        recover_into(other)
    dur2.close()


def test_journal_records_full_lifecycle(tiny, tmp_path):
    """A clean journaled drain records config -> submits -> emits ->
    finalizes, and the finalize records alone reconstruct the run."""
    cfg, model, params = tiny[:3]
    reqs = _mk_reqs(cfg, 3, seed=29)
    dur = Durability(tmp_path, snapshot_every=0)
    sched = ServingScheduler(model, params, durability=dur, capacity=2,
                             chunk=2, prompt_buckets=(16,), cache_len=32)
    run = sched.run(list(reqs))
    dur.close()
    recs, torn = RequestJournal.read(dur.journal.path)
    assert torn == 0
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "submit" and "config" in kinds
    assert kinds.count("submit") == 3 and kinds.count("finalize") == 3
    fin = {r["rid"]: r for r in recs if r["kind"] == "finalize"}
    for r in run.results:
        assert fin[r.request_id]["toks"] == \
            r.tokens[r.prompt_len:].tolist()
    # recovery over a COMPLETED journal is a no-op drain: everything is
    # prior results, nothing re-queues
    dur2 = Durability(tmp_path, snapshot_every=0)
    sched2 = ServingScheduler(model, params, durability=dur2, capacity=2,
                              chunk=2, prompt_buckets=(16,),
                              cache_len=32)
    info = recover_into(sched2)
    rec = finish_recovered(sched2, info)
    dur2.close()
    assert not info.requeued and not info.restored
    assert _tokens(rec.run) == _tokens(run)


def test_crash_recovery_shared_prefix(tiny, tmp_path):
    """Crash mid-drain of a shared-prefix (``prefix_cache=True``) mix:
    the recovered drain is bit-identical to the uninterrupted run,
    restored slots RE-SEED the prefix index (a follow-up burst of the
    same prompts hits on every admission and emits the same streams),
    and the pool is leak-free under index-aware accounting with
    ``drop()`` reclaiming every pinned page."""
    cfg, model, params = tiny[:3]
    rng = np.random.default_rng(2)
    shared = rng.integers(0, cfg.vocab_size, 2 * PAGE_SIZE)
    reqs = [Request(request_id=i,
                    prompt=np.concatenate(
                        [shared, rng.integers(0, cfg.vocab_size, 1 + i)]
                    ).astype(np.int32),
                    max_new=5)
            for i in range(4)]
    kw = dict(capacity=2, chunk=2, prompt_buckets=(16,), cache="paged",
              page_size=PAGE_SIZE, cache_len=24, num_pages=24,
              prefix_cache=True)
    ref = ServingScheduler(model, params, **kw).run(list(reqs))
    dur = Durability(tmp_path, snapshot_every=2)
    sched = ServingScheduler(model, params, durability=dur,
                             fault_plan=FaultPlan().at(2, "crash"), **kw)
    with pytest.raises(SchedulerCrash):
        sched.run(list(reqs))
    dur.close()
    dur2 = Durability(tmp_path, snapshot_every=2)
    sched2 = ServingScheduler(model, params, durability=dur2, **kw)
    info = recover_into(sched2)
    rec = finish_recovered(sched2, info)
    _assert_identical(ref, rec)
    _assert_pool_clean(sched2)
    # the restored slots re-inserted their prompt pages: re-serving the
    # same prompts through the RECOVERED scheduler hits on every
    # admission and still emits the reference streams
    warm = sched2.run([Request(request_id=100 + r.request_id,
                               prompt=r.prompt.copy(), max_new=5)
                       for r in reqs])
    dur2.close()
    assert warm.prefix_hits == len(reqs) and warm.prefix_misses == 0
    ref_t = _tokens(ref)
    for r in warm.results:
        n = r.prompt_len + r.generated
        assert r.tokens[:n].tolist() == ref_t[r.request_id - 100][:n], (
            f"warm request {r.request_id} diverged after recovery")
    sched2._alloc.check_invariants()
    assert (sched2._alloc.free_pages + sched2._prefix.resident_pages()
            == sched2._alloc.num_pages)
    sched2._prefix.drop()
    assert sched2._alloc.free_pages == sched2._alloc.num_pages
