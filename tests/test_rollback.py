"""Property tests for the verify-then-rollback cache contract.

For random accept/reject patterns (any ``advance`` in 0..k+1),
verify-then-rollback must leave EVERY cache type element-identical to
sequentially decoding only the accepted prefix:

  positional KV   entries below the write pointer match (junk beyond
                  it is causally masked and excluded); the parallel
                  verify path projects k/v in one batched matmul, so
                  "identical" here is fp-tolerance, not bitwise,
  ring buffers    the full circular buffer matches BITWISE (scan-of-
                  decode verify + rejected writes restored from the
                  saved slots),
  SSM state       conv taps + ssm state match BITWISE (checkpoint
                  selection over scan-of-decode states).

The same property is checked for the DRAFT side (``ckpt_decode`` /
``restore_decode`` around plain decode steps).  Runs under hypothesis
when available, with a deterministic parametrized fallback for clean
containers.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, get_smoke_config
from repro.models.model import build_model

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False

ARCHS = ("tiny", "gemma3_12b", "mamba2_2p7b", "zamba2_1p2b")
PLEN = 7


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = get_config("tiny") if arch == "tiny" else get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return (cfg, model, params, jax.jit(model.prefill),
            jax.jit(model.decode_step), jax.jit(model.verify_step))


def _assert_cache_equal(rolled, ref, label):
    """Element-identity per cache type; positional k/v compared up to
    the (shared) write pointer, to fp tolerance (the parallel verify
    projects all k+1 tokens in one matmul); everything the checkpoint
    machinery owns (conv/ssm/ring buffers) must match BITWISE."""
    assert set(rolled) == set(ref), label
    assert bool(jnp.all(rolled["pos"] == ref["pos"])), label
    p = int(np.asarray(ref["pos"])[0])
    for key in rolled:
        if key == "pos":
            continue
        a, b = rolled[key], ref[key]
        if key in ("k", "v", "xk", "xv"):  # positional: junk beyond
            a, b = a[:, :, :p], b[:, :, :p]   # pos is causally masked
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=str((label, key)))
        else:
            assert bool(jnp.all(a == b)), (label, key)


def _check_rollback(arch, seed, k, advance):
    advance = min(advance, k + 1)
    cfg, model, params, prefill, decode, verify = _setup(arch)
    rng = np.random.default_rng(seed)
    cache_len = PLEN + k + 9       # > gemma smoke window 8: ring engages
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, PLEN)),
                          jnp.int32)
    _, c0 = prefill(params, prompts,
                    model.init_cache(1, cache_len, dtype=jnp.float32))
    if arch == "gemma3_12b":
        assert "kl" in c0
    vin = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, k + 1)),
                      jnp.int32)
    adv = jnp.asarray([advance], jnp.int32)

    # reference: sequentially decode ONLY the accepted prefix
    ref = c0
    for j in range(advance):
        _, ref = decode(params, vin[:, j:j + 1], ref)

    # verify-side: verify_step then rollback_verify
    _, vc = verify(params, vin, c0)
    rolled = model.rollback_verify(vc, c0["pos"], adv)
    _assert_cache_equal(rolled, ref, (arch, "verify", seed, k, advance))

    # draft-side: k+1 decode steps with pre-step ckpts, then restore
    c, cks = c0, []
    for j in range(k + 1):
        cks.append(model.ckpt_decode(c))
        _, c = decode(params, vin[:, j:j + 1], c)
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *cks)
    restored = model.restore_decode(dict(c), stacked, c0["pos"], adv)
    _assert_cache_equal(restored, ref, (arch, "draft", seed, k, advance))


if HAVE_HYPOTHESIS:
    @pytest.mark.parametrize("arch", ARCHS)
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000), k=st.integers(1, 4),
           advance=st.integers(0, 5))
    def test_rollback_matches_sequential_prefix_property(arch, seed, k,
                                                         advance):
        _check_rollback(arch, seed, k, advance)


# Deterministic fallback sweep over the same domain (runs regardless,
# so a clean container still covers every arch x advance edge: full
# reject, mid-run reject, all-accept).
_CASES = [(0, 2, 0), (1, 2, 3), (2, 3, 1), (3, 4, 5), (4, 1, 2),
          (5, 3, 4)]


@pytest.mark.parametrize("seed,k,advance", _CASES)
@pytest.mark.parametrize("arch", ARCHS)
def test_rollback_matches_sequential_prefix(arch, seed, k, advance):
    _check_rollback(arch, seed, k, advance)


# ------------------------------------------------------- paged caches

# every family with a paged path, incl. the encdec decoder self-attn
# (no scheduler serves it, so this is its paged coverage); ring refuses
PAGED_ARCHS = ("tiny", "mamba2_2p7b", "zamba2_1p2b", "whisper_medium")


def _check_paged_rollback(arch, seed, k, advance):
    """Verify-then-rollback on a PAGED cache whose k+1 writes cross a
    page boundary: the logical gather of the rolled-back pool must be
    element-identical to contiguous rollback (same tolerance contract
    as above), and ``pos`` must match — pages themselves are never
    freed mid-flight, so rejected-suffix junk stays masked exactly as
    contiguous junk does."""
    from repro.runtime.paging import logical_view, paginate_cache
    advance = min(advance, k + 1)
    cfg, model, params, prefill, decode, verify = _setup(arch)
    rng = np.random.default_rng(seed)
    P = 4
    # PLEN=7 puts pos at the tail of page 1; the k+1 verify writes span
    # into page 2 (and beyond for k >= 4), crossing >= 1 boundary
    cache_len = PLEN + k + 9
    cache_len += (-cache_len) % P                  # page-aligned
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, PLEN)),
                          jnp.int32)
    pf_in = prompts
    if arch == "whisper_medium":     # enc-dec prefill carries frames
        frames = jnp.asarray(
            rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)) * 0.1,
            jnp.float32)
        pf_in = {"frames": frames, "tokens": prompts}
    _, c0 = prefill(params, pf_in,
                    model.init_cache(1, cache_len, dtype=jnp.float32))
    p0 = paginate_cache(c0, P)
    vin = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, k + 1)),
                      jnp.int32)
    adv = jnp.asarray([advance], jnp.int32)

    # contiguous reference: verify + rollback (already checked against
    # sequential decode above)
    _, vc = verify(params, vin, c0)
    ref = model.rollback_verify(vc, c0["pos"], adv)

    _, pvc = verify(params, vin, p0)
    rolled = model.rollback_verify(pvc, p0["pos"], adv)
    assert "bt" not in ref
    lv = logical_view(rolled)
    lv.pop("bt", None)
    _assert_cache_equal(
        {k2: (v[:, :, :cache_len] if k2 in ("k", "v") else v)
         for k2, v in lv.items()},
        ref, (arch, "paged-verify", seed, k, advance))

    # draft side: cached decode steps with pre-step ckpts, restored
    c, cks = p0, []
    for j in range(k + 1):
        cks.append(model.ckpt_decode(c))
        _, c = decode(params, vin[:, j:j + 1], c)
    stacked = (jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *cks)
               if cks[0] else {})
    restored = model.restore_decode(dict(c), stacked, p0["pos"], adv)
    lv = logical_view(restored)
    lv.pop("bt", None)
    _assert_cache_equal(
        {k2: (v[:, :, :cache_len] if k2 in ("k", "v") else v)
         for k2, v in lv.items()},
        ref, (arch, "paged-draft", seed, k, advance))


@pytest.mark.parametrize("seed,k,advance", _CASES)
@pytest.mark.parametrize("arch", PAGED_ARCHS)
def test_paged_rollback_across_page_boundary(arch, seed, k, advance):
    _check_paged_rollback(arch, seed, k, advance)
