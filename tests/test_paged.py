"""Paged (block-table) KV cache: allocator property tests + scheduler
bit-identity.

The allocator half property-tests ``runtime/paging.PageAllocator``
against arbitrary op sequences (hypothesis when available, the repo's
deterministic parametrized fallback otherwise).  Plain tapes cover
admit/extend/free; refcount tapes add shared-page admission, COW,
index pins/unpins, and swap-in allocation:

  * a writable page is never aliased to two slots (and never the
    sentinel); a shared page becomes writable only through ``cow``,
  * pages never leak — once every slot frees and every pin drops,
    the whole pool is free,
  * a shared page returns to the free list only at refcount 0,
  * exhaustion RAISES (``PoolExhausted``) instead of evicting.

The :class:`PrefixIndex` units pin the content-hash prefix cache:
full-page-only indexing, chain lookup with divergence, LRU host spill
that skips live-slot pages, swap-in payload round-trips, and
``drop()`` full reclaim.

The scheduler half pins the serving contract: ``cache="paged"`` output
is bit-identical to ``cache="contiguous"`` AND to the single-request
engine — greedy and sampled, plain and speculative slots, chunked and
whole-prompt native paged prefill, shared-prefix admissions (warm
hits, COW on aligned repeats, host-swapped prefixes) — while the pool
drains back to full after every run; undersized pools defer admission
with a ``no_pages`` reason (never a silent overwrite) and ring archs
refuse paged mode loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.paging import (PAGED_KEYS, PageAllocator, PoolExhausted,
                                  PrefixIndex, logical_view, pages_for,
                                  paginate_cache, params_fingerprint)
from repro.runtime.scheduler import Request, ServingScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------- allocator

def _run_ops(num_pages, page_size, capacity, n_logical, ops):
    """Drive an allocator through an op sequence, checking invariants
    after every step against a shadow model of slot -> page count."""
    alloc = PageAllocator(num_pages, page_size, capacity, n_logical)
    live = {}                      # slot -> token high-water
    for kind, slot, tokens in ops:
        slot = slot % capacity
        tokens = 1 + tokens % (n_logical * page_size)
        if kind == 0 and slot not in live:      # admit
            try:
                alloc.admit(slot, tokens)
                live[slot] = tokens
            except PoolExhausted:
                # refusal must leave the slot unallocated
                assert alloc.slot_pages(slot) == ()
        elif kind == 1 and slot in live:        # extend
            try:
                alloc.extend(slot, tokens)
                live[slot] = max(live[slot], tokens)
            except PoolExhausted:
                pass                            # kept what it had
        elif kind == 2 and slot in live:        # free
            alloc.free(slot)
            del live[slot]
        alloc.check_invariants()
        # allocation tracks the shadow model exactly
        for s, hw in live.items():
            assert len(alloc.slot_pages(s)) == pages_for(hw, page_size)
    for slot in list(live):
        alloc.free(slot)
    alloc.check_invariants()
    assert alloc.free_pages == num_pages, "pages leaked"


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(num_pages=st.integers(1, 24), page_size=st.integers(1, 8),
           capacity=st.integers(1, 6), n_logical=st.integers(1, 8),
           ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63),
                                  st.integers(0, 255)), max_size=40))
    def test_allocator_invariants_property(num_pages, page_size, capacity,
                                           n_logical, ops):
        _run_ops(num_pages, page_size, capacity, n_logical, ops)


# Deterministic fallback sweep (runs regardless): seeded random op
# tapes over small/tight pools, covering refusal and churn edges.
@pytest.mark.parametrize("seed,num_pages,page_size,capacity,n_logical",
                         [(0, 8, 2, 3, 4), (1, 3, 1, 4, 3), (2, 24, 4, 6, 6),
                          (3, 1, 8, 2, 1), (4, 12, 3, 5, 4)])
def test_allocator_invariants(seed, num_pages, page_size, capacity,
                              n_logical):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 64)),
            int(rng.integers(0, 256))) for _ in range(60)]
    _run_ops(num_pages, page_size, capacity, n_logical, ops)


def test_allocator_exhaustion_raises_not_evicts():
    alloc = PageAllocator(num_pages=4, page_size=2, capacity=3, n_logical=4)
    alloc.admit(0, 6)                       # 3 pages
    with pytest.raises(PoolExhausted):
        alloc.admit(1, 4)                   # needs 2, only 1 free
    assert alloc.slot_pages(0) == (1, 2, 3)  # nothing evicted
    assert alloc.slot_pages(1) == ()
    alloc.admit(1, 2)                       # 1 page fits
    with pytest.raises(PoolExhausted):
        alloc.extend(1, 4)                  # pool empty now
    alloc.check_invariants()
    assert alloc.free_pages == 0


def test_allocator_reservation_blocks_admission():
    """A reservation holds back pages for a live slot's future extends:
    a newcomer that would eat them is refused up front, and the live
    slot's extends then always succeed."""
    alloc = PageAllocator(num_pages=6, page_size=2, capacity=4, n_logical=6)
    alloc.admit(0, 2, reserve_tokens=8)     # 1 page now, 4 reserved
    assert not alloc.can_admit(6)           # 3 > 6 free - 3 outstanding
    assert alloc.can_admit(4)
    with pytest.raises(PoolExhausted):
        alloc.admit(1, 6, reserve_tokens=6)
    alloc.extend(0, 8)                      # reservation honoured
    assert len(alloc.slot_pages(0)) == 4


def test_allocator_extend_beyond_table_raises():
    alloc = PageAllocator(num_pages=8, page_size=2, capacity=2, n_logical=3)
    alloc.admit(0, 2)
    with pytest.raises(ValueError, match="logical"):
        alloc.extend(0, 8)                  # 4 pages > 3 table slots


# ------------------------------------------------- refcounted allocator

def _run_refcount_ops(num_pages, page_size, capacity, n_logical, ops):
    """Drive the refcounted allocator through shared-page admissions,
    COW detaches, index pins/unpins, and swap-in allocations.

    Shadow state: ``owner`` maps every WRITABLE page to the one slot
    allowed to write it (private admit/extend allocations and COW
    copies) — two writers on one page is the aliasing bug this tape
    hunts; ``shared_at`` tracks which logical indices a slot mapped
    read-only so COW targets them; ``pins`` mirrors the prefix index's
    references.  ``check_invariants`` recounts refcounts exactly after
    every op; teardown proves refcount-0-only frees left no leaks."""
    alloc = PageAllocator(num_pages, page_size, capacity, n_logical)
    live = {}                       # slot -> token high-water
    shared_at = {}                  # slot -> set of read-only logical idxs
    owner = {}                      # page -> writer slot
    pins = []                       # simulated prefix-index pins
    def claim(slot, pages):
        for pg in pages:
            assert pg not in owner, (
                f"page {pg} writable by slots {owner[pg]} and {slot}")
            assert alloc.refcount(pg) == 1
            owner[pg] = slot
    for kind, a, b in ops:
        slot = a % capacity
        tokens = 1 + b % (n_logical * page_size)
        if kind == 0 and slot not in live:          # admit, private
            try:
                claim(slot, alloc.admit(slot, tokens))
                live[slot] = tokens
                shared_at[slot] = set()
            except PoolExhausted:
                assert alloc.slot_pages(slot) == ()
        elif kind == 1 and slot not in live:        # admit mapping shared
            donors = [s for s in live if alloc.slot_pages(s)]
            if not donors:
                continue
            donor = donors[b % len(donors)]
            need = pages_for(tokens, page_size)
            sh = alloc.slot_pages(donor)[:min(need, 1 + a % 3)]
            before = [alloc.refcount(pg) for pg in sh]
            try:
                claim(slot, alloc.admit(slot, tokens, shared=sh))
                live[slot] = tokens
                shared_at[slot] = set(range(len(sh)))
                for pg, rc in zip(sh, before):
                    assert alloc.refcount(pg) == rc + 1
            except PoolExhausted:
                assert alloc.slot_pages(slot) == ()
                for pg, rc in zip(sh, before):
                    assert alloc.refcount(pg) == rc
        elif kind == 2 and slot in live:            # extend
            try:
                claim(slot, alloc.extend(slot, tokens))
                live[slot] = max(live[slot], tokens)
            except PoolExhausted:
                pass
        elif kind == 3 and slot in live:            # free
            before = {pg: alloc.refcount(pg)
                      for pg in alloc.slot_pages(slot)}
            alloc.free(slot)
            for pg, rc in before.items():
                # shared pages survive the free; refcount-1 pages don't
                assert alloc.refcount(pg) == rc - 1
                if owner.get(pg) == slot:
                    del owner[pg]
            del live[slot], shared_at[slot]
        elif kind == 4 and slot in live and shared_at[slot]:   # cow
            logical = sorted(shared_at[slot])[a % len(shared_at[slot])]
            old = alloc.slot_pages(slot)[logical]
            rc = alloc.refcount(old)
            try:
                res = alloc.cow(slot, logical)
            except PoolExhausted:
                assert alloc.slot_pages(slot)[logical] == old
                continue
            if res is None:                  # already private: rc was 1
                assert rc == 1
                assert old not in owner
                owner[old] = slot
            else:
                assert res[0] == old and rc > 1
                assert alloc.refcount(old) == rc - 1
                claim(slot, [res[1]])
                assert alloc.slot_pages(slot)[logical] == res[1]
            shared_at[slot].discard(logical)
        elif kind == 5:                             # pin (prefix index)
            pages = [pg for s in live for pg in alloc.slot_pages(s)]
            if pages:
                pg = pages[b % len(pages)]
                alloc.pin(pg)
                pins.append(pg)
        elif kind == 6 and pins:                    # unpin
            pg = pins.pop(b % len(pins))
            rc = alloc.refcount(pg)
            freed = alloc.unpin(pg)
            assert freed == (rc == 1), (
                f"page {pg} freed at refcount {rc}")
            if freed:
                owner.pop(pg, None)
        elif kind == 7:                             # swap-in target
            pg = alloc.alloc_pinned()
            if pg is not None:
                assert alloc.refcount(pg) == 1
                assert alloc.pin_count(pg) == 1
                pins.append(pg)
        alloc.check_invariants()
    for slot in list(live):
        alloc.free(slot)
    while pins:
        alloc.unpin(pins.pop())
    alloc.check_invariants()
    assert alloc.free_pages == num_pages, "pages leaked"


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(num_pages=st.integers(1, 24), page_size=st.integers(1, 8),
           capacity=st.integers(1, 6), n_logical=st.integers(1, 8),
           ops=st.lists(st.tuples(st.integers(0, 7), st.integers(0, 63),
                                  st.integers(0, 255)), max_size=50))
    def test_refcount_invariants_property(num_pages, page_size, capacity,
                                          n_logical, ops):
        _run_refcount_ops(num_pages, page_size, capacity, n_logical, ops)


@pytest.mark.parametrize("seed,num_pages,page_size,capacity,n_logical",
                         [(0, 8, 2, 3, 4), (1, 3, 1, 4, 3), (2, 24, 4, 6, 6),
                          (3, 1, 8, 2, 1), (4, 12, 3, 5, 4), (5, 6, 2, 4, 3)])
def test_refcount_invariants(seed, num_pages, page_size, capacity,
                             n_logical):
    rng = np.random.default_rng(100 + seed)
    ops = [(int(rng.integers(0, 8)), int(rng.integers(0, 64)),
            int(rng.integers(0, 256))) for _ in range(80)]
    _run_refcount_ops(num_pages, page_size, capacity, n_logical, ops)


def test_shared_page_freed_only_at_refcount_zero():
    """Three holders of one page (owner slot, sharer slot, index pin):
    the page returns to the free list only when the LAST reference
    drops, whichever order they release in."""
    alloc = PageAllocator(num_pages=4, page_size=2, capacity=3, n_logical=4)
    (pg,) = alloc.admit(0, 2)
    alloc.admit(1, 2, shared=(pg,))
    alloc.pin(pg)
    assert alloc.refcount(pg) == 3
    alloc.free(0)
    assert alloc.refcount(pg) == 2 and pg not in alloc._free
    assert not alloc.unpin(pg)
    assert alloc.refcount(pg) == 1 and pg not in alloc._free
    assert alloc.free(1) == 1
    assert alloc.refcount(pg) == 0 and alloc.free_pages == 4
    alloc.check_invariants()


def test_cow_respects_reservations():
    """COW refuses rather than eat a page another slot's reservation
    is counting on — an admitted request must always be able to
    finish."""
    alloc = PageAllocator(num_pages=3, page_size=2, capacity=3, n_logical=3)
    (pg,) = alloc.admit(0, 2)
    alloc.admit(1, 2, shared=(pg,), reserve_tokens=6)  # reserves the rest
    with pytest.raises(PoolExhausted, match="copy-on-write"):
        alloc.cow(1, 0)
    assert alloc.slot_pages(1) == (pg,)                # nothing changed
    alloc.check_invariants()


# ----------------------------------------------------- paged <-> logical

def test_paginate_roundtrip_and_sentinel():
    rng = np.random.default_rng(0)
    cache = {"k": jnp.asarray(rng.normal(size=(2, 3, 10, 2, 4)),
                              jnp.float32),
             "v": jnp.asarray(rng.normal(size=(2, 3, 10, 2, 4)),
                              jnp.float32),
             "pos": jnp.asarray([10, 10, 10], jnp.int32)}
    paged = paginate_cache(cache, page_size=4)
    assert paged["bt"].shape == (3, 3)
    assert bool(jnp.all(paged["bt"] > 0))           # sentinel unmapped only
    assert bool(jnp.all(paged["k"][:, 0] == 0))     # sentinel page zeroed
    lv = logical_view(paged)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(lv[key][:, :, :10]),
                                      np.asarray(cache[key]))


# ----------------------------------------------------------- prefix index

def _index_fixture(num_pages=8, page_size=4, capacity=2, n_logical=5):
    alloc = PageAllocator(num_pages, page_size, capacity, n_logical)
    cache = {key: jnp.zeros((1, num_pages + 1, page_size, 1, 2),
                            jnp.float32) for key in PAGED_KEYS}
    return alloc, cache, PrefixIndex(alloc, PAGED_KEYS, b"fp0")


def test_prefix_index_full_pages_only_and_pins():
    """Only FULL prompt pages are indexed (partial tails are decode-
    written later); entries pin their page so prefixes outlive the slot
    that produced them, and a later admission maps them shared."""
    alloc, _, idx = _index_fixture()
    prompt = np.arange(10, dtype=np.int32)        # 2 full pages + 2 tokens
    pages = tuple(alloc.admit(0, 10))
    assert idx.insert(prompt, 10, pages) == 2
    assert idx.insert(prompt, 10, pages) == 0     # re-insert: touch only
    assert [alloc.refcount(p) for p in pages] == [2, 2, 1]
    chain = idx.lookup(prompt)
    assert [e.page for e in chain] == list(pages[:2])
    assert idx.lookup(np.arange(1, 11, dtype=np.int32)) == []
    diverged = prompt.copy()
    diverged[5] = 9999                            # inside page 1
    assert [e.page for e in idx.lookup(diverged)] == [pages[0]]
    alloc.free(0)                                 # prefix stays warm
    assert alloc.refcount(pages[0]) == 1
    assert alloc.refcount(pages[2]) == 0          # partial page reclaimed
    got = alloc.admit(1, 10, shared=tuple(e.page for e in chain))
    assert alloc.slot_pages(1)[:2] == pages[:2] and len(got) == 1
    assert alloc.refcount(pages[0]) == 2
    alloc.check_invariants()
    alloc.free(1)
    assert idx.drop() == 2
    assert alloc.free_pages == alloc.num_pages


def test_prefix_index_spill_skips_live_then_roundtrips():
    """Host spill never touches a page a live slot maps; once the slot
    frees, the coldest index-only pages swap out (pool fully drains)
    and the payload round-trips bit-exactly on the next hit."""
    alloc, cache, idx = _index_fixture()
    prompt = np.arange(8, dtype=np.int32)
    pages = np.asarray(alloc.admit(0, 8))
    rng = np.random.default_rng(0)
    for key in PAGED_KEYS:
        cache[key] = cache[key].at[:, pages].set(
            jnp.asarray(rng.normal(size=(1, 2, 4, 1, 2)), jnp.float32))
    want = {key: np.asarray(cache[key][:, pages]) for key in PAGED_KEYS}
    idx.insert(prompt, 8, tuple(int(p) for p in pages))
    cache, freed = idx.spill(cache, need=2)
    assert freed == 0                             # slot 0 still maps them
    alloc.free(0)
    cache, freed = idx.spill(cache, need=2)
    assert freed == 2 and idx.swap_outs == 2
    assert idx.resident_pages() == 0 and idx.swapped_pages() == 2
    assert alloc.free_pages == alloc.num_pages    # fully reclaimed
    chain = idx.lookup(prompt)                    # swapped entries still hit
    assert len(chain) == 2
    cache, back = idx.ensure_resident(cache, chain)
    assert len(back) == 2 and idx.swap_ins == 2
    for key in PAGED_KEYS:
        np.testing.assert_array_equal(
            np.asarray(cache[key][:, np.asarray(back)]), want[key])
    alloc.check_invariants()
    assert idx.drop() == 2
    assert alloc.free_pages == alloc.num_pages


def test_prefix_index_ensure_resident_truncates_under_pressure():
    """When swap-in cannot allocate (reservations hold the pool), the
    chain truncates to a shorter shared prefix instead of failing."""
    alloc, cache, idx = _index_fixture(num_pages=4)
    prompt = np.arange(8, dtype=np.int32)
    pages = tuple(alloc.admit(0, 8))
    idx.insert(prompt, 8, pages)
    alloc.free(0)
    cache, _ = idx.spill(cache, need=2)
    alloc.admit(1, 12)                            # 3 pages -> headroom 1
    chain = idx.lookup(prompt)
    assert len(chain) == 2
    cache, back = idx.ensure_resident(cache, chain)
    assert len(back) == 1                         # second stayed swapped
    assert chain[1].page is None
    alloc.check_invariants()


def test_params_fingerprint_keys_checkpoint():
    """Prefix entries are unreachable under different params: the
    fingerprint changes with values AND shapes, and is stable across
    calls for the same params."""
    a = {"w": jnp.ones((2, 3))}
    assert params_fingerprint(a) == params_fingerprint(
        {"w": jnp.ones((2, 3))})
    assert params_fingerprint(a) != params_fingerprint(
        {"w": 2 * jnp.ones((2, 3))})
    assert params_fingerprint(a) != params_fingerprint(
        {"w": jnp.ones((3, 2))})


# ------------------------------------------------------------- scheduler

def _requests(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m))
            for i, (l, m) in enumerate(zip(lens, budgets))]


def _assert_bit_identical(engine, params, run, requests, eos_id, **kw):
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = requests[r.request_id]
        ref = np.asarray(engine.generate(
            params, jnp.asarray(req.prompt[None, :]), req.max_new,
            eos_id=eos_id, **kw).tokens[0])
        n = r.prompt_len + r.generated
        assert r.generated >= 1
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from single-request engine")


def test_paged_bit_identity_and_pool_drains(tiny, engine):
    """Paged serving is bit-identical to BOTH the contiguous scheduler
    (same page-aligned cache_len -> identical reduction shapes ->
    identical logits) and the single-request engine; every page is back
    in the pool after the drain (free-on-eos, no leaks)."""
    cfg, model, params = tiny[:3]
    lens, budgets = [5, 12, 9, 16, 3, 7], [6, 3, 8, 2, 7, 4]
    runs = {}
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 eos_id=1, prompt_buckets=(8, 16),
                                 cache_len=28, cache=mode, page_size=4)
        runs[mode] = sched.run(_requests(cfg, lens, budgets))
        if mode == "paged":
            assert sched._alloc.free_pages == sched.num_pages
            sched._alloc.check_invariants()
    paged = {r.request_id: r.tokens for r in runs["paged"].results}
    contig = {r.request_id: r.tokens for r in runs["contiguous"].results}
    assert sorted(paged) == list(range(len(lens)))
    for rid in paged:
        assert np.array_equal(paged[rid], contig[rid]), (
            f"request {rid}: paged diverged from contiguous")
    _assert_bit_identical(engine, params, runs["paged"],
                          _requests(cfg, lens, budgets), eos_id=1)


def test_paged_sampled_identical_to_contiguous(tiny):
    """Sampled decode: per-request streams are identical between paged
    and contiguous mode (same page-aligned cache_len, same keys)."""
    cfg, model, params = tiny[:3]
    runs = {}
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 prompt_buckets=(8, 16), cache_len=24,
                                 cache=mode, page_size=4,
                                 temperature=0.8, top_k=4, sample_seed=7)
        runs[mode] = {r.request_id: r.tokens.tolist()
                      for r in sched.run(_requests(cfg, [5, 9, 7],
                                                   [6, 4, 5])).results}
    assert runs["paged"] == runs["contiguous"]


def test_paged_compressed_ns(tiny, tiny_ns):
    """MPIFA_NS (heterogeneous ranks, bucketed restack) serves through
    the paged scheduler bit-identically to the engine."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[6, 11, 4], budgets=[5, 3, 6])
    sched = ServingScheduler(model, tiny_ns, capacity=2, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16),
                             cache="paged", page_size=4)
    run = sched.run(reqs)
    _assert_bit_identical(GenerationEngine(model), tiny_ns, run, reqs,
                          eos_id=1)


def test_paged_speculative_greedy_and_sampled(tiny, engine, tiny_draft):
    """Paged speculative slots: greedy output bit-identical to the
    plain engine (and hence to contiguous spec slots); sampled slots
    reproduce the batch-1 ``engine.generate_speculative`` stream of
    their ``spec_request_key`` — the draft cache pages too."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache="paged",
                             page_size=4, draft_params=tiny_draft,
                             spec_k=3)
    run = sched.run(reqs)
    assert run.drafted > 0
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)
    assert sched._alloc.free_pages == sched.num_pages
    assert sched._dalloc.free_pages == sched.num_pages

    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache="paged",
                             page_size=4, draft_params=tiny_draft,
                             spec_k=3, temperature=0.8, top_k=4,
                             sample_seed=11)
    run = sched.run(reqs)
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = reqs[r.request_id]
        ref = engine.generate_speculative(
            params, tiny_draft, jnp.asarray(req.prompt[None, :]),
            req.max_new, spec_k=3, temperature=0.8, top_k=4, eos_id=1,
            key=sched.spec_request_key(req.request_id))
        n = r.prompt_len + r.generated
        assert np.array_equal(r.tokens[:n], np.asarray(ref.tokens[0])[:n]), (
            f"request {r.request_id} diverged from engine stream")


def test_paged_no_pages_deferral_then_serves(tiny, engine):
    """An undersized pool defers admission with a ``no_pages`` reason
    (reported in SchedulerRun.deferrals, not a bare retry) and admits
    once finished requests free their pages — outputs still exact."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=4, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache_len=28,
                             cache="paged", page_size=4, num_pages=9)
    run = sched.run(reqs)
    assert run.deferrals.get("no_pages", 0) > 0
    assert sorted(r.request_id for r in run.results) == [0, 1, 2]
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)


def test_paged_no_slot_deferral_reported(tiny):
    """Slot starvation is reported as ``no_slot`` (distinct from page
    starvation) — the single-slot queue defers the followers."""
    cfg, model, params = tiny[:3]
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(8,), cache="paged",
                             page_size=4)
    run = sched.run(_requests(cfg, [5, 6, 7], [4, 4, 4]))
    assert run.deferrals.get("no_slot", 0) > 0
    assert run.deferrals.get("no_pages", 0) == 0


def test_paged_request_that_never_fits_raises(tiny):
    """A request whose worst case exceeds the whole pool raises a
    bucket-mismatch/pool error instead of deferring forever."""
    cfg, model, params = tiny[:3]
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,), cache_len=32,
                             cache="paged", page_size=4, num_pages=4)
    big = Request(request_id=9, prompt=np.zeros(5, np.int32), max_new=20)
    with pytest.raises(ValueError, match="never be admitted"):
        sched.run([big])
    assert len(sched._free) == sched.capacity      # nothing leaked
    sched._queue.popleft()
    run = sched.run(_requests(cfg, [5], [4]))
    assert [r.request_id for r in run.results] == [0]


def test_paged_bucket_mismatch_raises(tiny):
    """Oversized-for-cache_len requests raise the (renamed) bucket
    mismatch error in both cache modes; state stays intact."""
    cfg, model, params = tiny[:3]
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(8,), cache_len=16,
                                 cache=mode, page_size=4)
        big = Request(request_id=9, prompt=np.zeros(5, np.int32),
                      max_new=50)
        with pytest.raises(ValueError, match="bucket mismatch"):
            sched.run([big])
        assert len(sched._free) == sched.capacity


def test_paged_hybrid_bit_identity():
    """The hybrid family pages its shared-attention KV (conv/ssm state
    stays per-slot by design) and still serves bit-identically."""
    cfg = get_smoke_config("zamba2_1p2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5, 11], budgets=[4, 2, 5, 3])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             cache="paged", page_size=4)
    assert sched.prompt_buckets is None
    run = sched.run(reqs)
    assert sched._paged_kv
    _assert_bit_identical(GenerationEngine(model), params, run, reqs,
                          eos_id=1)


def test_paged_mamba2_is_noop_by_design():
    """Pure SSM state is constant size — paged mode has nothing to page
    and must behave exactly like the contiguous scheduler."""
    cfg = get_smoke_config("mamba2_2p7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5], budgets=[4, 2, 5])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             cache="paged", page_size=4)
    run = sched.run(reqs)
    assert not sched._paged_kv                  # nothing paged
    _assert_bit_identical(GenerationEngine(model), params, run, reqs,
                          eos_id=1)


def test_paged_ring_arch_refuses_loudly():
    cfg = get_smoke_config("gemma3_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ring"):
        ServingScheduler(model, params, cache="paged")


def test_paged_config_errors(tiny):
    cfg, model, params = tiny[:3]
    with pytest.raises(ValueError, match="cache"):
        ServingScheduler(model, params, cache="virtual")
    with pytest.raises(ValueError, match="page_size"):
        ServingScheduler(model, params, cache="paged", page_size=0)
    with pytest.raises(ValueError, match="prefix_cache"):
        ServingScheduler(model, params, prefix_cache=True)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingScheduler(model, params, cache="paged", prefill_chunk=0)
    with pytest.raises(ValueError, match="contiguous path"):
        ServingScheduler(model, params, prefill_chunk=4)


# ------------------------------------------------- shared-prefix serving

def test_prefix_sharing_hits_and_bit_identity(tiny, engine):
    """Three prompts sharing two full pages: the admissions after the
    first map the indexed pages (prefix_hits), prefill only their
    tails, and still match the single-request engine bit-for-bit; a
    warm re-run of the same prompts hits on EVERY admission; dropping
    the index drains the pool back to full."""
    cfg, model, params = tiny[:3]
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 8)       # 2 full pages
    prompts = [np.concatenate([shared,
                               rng.integers(0, cfg.vocab_size, 3)])
               for _ in range(3)]
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache="paged",
                             page_size=4, cache_len=24, num_pages=20,
                             prefix_cache=True)

    def drain(base):
        run = sched.run([Request(request_id=base + i,
                                 prompt=p.astype(np.int32), max_new=5)
                         for i, p in enumerate(prompts)])
        for r in run.results:
            ref = np.asarray(engine.generate(
                params, jnp.asarray(r.tokens[:r.prompt_len])[None, :],
                5).tokens[0])
            n = r.prompt_len + r.generated
            assert np.array_equal(r.tokens[:n], ref[:n]), (
                f"request {r.request_id} diverged from engine")
        sched._alloc.check_invariants()
        return run

    cold = drain(0)
    assert cold.prefix_hits >= 1                  # within-burst sharing
    assert cold.prefix_misses >= 1                # the seeding admission
    warm = drain(10)
    assert warm.prefix_hits == 3 and warm.prefix_misses == 0
    assert warm.page_high_water <= cold.page_high_water
    assert len(sched._prefix) > 0
    sched._prefix.drop()
    assert sched._alloc.free_pages == sched.num_pages


def test_prefix_cow_on_aligned_repeat(tiny, engine):
    """A page-aligned prompt indexes ALL its pages; a later repeat maps
    every page but must re-prefill the last token for logits — that
    write triggers exactly the COW detach, and both streams still
    match the engine."""
    cfg, model, params = tiny[:3]
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, 12).astype(np.int32)  # aligned
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), cache="paged",
                             page_size=4, cache_len=24, num_pages=20,
                             prefix_cache=True)
    first = sched.run([Request(request_id=0, prompt=prompt, max_new=5)])
    repeat = sched.run([Request(request_id=1, prompt=prompt.copy(),
                                max_new=5)])
    assert repeat.prefix_hits == 1
    assert repeat.cow_copies >= 1, "aligned repeat must copy-on-write"
    ref = np.asarray(engine.generate(params, jnp.asarray(prompt)[None, :],
                                     5).tokens[0])
    for r in list(first.results) + list(repeat.results):
        n = r.prompt_len + r.generated
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from engine")
    sched._alloc.check_invariants()


def test_prefix_host_swap_under_pressure(tiny, engine):
    """A pool too small for live slots + warm prefixes SPILLS the
    coldest index pages to host instead of deferring with no_pages;
    re-admitting the spilled prompt swaps them back in (still a hit)
    and the stream stays bit-identical."""
    cfg, model, params = tiny[:3]
    rng = np.random.default_rng(1)
    pa, pb, pc = (rng.integers(0, cfg.vocab_size, 9) for _ in range(3))
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(12,), cache="paged",
                             page_size=4, cache_len=20, num_pages=6,
                             prefix_cache=True)

    def one(rid, p):
        return sched.run([Request(request_id=rid,
                                  prompt=p.astype(np.int32), max_new=4)])

    one(0, pa)
    one(1, pb)
    spill = one(2, pc)          # 4 pages pinned, pc needs the pool
    assert spill.swap_outs >= 1, "expected host spill under pressure"
    assert spill.deferrals.get("no_pages", 0) == 0
    back = one(3, pa.copy())    # pa's pages are host-side now
    assert back.swap_ins >= 1 and back.prefix_hits == 1
    r = back.results[0]
    ref = np.asarray(engine.generate(params,
                                     jnp.asarray(pa.astype(np.int32))[None, :],
                                     4).tokens[0])
    n = r.prompt_len + r.generated
    assert np.array_equal(r.tokens[:n], ref[:n]), "swapped-in prefix diverged"
    sched._alloc.check_invariants()
    sched._prefix.drop()
    assert sched._alloc.free_pages == sched.num_pages


def test_chunked_paged_prefill_bit_identity(tiny):
    """prefill_chunk splits native paged prompt prefill into fixed-size
    pieces; per-query softmax independence makes every chunking — and
    the unchunked whole-prompt pass — produce identical streams."""
    cfg, model, params = tiny[:3]
    reqs = lambda: _requests(cfg, [13, 6, 9], [4, 5, 3], seed=7)
    runs = {}
    for pc in (None, 3, 8):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 eos_id=1, prompt_buckets=(16,),
                                 cache="paged", page_size=4,
                                 prefill_chunk=pc)
        runs[pc] = {r.request_id: r.tokens.tolist()
                    for r in sched.run(reqs()).results}
    assert runs[3] == runs[None]
    assert runs[8] == runs[None]
