"""Paged (block-table) KV cache: allocator property tests + scheduler
bit-identity.

The allocator half property-tests ``runtime/paging.PageAllocator``
against arbitrary admit/extend/free sequences (hypothesis when
available, the repo's deterministic parametrized fallback otherwise):

  * a live page is never aliased to two slots (and never the sentinel),
  * pages never leak — once every slot frees, the whole pool is free,
  * exhaustion RAISES (``PoolExhausted``) instead of evicting.

The scheduler half pins the serving contract: ``cache="paged"`` output
is bit-identical to ``cache="contiguous"`` AND to the single-request
engine — greedy and sampled, plain and speculative slots — while the
pool drains back to full after every run; undersized pools defer
admission with a ``no_pages`` reason (never a silent overwrite) and
ring archs refuse paged mode loudly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.paging import (PageAllocator, PoolExhausted,
                                  logical_view, pages_for, paginate_cache)
from repro.runtime.scheduler import Request, ServingScheduler

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False


# ------------------------------------------------------------- allocator

def _run_ops(num_pages, page_size, capacity, n_logical, ops):
    """Drive an allocator through an op sequence, checking invariants
    after every step against a shadow model of slot -> page count."""
    alloc = PageAllocator(num_pages, page_size, capacity, n_logical)
    live = {}                      # slot -> token high-water
    for kind, slot, tokens in ops:
        slot = slot % capacity
        tokens = 1 + tokens % (n_logical * page_size)
        if kind == 0 and slot not in live:      # admit
            try:
                alloc.admit(slot, tokens)
                live[slot] = tokens
            except PoolExhausted:
                # refusal must leave the slot unallocated
                assert alloc.slot_pages(slot) == ()
        elif kind == 1 and slot in live:        # extend
            try:
                alloc.extend(slot, tokens)
                live[slot] = max(live[slot], tokens)
            except PoolExhausted:
                pass                            # kept what it had
        elif kind == 2 and slot in live:        # free
            alloc.free(slot)
            del live[slot]
        alloc.check_invariants()
        # allocation tracks the shadow model exactly
        for s, hw in live.items():
            assert len(alloc.slot_pages(s)) == pages_for(hw, page_size)
    for slot in list(live):
        alloc.free(slot)
    alloc.check_invariants()
    assert alloc.free_pages == num_pages, "pages leaked"


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(num_pages=st.integers(1, 24), page_size=st.integers(1, 8),
           capacity=st.integers(1, 6), n_logical=st.integers(1, 8),
           ops=st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63),
                                  st.integers(0, 255)), max_size=40))
    def test_allocator_invariants_property(num_pages, page_size, capacity,
                                           n_logical, ops):
        _run_ops(num_pages, page_size, capacity, n_logical, ops)


# Deterministic fallback sweep (runs regardless): seeded random op
# tapes over small/tight pools, covering refusal and churn edges.
@pytest.mark.parametrize("seed,num_pages,page_size,capacity,n_logical",
                         [(0, 8, 2, 3, 4), (1, 3, 1, 4, 3), (2, 24, 4, 6, 6),
                          (3, 1, 8, 2, 1), (4, 12, 3, 5, 4)])
def test_allocator_invariants(seed, num_pages, page_size, capacity,
                              n_logical):
    rng = np.random.default_rng(seed)
    ops = [(int(rng.integers(0, 3)), int(rng.integers(0, 64)),
            int(rng.integers(0, 256))) for _ in range(60)]
    _run_ops(num_pages, page_size, capacity, n_logical, ops)


def test_allocator_exhaustion_raises_not_evicts():
    alloc = PageAllocator(num_pages=4, page_size=2, capacity=3, n_logical=4)
    alloc.admit(0, 6)                       # 3 pages
    with pytest.raises(PoolExhausted):
        alloc.admit(1, 4)                   # needs 2, only 1 free
    assert alloc.slot_pages(0) == (1, 2, 3)  # nothing evicted
    assert alloc.slot_pages(1) == ()
    alloc.admit(1, 2)                       # 1 page fits
    with pytest.raises(PoolExhausted):
        alloc.extend(1, 4)                  # pool empty now
    alloc.check_invariants()
    assert alloc.free_pages == 0


def test_allocator_reservation_blocks_admission():
    """A reservation holds back pages for a live slot's future extends:
    a newcomer that would eat them is refused up front, and the live
    slot's extends then always succeed."""
    alloc = PageAllocator(num_pages=6, page_size=2, capacity=4, n_logical=6)
    alloc.admit(0, 2, reserve_tokens=8)     # 1 page now, 4 reserved
    assert not alloc.can_admit(6)           # 3 > 6 free - 3 outstanding
    assert alloc.can_admit(4)
    with pytest.raises(PoolExhausted):
        alloc.admit(1, 6, reserve_tokens=6)
    alloc.extend(0, 8)                      # reservation honoured
    assert len(alloc.slot_pages(0)) == 4


def test_allocator_extend_beyond_table_raises():
    alloc = PageAllocator(num_pages=8, page_size=2, capacity=2, n_logical=3)
    alloc.admit(0, 2)
    with pytest.raises(ValueError, match="logical"):
        alloc.extend(0, 8)                  # 4 pages > 3 table slots


# ----------------------------------------------------- paged <-> logical

def test_paginate_roundtrip_and_sentinel():
    rng = np.random.default_rng(0)
    cache = {"k": jnp.asarray(rng.normal(size=(2, 3, 10, 2, 4)),
                              jnp.float32),
             "v": jnp.asarray(rng.normal(size=(2, 3, 10, 2, 4)),
                              jnp.float32),
             "pos": jnp.asarray([10, 10, 10], jnp.int32)}
    paged = paginate_cache(cache, page_size=4)
    assert paged["bt"].shape == (3, 3)
    assert bool(jnp.all(paged["bt"] > 0))           # sentinel unmapped only
    assert bool(jnp.all(paged["k"][:, 0] == 0))     # sentinel page zeroed
    lv = logical_view(paged)
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(lv[key][:, :, :10]),
                                      np.asarray(cache[key]))


# ------------------------------------------------------------- scheduler

def _requests(cfg, lens, budgets, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m))
            for i, (l, m) in enumerate(zip(lens, budgets))]


def _assert_bit_identical(engine, params, run, requests, eos_id, **kw):
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = requests[r.request_id]
        ref = np.asarray(engine.generate(
            params, jnp.asarray(req.prompt[None, :]), req.max_new,
            eos_id=eos_id, **kw).tokens[0])
        n = r.prompt_len + r.generated
        assert r.generated >= 1
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from single-request engine")


def test_paged_bit_identity_and_pool_drains(tiny, engine):
    """Paged serving is bit-identical to BOTH the contiguous scheduler
    (same page-aligned cache_len -> identical reduction shapes ->
    identical logits) and the single-request engine; every page is back
    in the pool after the drain (free-on-eos, no leaks)."""
    cfg, model, params = tiny[:3]
    lens, budgets = [5, 12, 9, 16, 3, 7], [6, 3, 8, 2, 7, 4]
    runs = {}
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 eos_id=1, prompt_buckets=(8, 16),
                                 cache_len=28, cache=mode, page_size=4)
        runs[mode] = sched.run(_requests(cfg, lens, budgets))
        if mode == "paged":
            assert sched._alloc.free_pages == sched.num_pages
            sched._alloc.check_invariants()
    paged = {r.request_id: r.tokens for r in runs["paged"].results}
    contig = {r.request_id: r.tokens for r in runs["contiguous"].results}
    assert sorted(paged) == list(range(len(lens)))
    for rid in paged:
        assert np.array_equal(paged[rid], contig[rid]), (
            f"request {rid}: paged diverged from contiguous")
    _assert_bit_identical(engine, params, runs["paged"],
                          _requests(cfg, lens, budgets), eos_id=1)


def test_paged_sampled_identical_to_contiguous(tiny):
    """Sampled decode: per-request streams are identical between paged
    and contiguous mode (same page-aligned cache_len, same keys)."""
    cfg, model, params = tiny[:3]
    runs = {}
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=3,
                                 prompt_buckets=(8, 16), cache_len=24,
                                 cache=mode, page_size=4,
                                 temperature=0.8, top_k=4, sample_seed=7)
        runs[mode] = {r.request_id: r.tokens.tolist()
                      for r in sched.run(_requests(cfg, [5, 9, 7],
                                                   [6, 4, 5])).results}
    assert runs["paged"] == runs["contiguous"]


def test_paged_compressed_ns(tiny, tiny_ns):
    """MPIFA_NS (heterogeneous ranks, bucketed restack) serves through
    the paged scheduler bit-identically to the engine."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[6, 11, 4], budgets=[5, 3, 6])
    sched = ServingScheduler(model, tiny_ns, capacity=2, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16),
                             cache="paged", page_size=4)
    run = sched.run(reqs)
    _assert_bit_identical(GenerationEngine(model), tiny_ns, run, reqs,
                          eos_id=1)


def test_paged_speculative_greedy_and_sampled(tiny, engine, tiny_draft):
    """Paged speculative slots: greedy output bit-identical to the
    plain engine (and hence to contiguous spec slots); sampled slots
    reproduce the batch-1 ``engine.generate_speculative`` stream of
    their ``spec_request_key`` — the draft cache pages too."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache="paged",
                             page_size=4, draft_params=tiny_draft,
                             spec_k=3)
    run = sched.run(reqs)
    assert run.drafted > 0
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)
    assert sched._alloc.free_pages == sched.num_pages
    assert sched._dalloc.free_pages == sched.num_pages

    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache="paged",
                             page_size=4, draft_params=tiny_draft,
                             spec_k=3, temperature=0.8, top_k=4,
                             sample_seed=11)
    run = sched.run(reqs)
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = reqs[r.request_id]
        ref = engine.generate_speculative(
            params, tiny_draft, jnp.asarray(req.prompt[None, :]),
            req.max_new, spec_k=3, temperature=0.8, top_k=4, eos_id=1,
            key=sched.spec_request_key(req.request_id))
        n = r.prompt_len + r.generated
        assert np.array_equal(r.tokens[:n], np.asarray(ref.tokens[0])[:n]), (
            f"request {r.request_id} diverged from engine stream")


def test_paged_no_pages_deferral_then_serves(tiny, engine):
    """An undersized pool defers admission with a ``no_pages`` reason
    (reported in SchedulerRun.deferrals, not a bare retry) and admits
    once finished requests free their pages — outputs still exact."""
    cfg, model, params = tiny[:3]
    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8])
    sched = ServingScheduler(model, params, capacity=4, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16), cache_len=28,
                             cache="paged", page_size=4, num_pages=9)
    run = sched.run(reqs)
    assert run.deferrals.get("no_pages", 0) > 0
    assert sorted(r.request_id for r in run.results) == [0, 1, 2]
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)


def test_paged_no_slot_deferral_reported(tiny):
    """Slot starvation is reported as ``no_slot`` (distinct from page
    starvation) — the single-slot queue defers the followers."""
    cfg, model, params = tiny[:3]
    sched = ServingScheduler(model, params, capacity=1, chunk=2,
                             prompt_buckets=(8,), cache="paged",
                             page_size=4)
    run = sched.run(_requests(cfg, [5, 6, 7], [4, 4, 4]))
    assert run.deferrals.get("no_slot", 0) > 0
    assert run.deferrals.get("no_pages", 0) == 0


def test_paged_request_that_never_fits_raises(tiny):
    """A request whose worst case exceeds the whole pool raises a
    bucket-mismatch/pool error instead of deferring forever."""
    cfg, model, params = tiny[:3]
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,), cache_len=32,
                             cache="paged", page_size=4, num_pages=4)
    big = Request(request_id=9, prompt=np.zeros(5, np.int32), max_new=20)
    with pytest.raises(ValueError, match="never be admitted"):
        sched.run([big])
    assert len(sched._free) == sched.capacity      # nothing leaked
    sched._queue.popleft()
    run = sched.run(_requests(cfg, [5], [4]))
    assert [r.request_id for r in run.results] == [0]


def test_paged_bucket_mismatch_raises(tiny):
    """Oversized-for-cache_len requests raise the (renamed) bucket
    mismatch error in both cache modes; state stays intact."""
    cfg, model, params = tiny[:3]
    for mode in ("contiguous", "paged"):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(8,), cache_len=16,
                                 cache=mode, page_size=4)
        big = Request(request_id=9, prompt=np.zeros(5, np.int32),
                      max_new=50)
        with pytest.raises(ValueError, match="bucket mismatch"):
            sched.run([big])
        assert len(sched._free) == sched.capacity


def test_paged_hybrid_bit_identity():
    """The hybrid family pages its shared-attention KV (conv/ssm state
    stays per-slot by design) and still serves bit-identically."""
    cfg = get_smoke_config("zamba2_1p2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5, 11], budgets=[4, 2, 5, 3])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             cache="paged", page_size=4)
    assert sched.prompt_buckets is None
    run = sched.run(reqs)
    assert sched._paged_kv
    _assert_bit_identical(GenerationEngine(model), params, run, reqs,
                          eos_id=1)


def test_paged_mamba2_is_noop_by_design():
    """Pure SSM state is constant size — paged mode has nothing to page
    and must behave exactly like the contiguous scheduler."""
    cfg = get_smoke_config("mamba2_2p7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5], budgets=[4, 2, 5])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             cache="paged", page_size=4)
    run = sched.run(reqs)
    assert not sched._paged_kv                  # nothing paged
    _assert_bit_identical(GenerationEngine(model), params, run, reqs,
                          eos_id=1)


def test_paged_ring_arch_refuses_loudly():
    cfg = get_smoke_config("gemma3_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ring"):
        ServingScheduler(model, params, cache="paged")


def test_paged_config_errors(tiny):
    cfg, model, params = tiny[:3]
    with pytest.raises(ValueError, match="cache"):
        ServingScheduler(model, params, cache="virtual")
    with pytest.raises(ValueError, match="page_size"):
        ServingScheduler(model, params, cache="paged", page_size=0)
