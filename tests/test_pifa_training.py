"""PIFA is differentiable (paper §6): fine-tuning the factorized form."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.models.model import build_model, make_train_step
from repro.optim.adamw import AdamW

CFG = ModelConfig(name="ft-tiny", family="dense", num_layers=2, d_model=48,
                  num_heads=4, num_kv_heads=4, d_ff=144, vocab_size=64,
                  tie_embeddings=True)


def test_train_step_through_pifa_factors():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    calib = [jax.random.randint(jax.random.PRNGKey(i), (1, 32), 0,
                                CFG.vocab_size) for i in range(2)]
    cp = compress_transformer(model, params, calib,
                              MpifaConfig(density=0.6))
    stacked = model.restack_blocks(cp)
    assert stacked is not None

    optim = AdamW(lr=1e-3)
    step = jax.jit(make_train_step(model, CFG, optim))
    opt = optim.init(stacked)
    pipe = TokenPipeline(DataConfig(vocab_size=CFG.vocab_size, seq_len=32,
                                    global_batch=4))
    losses = []
    inv_before = np.asarray(stacked["blocks"]["mlp"]["gate"]["inv_perm"])
    p = stacked
    for i in range(8):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        loss, p, opt = step(p, opt, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0]          # factors actually train
    inv_after = np.asarray(p["blocks"]["mlp"]["gate"]["inv_perm"])
    np.testing.assert_array_equal(inv_before, inv_after)  # structural


def test_restack_uniform_blocks():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    un = model.unstack_blocks(params)
    re = model.restack_blocks(un)
    assert re is not None
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(re)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restack_heterogeneous_returns_none():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    un = model.unstack_blocks(params)
    # corrupt one block's shape (simulates MPIFA_NS per-layer ranks)
    b0 = dict(un["blocks"][0])
    b0["mlp"] = dict(b0["mlp"])
    b0["mlp"]["up"] = {"u": jnp.zeros((CFG.d_ff, 3)),
                       "vt": jnp.zeros((3, CFG.d_model))}
    un["blocks"][0] = b0
    assert model.restack_blocks(un) is None
