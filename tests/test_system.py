"""End-to-end behaviour: train a real (tiny) LM on structured data, run
the full MPIFA pipeline on the TRAINED weights, and check the paper's
qualitative claims (Table 2/5 ordering) hold on real perplexities.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.data.pipeline import DataConfig, SyntheticLM, TokenPipeline
from repro.models.model import build_model, make_train_step
from repro.optim.adamw import AdamW

CFG = ModelConfig(name="sys-tiny", family="dense", num_layers=2, d_model=64,
                  num_heads=4, num_kv_heads=4, d_ff=192, vocab_size=128,
                  tie_embeddings=True)


@pytest.fixture(scope="module")
def trained():
    model = build_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    optim = AdamW(lr=3e-3)
    step = jax.jit(make_train_step(model, CFG, optim))
    opt = optim.init(params)
    pipe = TokenPipeline(DataConfig(vocab_size=CFG.vocab_size, seq_len=64,
                                    global_batch=8, seed=0))
    losses = []
    for i in range(80):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        loss, params, opt = step(params, opt, batch)
        losses.append(float(loss))
    eval_batches = [pipe.batch_at(1000 + i) for i in range(4)]
    return model, params, losses, eval_batches


def _ppl(model, params, eval_batches, unstacked=False):
    tot, n = 0.0, 0
    for b in eval_batches:
        toks = jnp.asarray(b["tokens"])
        labels = jnp.asarray(b["labels"])
        fwd = model.forward_unstacked if unstacked else model.forward
        logits = fwd(params, toks).astype(jnp.float32)
        lp = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(lp, labels[..., None], -1)
        tot += float(nll.sum())
        n += labels.size
    return float(np.exp(tot / n))


def test_training_learns(trained):
    model, params, losses, eb = trained
    assert losses[-1] < losses[0] - 0.5  # real learning happened


def test_mpifa_quality_ordering_on_trained_model(trained):
    """The paper's central quality claims, on a real trained model:
       dense < MPIFA <= W+M < W (whiten-only) < vanilla SVD   (PPL)."""
    model, params, losses, eb = trained
    calib = [jnp.asarray(TokenPipeline(
        DataConfig(vocab_size=CFG.vocab_size, seq_len=64, global_batch=2,
                   seed=7)).batch_at(i)["tokens"]) for i in range(6)]
    density = 0.55

    def run(**kw):
        return _ppl(model, compress_transformer(
            model, params, calib, MpifaConfig(density=density, **kw)),
            eb, unstacked=True)

    ppl_dense = _ppl(model, params, eb)
    ppl_svd = run(prune="svd", reconstruct="none", final_repr="lowrank")
    ppl_w = run(prune="whiten", reconstruct="none", final_repr="lowrank")
    ppl_wm = run(prune="whiten", reconstruct="m", final_repr="lowrank")
    ppl_mpifa = run(prune="whiten", reconstruct="m", final_repr="pifa")

    assert ppl_dense < ppl_mpifa          # compression costs something
    assert ppl_w <= ppl_svd * 1.02        # whitening helps (Table 5: W vs SVD)
    assert ppl_wm <= ppl_w * 1.02         # M helps (Table 5: W+M vs W)
    assert ppl_mpifa <= ppl_wm * 1.02     # PIFA's extra rank helps (MPIFA)
    # and the end-to-end gap vs the best baseline is meaningful
    assert ppl_mpifa <= ppl_svd


def test_fullbatch_reconstruction_can_overfit(trained):
    """Table 5 finding: full-batch U-only reconstruction (W+U) is not
    reliably better than W -- our M must not be worse than W+U."""
    model, params, losses, eb = trained
    calib = [jnp.asarray(TokenPipeline(
        DataConfig(vocab_size=CFG.vocab_size, seq_len=64, global_batch=2,
                   seed=9)).batch_at(i)["tokens"]) for i in range(4)]

    def run(**kw):
        return _ppl(model, compress_transformer(
            model, params, calib, MpifaConfig(density=0.55, **kw)),
            eb, unstacked=True)

    ppl_wu = run(prune="whiten", reconstruct="fullbatch",
                 final_repr="lowrank")
    ppl_wm = run(prune="whiten", reconstruct="m", final_repr="lowrank")
    assert ppl_wm <= ppl_wu * 1.05
