import os
import sys

# Tests see the default single CPU device (the dry-run alone forces 512
# placeholder devices, in its own process). Keep XLA quiet and small.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
