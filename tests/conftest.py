import os
import sys

# Tests see the default single CPU device (the dry-run alone forces 512
# placeholder devices, in its own process). Keep XLA quiet and small.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# Shared tiny-model fixtures (session-scoped).
#
# test_engine / test_scheduler / test_speculative / test_conformance all
# exercise the same tiny transformer and its MPIFA-compressed variants;
# building them (especially the NS compression sweep) dominated tier-1
# wall-clock when each module owned a copy.  One session-scoped build
# serves every suite.
# ---------------------------------------------------------------------------

PROMPT_LEN = 12


class FakeClock:
    """Deterministic injectable clock shared by every robustness test
    (fault_tolerance components AND the scheduler's admission backoff):
    time only moves when advanced, so no test sleeps on wall-clock."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, seconds: float) -> None:
        self.t += float(seconds)

    # drop-in for the scheduler's sleep_fn: sleeping IS advancing
    sleep = advance


@pytest.fixture
def fake_clock():
    return FakeClock()


@pytest.fixture(scope="session")
def tiny():
    """(cfg, model, params, calib, prompts): random-init tiny LM with
    calibration batches and (4, 12) greedy-probe prompts."""
    from repro.configs.base import get_config
    from repro.models.model import build_model
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                cfg.vocab_size) for i in range(3)]
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size,
                                          (4, PROMPT_LEN)), jnp.int32)
    return cfg, model, params, calib, prompts


@pytest.fixture(scope="session")
def engine(tiny):
    from repro.runtime.engine import GenerationEngine
    return GenerationEngine(tiny[1])


@pytest.fixture(scope="session")
def tiny_pifa(tiny):
    """Uniform-density MPIFA compression of the tiny LM."""
    from repro.core.mpifa import MpifaConfig, compress_transformer
    cfg, model, params, calib, _ = tiny
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.55))


@pytest.fixture(scope="session")
def tiny_ns(tiny):
    """MPIFA_NS: per-layer densities -> heterogeneous PIFA ranks."""
    from repro.core.mpifa import MpifaConfig, compress_transformer
    cfg, model, params, calib, _ = tiny
    md = {}
    for bi in range(cfg.num_layers):
        rho = 0.4 if bi % 2 == 0 else 0.7
        for info in model.linears_in_block():
            md[f"block{bi}/" + "/".join(info.path)] = rho
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.55, module_density=md))


@pytest.fixture(scope="session")
def tiny_draft(tiny):
    """A more aggressively compressed draft of the same weights."""
    from repro.core.mpifa import MpifaConfig, compress_transformer
    cfg, model, params, calib, _ = tiny
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.45))
