"""Speculative decoding: draft-then-verify through the engine and the
continuous-batching scheduler.

The acceptance bar mirrors ISSUE 3/4: greedy speculative output must be
BIT-IDENTICAL to plain engine generation — for dense, PIFA and
rank-bucketed MPIFA_NS targets, for the SSM/hybrid/ring families (whose
verify rolls back through per-step state checkpoints), at both extremes
of acceptance, with eos landing inside an accepted run, and for
scheduler slots mixing speculative and plain requests.  Sampled
speculative scheduler slots must reproduce the token stream of a
batch-1 ``engine.generate_speculative`` call with the slot's request
key (``ServingScheduler.spec_request_key``).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.scheduler import Request, ServingScheduler

MAX_NEW = 12
PROMPT = 12  # mirrors the conftest prompt fixture


# ------------------------------------------------------------ verify mode

def test_verify_step_matches_sequential_decode(tiny):
    """The multi-token cached forward: verify logits at every position
    match one-token-at-a-time decode_step logits."""
    cfg, model, params, calib, prompts = tiny
    k = 3
    cache = model.init_cache(prompts.shape[0], PROMPT + k + 2,
                             dtype=jnp.float32)
    logits, cache_seq = model.prefill(params, prompts, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    toks = [nxt]
    seq_logits = []
    for _ in range(k):
        lg, cache_seq = model.decode_step(params, toks[-1], cache_seq)
        seq_logits.append(lg[:, -1, :])
        toks.append(jnp.argmax(lg[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None])
    cache2 = model.init_cache(prompts.shape[0], PROMPT + k + 2,
                              dtype=jnp.float32)
    _, cache_v = model.prefill(params, prompts, cache2)
    vin = jnp.concatenate(toks, axis=1)               # (b, k+1)
    vlogits, cache_v = model.verify_step(params, vin, cache_v)
    assert vlogits.shape == (prompts.shape[0], k + 1, cfg.vocab_size)
    assert bool(jnp.all(cache_v["pos"] == cache_seq["pos"] + 1))
    for i in range(k):
        np.testing.assert_allclose(np.asarray(vlogits[:, i, :]),
                                   np.asarray(seq_logits[i]),
                                   rtol=1e-5, atol=1e-5)


def test_verify_step_encdec_matches_sequential_decode():
    """The decoder-side cache of the enc-dec family is purely
    positional (cross-KV is static), so multi-token verify works there
    too — logits match sequential decode_step."""
    cfg = get_smoke_config("whisper_medium")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    batch = {"frames": jnp.asarray(rng.normal(size=(1, cfg.encoder_seq,
                                                    cfg.d_model)) * 0.1,
                                   jnp.float32),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)),
                                   jnp.int32)}
    k = 2
    cache = model.init_cache(1, 6 + k + 2, dtype=jnp.float32)
    logits, cache_seq = model.prefill(params, batch, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    toks, seq_logits = [nxt], []
    for _ in range(k):
        lg, cache_seq = model.decode_step(params, toks[-1], cache_seq)
        seq_logits.append(lg[:, -1, :])
        toks.append(jnp.argmax(lg[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None])
    cache2 = model.init_cache(1, 6 + k + 2, dtype=jnp.float32)
    _, cache_v = model.prefill(params, batch, cache2)
    vlogits, _ = model.verify_step(params, jnp.concatenate(toks, axis=1),
                                   cache_v)
    for i in range(k):
        np.testing.assert_allclose(np.asarray(vlogits[:, i, :]),
                                   np.asarray(seq_logits[i]),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("arch", ["mamba2_2p7b", "zamba2_1p2b",
                                  "gemma3_12b"])
def test_verify_step_ssm_and_ring_matches_sequential_decode(arch):
    """SSM recurrences and ring caches now verify through the scan-of-
    decode-steps path: logits are BIT-identical to sequential
    decode_step logits (same computation inside one dispatch), and the
    advanced cache carries the per-step checkpoint stack."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 9)), jnp.int32)
    k = 3
    cache_len = 9 + k + 4  # > gemma smoke window 8: ring engages
    cache = model.init_cache(2, cache_len, dtype=jnp.float32)
    if arch == "gemma3_12b":
        assert "kl" in cache
    logits, cache_seq = model.prefill(params, prompts, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    toks, seq_logits = [nxt], []
    for _ in range(k):
        lg, cache_seq = model.decode_step(params, toks[-1], cache_seq)
        seq_logits.append(lg[:, -1, :])
        toks.append(jnp.argmax(lg[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None])
    cache2 = model.init_cache(2, cache_len, dtype=jnp.float32)
    _, cache_v = model.prefill(params, prompts, cache2)
    vlogits, cache_v = model.verify_step(
        params, jnp.concatenate(toks, axis=1), cache_v)
    assert "ckpt" in cache_v                     # checkpoint stack rides
    assert bool(jnp.all(cache_v["pos"] == cache_seq["pos"] + 1))
    for i in range(k):
        # scan-of-decode verify: BIT-identical, not just close
        assert bool(jnp.all(vlogits[:, i, :] == seq_logits[i])), (arch, i)


def test_ring_verify_rejects_oversized_k():
    """spec_k + 1 > window would overwrite the same ring slot twice in
    one verify — refused loudly at every entry point."""
    g = build_model(get_smoke_config("gemma3_12b"))
    gp = g.init(jax.random.PRNGKey(0))
    w = g.cfg.sliding_window
    rc = g.init_cache(1, w + 8, dtype=jnp.float32)
    assert "kl" in rc
    with pytest.raises(ValueError, match="distinct ring slot"):
        g.verify_step(gp, jnp.zeros((1, w + 1), jnp.int32), rc)
    eng = GenerationEngine(g)
    with pytest.raises(ValueError, match="distinct ring slot"):
        eng.generate_speculative(gp, gp, jnp.zeros((1, 6), jnp.int32),
                                 8, cache_len=w + 8, spec_k=w)
    with pytest.raises(ValueError, match="distinct ring slot"):
        ServingScheduler(g, gp, capacity=1, draft_params=gp, spec_k=w,
                         cache_len=w + 8).run(
            [Request(request_id=0, prompt=np.zeros(4, np.int32),
                     max_new=2)])


# ----------------------------------------------------- engine bit-identity

@pytest.mark.parametrize("target", ["dense", "pifa", "ns"])
def test_greedy_bit_identity(tiny, engine, tiny_pifa, tiny_ns, tiny_draft,
                             target):
    """Greedy speculative == plain engine generation, token for token,
    for every target representation (draft at a different density, so
    acceptance is partial — the interesting regime)."""
    cfg, model, params, calib, prompts = tiny
    tp = {"dense": params, "pifa": tiny_pifa, "ns": tiny_ns}[target]
    ref = engine.generate(tp, prompts, MAX_NEW)
    res = engine.generate_speculative(tp, tiny_draft, prompts, MAX_NEW,
                                      spec_k=4)
    assert bool(jnp.all(res.tokens == ref.tokens)), target
    assert res.emitted_per_dispatch >= 1.0
    assert res.rounds >= 1


@pytest.mark.parametrize("arch", ["mamba2_2p7b", "zamba2_1p2b",
                                  "gemma3_12b"])
def test_greedy_bit_identity_ssm_and_ring(arch):
    """The previously refused families: greedy speculative decoding is
    bit-identical to plain scanned decode for SSM (mamba2), hybrid
    (zamba2) and ring-cache (gemma3) targets, with an identical draft
    (all-accept) AND an independent random draft (all-reject)."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    eng = GenerationEngine(model)
    ref = eng.generate(params, prompts, 7)
    res = eng.generate_speculative(params, params, prompts, 7, spec_k=3)
    assert bool(jnp.all(res.tokens == ref.tokens)), arch
    assert res.acceptance_rate > 0.7          # identical draft accepts
    assert res.emitted_per_dispatch > 1.0
    dparams = model.init(jax.random.PRNGKey(99))
    res2 = eng.generate_speculative(params, dparams, prompts, 7, spec_k=3)
    assert bool(jnp.all(res2.tokens == ref.tokens)), arch


def test_all_accept_identical_draft(tiny, engine):
    """Draft == target: every proposal accepted, rounds collapse to
    ceil((max_new-1)/(k+1))."""
    cfg, model, params, calib, prompts = tiny
    k = 3
    ref = engine.generate(params, prompts, MAX_NEW)
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=k)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.acceptance_rate == 1.0
    assert res.rounds == -(-(MAX_NEW - 1) // (k + 1))


def test_all_reject_random_draft(tiny, engine):
    """An independent random-init draft: near-zero acceptance, output
    still bit-identical (every round falls back to the target token)."""
    cfg, model, params, calib, prompts = tiny
    dparams = model.init(jax.random.PRNGKey(99))
    ref = engine.generate(params, prompts, MAX_NEW)
    res = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                      spec_k=4)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.acceptance_rate < 0.5
    # worst case one emitted token per round per row
    assert res.rounds <= MAX_NEW


def test_rank_bucket_mismatch(tiny, tiny_ns, tiny_draft):
    """Target restacks into multiple rank buckets, the draft into a
    different (uniform) stack — each traces its own forward, outputs
    stay bit-identical."""
    cfg, model, params, calib, prompts = tiny
    eng = GenerationEngine(model, max_buckets=4)
    prepared = eng.prepare_params(tiny_ns)
    assert "block_buckets" in prepared        # multi-bucket target
    dprep = eng.prepare_params(tiny_draft)
    assert "block_buckets" not in dprep       # uniform draft stack
    ref = eng.generate(tiny_ns, prompts, MAX_NEW)
    res = eng.generate_speculative(tiny_ns, tiny_draft, prompts, MAX_NEW,
                                   spec_k=3)
    assert bool(jnp.all(res.tokens == ref.tokens))


def test_eos_inside_accepted_run(tiny, engine):
    """An eos token landing mid-run (identical draft: the whole run is
    accepted) stops the row exactly where plain generation stops, and
    the remaining positions emit eos fill."""
    cfg, model, params, calib, prompts = tiny
    greedy = engine.generate(params, prompts, MAX_NEW)
    # the token greedy emits at step 4 of row 0 lands INSIDE the first
    # accepted run of a k=6 all-accept speculation (positions 1..6)
    eos = int(greedy.tokens[0, PROMPT + 3])
    ref = engine.generate(params, prompts, MAX_NEW, eos_id=eos)
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=6, eos_id=eos)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.generated == ref.generated
    gen = np.asarray(res.tokens[:, PROMPT:])
    for row in gen:
        hits = np.nonzero(row == eos)[0]
        if hits.size:
            assert np.all(row[hits[0]:] == eos)


def test_sampled_speculative_deterministic(tiny, engine):
    """Sampled speculation: deterministic given the key, different
    across keys, and still a valid token stream."""
    cfg, model, params, calib, prompts = tiny
    dparams = model.init(jax.random.PRNGKey(99))
    kw = dict(spec_k=3, temperature=0.8, top_k=4)
    r1 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(5), **kw)
    r2 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(5), **kw)
    assert bool(jnp.all(r1.tokens == r2.tokens))
    r3 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(6), **kw)
    assert not bool(jnp.all(r1.tokens == r3.tokens))
    assert r1.tokens.shape == (prompts.shape[0], PROMPT + MAX_NEW)
    assert int(jnp.max(r1.tokens)) < cfg.vocab_size


def test_sampled_identical_draft_high_acceptance(tiny, engine):
    """Rejection sampling with p_d == p_t accepts with probability 1:
    an identical draft must keep (nearly) everything even when
    sampling."""
    cfg, model, params, calib, prompts = tiny
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=3, temperature=0.7,
                                      key=jax.random.PRNGKey(1))
    assert res.acceptance_rate > 0.99


# ------------------------------------------------------- scheduler slots

def _requests(cfg, lens, budgets, seed=0, spec=None):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m),
                    speculative=True if spec is None else spec[i])
            for i, (l, m) in enumerate(zip(lens, budgets))]


def _assert_bit_identical(engine, params, run, requests, eos_id):
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = requests[r.request_id]
        ref = np.asarray(engine.generate(
            params, jnp.asarray(req.prompt[None, :]), req.max_new,
            eos_id=eos_id).tokens[0])
        n = r.prompt_len + r.generated
        assert r.generated >= 1
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from single-request engine")


def test_scheduler_mixed_spec_and_plain_slots(tiny, engine, tiny_draft):
    """Speculative and plain requests share the slot batch: every
    output bit-identical to the engine, accept/reject bookkeeping only
    accrues on speculative slots — plain slots report n/a (None), so
    they never pollute the aggregate acceptance rate."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[5, 9, 7, 12, 4], budgets=[6, 3, 8, 5, 7],
                     spec=[True, False, True, True, False])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16),
                             draft_params=tiny_draft, spec_k=3)
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == list(range(5))
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)
    by_id = {r.request_id: r for r in run.results}
    for rid in (1, 4):                       # plain slots: n/a, not 0/0
        assert by_id[rid].drafted is None and by_id[rid].accepted is None
    for rid in (0, 2, 3):
        assert by_id[rid].drafted is not None
    assert sum(by_id[rid].drafted for rid in (0, 2, 3)) > 0
    assert run.drafted == sum(r.drafted for r in run.results
                              if r.drafted is not None)
    assert run.accepted <= run.drafted


def test_scheduler_spec_compressed_target(tiny, tiny_pifa, tiny_draft):
    """PIFA target + lower-density draft through scheduler slots."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[6, 11, 4], budgets=[5, 3, 6])
    sched = ServingScheduler(model, tiny_pifa, capacity=2, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16),
                             draft_params=tiny_draft, spec_k=4)
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, tiny_pifa, run, reqs, eos_id=1)


def test_scheduler_spec_variable_advance_chunk_boundaries(tiny, engine):
    """All-accept draft: slots advance k+1 tokens per round, budgets
    that are NOT multiples of the advance still finish exactly."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[6, 8], budgets=[7, 10])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,), draft_params=params,
                             spec_k=3)
    run = sched.run(reqs)
    for r in run.results:
        assert r.generated == reqs[r.request_id].max_new
    _assert_bit_identical(engine, params, run, reqs, eos_id=None)
    # proposals past the budget are drafted-but-unconsumed (the final
    # round clips emit_n), so the rate stays below 1.0 by exactly that
    # tail — anything high means the variable advance really ran
    assert run.acceptance_rate > 0.7


@pytest.mark.parametrize("arch", ["mamba2_2p7b", "zamba2_1p2b",
                                  "gemma3_12b"])
def test_scheduler_spec_ssm_and_ring_slots(arch):
    """Speculative slots for the previously refused families: SSM and
    hybrid roll back through per-step state checkpoints, ring caches
    through saved-slot restores — every request bit-identical to the
    single-request engine."""
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = _requests(cfg, lens=[6, 9, 5], budgets=[5, 3, 6], seed=2)
    kw = dict(capacity=2, chunk=2, eos_id=1, draft_params=params,
              spec_k=3)
    if arch == "gemma3_12b":
        kw["cache_len"] = 9 + 6 + 3 + 2   # > window 8: ring engages
    sched = ServingScheduler(model, params, **kw)
    assert sched.prompt_buckets is None   # exact-length prefills forced
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, params, run, reqs, eos_id=1)
    assert run.drafted > 0


def test_scheduler_sampled_spec_matches_engine_streams(tiny, engine,
                                                       tiny_draft):
    """THE sampled-slot contract: a sampled speculative scheduler slot
    reproduces the token stream of a batch-1
    ``engine.generate_speculative`` call keyed by
    ``spec_request_key(request_id)`` — slot placement, chunk
    boundaries and batch composition are invisible."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 8], seed=3)
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16),
                             draft_params=tiny_draft, spec_k=3,
                             temperature=0.8, top_k=4, sample_seed=11)
    run = sched.run(reqs)
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = reqs[r.request_id]
        ref = engine.generate_speculative(
            params, tiny_draft, jnp.asarray(req.prompt[None, :]),
            req.max_new, spec_k=3, temperature=0.8, top_k=4, eos_id=1,
            key=sched.spec_request_key(req.request_id))
        n = r.prompt_len + r.generated
        assert np.array_equal(r.tokens[:n], np.asarray(ref.tokens[0])[:n]), (
            f"request {r.request_id} diverged from engine stream")


def test_scheduler_sampled_spec_deterministic_and_seed_sensitive(
        tiny, tiny_draft):
    """Same sample_seed reproduces every sampled-spec stream; a
    different seed changes them; plain slots mix in and stay in-vocab."""
    cfg, model, params, calib, _ = tiny

    def run_with(seed):
        sched = ServingScheduler(model, params, capacity=2, chunk=2,
                                 prompt_buckets=(8, 16),
                                 draft_params=tiny_draft, spec_k=2,
                                 temperature=0.9, sample_seed=seed)
        reqs = _requests(cfg, lens=[5, 9, 7], budgets=[6, 4, 5],
                         spec=[True, False, True])
        return {r.request_id: r.tokens.tolist()
                for r in sched.run(reqs).results}

    r1, r2, r3 = run_with(7), run_with(7), run_with(8)
    assert r1 == r2
    assert r1 != r3
    assert all(t < cfg.vocab_size for toks in r1.values() for t in toks)


def test_scheduler_spec_config_errors(tiny, tiny_draft):
    cfg, model, params, calib, _ = tiny
    with pytest.raises(ValueError, match="top_k"):
        ServingScheduler(model, params, top_k=5)
    with pytest.raises(ValueError, match="spec_k"):
        ServingScheduler(model, params, draft_params=tiny_draft, spec_k=0)
