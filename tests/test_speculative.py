"""Speculative decoding: draft-then-verify through the engine and the
continuous-batching scheduler.

The acceptance bar mirrors ISSUE 3: greedy speculative output must be
BIT-IDENTICAL to plain engine generation — for dense, PIFA and
rank-bucketed MPIFA_NS targets, at both extremes of acceptance
(identical draft accepts everything, an independent random draft
rejects essentially everything), with eos landing inside an accepted
run, and for scheduler slots mixing speculative and plain requests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, get_smoke_config
from repro.core.mpifa import MpifaConfig, compress_transformer
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.scheduler import Request, ServingScheduler

MAX_NEW = 12
PROMPT = 10


@pytest.fixture(scope="module")
def tiny():
    cfg = get_config("tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    calib = [jax.random.randint(jax.random.PRNGKey(i), (2, 32), 0,
                                cfg.vocab_size) for i in range(3)]
    prompts = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (3, PROMPT)),
        jnp.int32)
    return cfg, model, params, calib, prompts


@pytest.fixture(scope="module")
def engine(tiny):
    return GenerationEngine(tiny[1])


@pytest.fixture(scope="module")
def tiny_pifa(tiny):
    cfg, model, params, calib, _ = tiny
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.7))


@pytest.fixture(scope="module")
def tiny_draft(tiny):
    """A more aggressively compressed draft of the same weights."""
    cfg, model, params, calib, _ = tiny
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.45))


@pytest.fixture(scope="module")
def tiny_ns(tiny):
    """MPIFA_NS: per-layer densities -> heterogeneous PIFA ranks."""
    cfg, model, params, calib, _ = tiny
    md = {}
    for bi in range(cfg.num_layers):
        rho = 0.4 if bi % 2 == 0 else 0.7
        for info in model.linears_in_block():
            md[f"block{bi}/" + "/".join(info.path)] = rho
    return compress_transformer(model, params, calib,
                                MpifaConfig(density=0.55, module_density=md))


# ------------------------------------------------------------ verify mode

def test_verify_step_matches_sequential_decode(tiny):
    """The new multi-token cached forward: verify logits at every
    position match one-token-at-a-time decode_step logits."""
    cfg, model, params, calib, prompts = tiny
    k = 3
    cache = model.init_cache(prompts.shape[0], PROMPT + k + 2,
                             dtype=jnp.float32)
    logits, cache_seq = model.prefill(params, prompts, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    toks = [nxt]
    seq_logits = []
    for _ in range(k):
        lg, cache_seq = model.decode_step(params, toks[-1], cache_seq)
        seq_logits.append(lg[:, -1, :])
        toks.append(jnp.argmax(lg[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None])
    cache2 = model.init_cache(prompts.shape[0], PROMPT + k + 2,
                              dtype=jnp.float32)
    _, cache_v = model.prefill(params, prompts, cache2)
    vin = jnp.concatenate(toks, axis=1)               # (b, k+1)
    vlogits, cache_v = model.verify_step(params, vin, cache_v)
    assert vlogits.shape == (prompts.shape[0], k + 1, cfg.vocab_size)
    assert bool(jnp.all(cache_v["pos"] == cache_seq["pos"] + 1))
    for i in range(k):
        np.testing.assert_allclose(np.asarray(vlogits[:, i, :]),
                                   np.asarray(seq_logits[i]),
                                   rtol=1e-5, atol=1e-5)


def test_verify_step_encdec_matches_sequential_decode():
    """The decoder-side cache of the enc-dec family is purely
    positional (cross-KV is static), so multi-token verify works there
    too — logits match sequential decode_step."""
    cfg = get_smoke_config("whisper_medium")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    batch = {"frames": jnp.asarray(rng.normal(size=(1, cfg.encoder_seq,
                                                    cfg.d_model)) * 0.1,
                                   jnp.float32),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)),
                                   jnp.int32)}
    k = 2
    cache = model.init_cache(1, 6 + k + 2, dtype=jnp.float32)
    logits, cache_seq = model.prefill(params, batch, cache)
    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    toks, seq_logits = [nxt], []
    for _ in range(k):
        lg, cache_seq = model.decode_step(params, toks[-1], cache_seq)
        seq_logits.append(lg[:, -1, :])
        toks.append(jnp.argmax(lg[:, -1, :], axis=-1
                               ).astype(jnp.int32)[:, None])
    cache2 = model.init_cache(1, 6 + k + 2, dtype=jnp.float32)
    _, cache_v = model.prefill(params, batch, cache2)
    vlogits, _ = model.verify_step(params, jnp.concatenate(toks, axis=1),
                                   cache_v)
    for i in range(k):
        np.testing.assert_allclose(np.asarray(vlogits[:, i, :]),
                                   np.asarray(seq_logits[i]),
                                   rtol=1e-5, atol=1e-5)


def test_verify_refuses_ssm_and_ring():
    m = build_model(get_smoke_config("mamba2_2p7b"))
    p = m.init(jax.random.PRNGKey(0))
    cache = m.init_cache(1, 16, dtype=jnp.float32)
    with pytest.raises(NotImplementedError, match="rollback"):
        m.verify_step(p, jnp.zeros((1, 3), jnp.int32), cache)
    g = build_model(get_smoke_config("gemma3_12b"))
    gp = g.init(jax.random.PRNGKey(0))
    # cache_len > sliding_window engages the ring layout
    rc = g.init_cache(1, g.cfg.sliding_window + 8, dtype=jnp.float32)
    assert "kl" in rc
    with pytest.raises(ValueError, match="ring"):
        g.verify_step(gp, jnp.zeros((1, 3), jnp.int32), rc)


# ----------------------------------------------------- engine bit-identity

@pytest.mark.parametrize("target", ["dense", "pifa", "ns"])
def test_greedy_bit_identity(tiny, engine, tiny_pifa, tiny_ns, tiny_draft,
                             target):
    """Greedy speculative == plain engine generation, token for token,
    for every target representation (draft at a different density, so
    acceptance is partial — the interesting regime)."""
    cfg, model, params, calib, prompts = tiny
    tp = {"dense": params, "pifa": tiny_pifa, "ns": tiny_ns}[target]
    ref = engine.generate(tp, prompts, MAX_NEW)
    res = engine.generate_speculative(tp, tiny_draft, prompts, MAX_NEW,
                                      spec_k=4)
    assert bool(jnp.all(res.tokens == ref.tokens)), target
    assert res.emitted_per_dispatch >= 1.0
    assert res.rounds >= 1


def test_all_accept_identical_draft(tiny, engine):
    """Draft == target: every proposal accepted, rounds collapse to
    ceil((max_new-1)/(k+1))."""
    cfg, model, params, calib, prompts = tiny
    k = 3
    ref = engine.generate(params, prompts, MAX_NEW)
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=k)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.acceptance_rate == 1.0
    assert res.rounds == -(-(MAX_NEW - 1) // (k + 1))


def test_all_reject_random_draft(tiny, engine):
    """An independent random-init draft: near-zero acceptance, output
    still bit-identical (every round falls back to the target token)."""
    cfg, model, params, calib, prompts = tiny
    dparams = model.init(jax.random.PRNGKey(99))
    ref = engine.generate(params, prompts, MAX_NEW)
    res = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                      spec_k=4)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.acceptance_rate < 0.5
    # worst case one emitted token per round per row
    assert res.rounds <= MAX_NEW


def test_rank_bucket_mismatch(tiny, engine, tiny_ns, tiny_draft):
    """Target restacks into multiple rank buckets, the draft into a
    different (uniform) stack — each traces its own forward, outputs
    stay bit-identical."""
    cfg, model, params, calib, prompts = tiny
    eng = GenerationEngine(model, max_buckets=4)
    prepared = eng.prepare_params(tiny_ns)
    assert "block_buckets" in prepared        # multi-bucket target
    dprep = eng.prepare_params(tiny_draft)
    assert "block_buckets" not in dprep       # uniform draft stack
    ref = eng.generate(tiny_ns, prompts, MAX_NEW)
    res = eng.generate_speculative(tiny_ns, tiny_draft, prompts, MAX_NEW,
                                   spec_k=3)
    assert bool(jnp.all(res.tokens == ref.tokens))


def test_eos_inside_accepted_run(tiny, engine):
    """An eos token landing mid-run (identical draft: the whole run is
    accepted) stops the row exactly where plain generation stops, and
    the remaining positions emit eos fill."""
    cfg, model, params, calib, prompts = tiny
    greedy = engine.generate(params, prompts, MAX_NEW)
    # the token greedy emits at step 4 of row 0 lands INSIDE the first
    # accepted run of a k=6 all-accept speculation (positions 1..6)
    eos = int(greedy.tokens[0, PROMPT + 3])
    ref = engine.generate(params, prompts, MAX_NEW, eos_id=eos)
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=6, eos_id=eos)
    assert bool(jnp.all(res.tokens == ref.tokens))
    assert res.generated == ref.generated
    gen = np.asarray(res.tokens[:, PROMPT:])
    for row in gen:
        hits = np.nonzero(row == eos)[0]
        if hits.size:
            assert np.all(row[hits[0]:] == eos)


def test_sampled_speculative_deterministic(tiny, engine):
    """Sampled speculation: deterministic given the key, different
    across keys, and still a valid token stream."""
    cfg, model, params, calib, prompts = tiny
    dparams = model.init(jax.random.PRNGKey(99))
    kw = dict(spec_k=3, temperature=0.8, top_k=4)
    r1 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(5), **kw)
    r2 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(5), **kw)
    assert bool(jnp.all(r1.tokens == r2.tokens))
    r3 = engine.generate_speculative(params, dparams, prompts, MAX_NEW,
                                     key=jax.random.PRNGKey(6), **kw)
    assert not bool(jnp.all(r1.tokens == r3.tokens))
    assert r1.tokens.shape == (prompts.shape[0], PROMPT + MAX_NEW)
    assert int(jnp.max(r1.tokens)) < cfg.vocab_size


def test_sampled_identical_draft_high_acceptance(tiny, engine):
    """Rejection sampling with p_d == p_t accepts with probability 1:
    an identical draft must keep (nearly) everything even when
    sampling."""
    cfg, model, params, calib, prompts = tiny
    res = engine.generate_speculative(params, params, prompts, MAX_NEW,
                                      spec_k=3, temperature=0.7,
                                      key=jax.random.PRNGKey(1))
    assert res.acceptance_rate > 0.99


# ------------------------------------------------------- scheduler slots

def _requests(cfg, lens, budgets, seed=0, spec=None):
    rng = np.random.default_rng(seed)
    return [Request(request_id=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        int(l)).astype(np.int32),
                    max_new=int(m),
                    speculative=True if spec is None else spec[i])
            for i, (l, m) in enumerate(zip(lens, budgets))]


def _assert_bit_identical(engine, params, run, requests, eos_id):
    for r in sorted(run.results, key=lambda r: r.request_id):
        req = requests[r.request_id]
        ref = np.asarray(engine.generate(
            params, jnp.asarray(req.prompt[None, :]), req.max_new,
            eos_id=eos_id).tokens[0])
        n = r.prompt_len + r.generated
        assert r.generated >= 1
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"request {r.request_id} diverged from single-request engine")


def test_scheduler_mixed_spec_and_plain_slots(tiny, engine, tiny_draft):
    """Speculative and plain requests share the slot batch: every
    output bit-identical to the engine, accept/reject bookkeeping only
    accrues on speculative slots."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[5, 9, 7, 12, 4], budgets=[6, 3, 8, 5, 7],
                     spec=[True, False, True, True, False])
    sched = ServingScheduler(model, params, capacity=2, chunk=2, eos_id=1,
                             prompt_buckets=(8, 16),
                             draft_params=tiny_draft, spec_k=3)
    run = sched.run(reqs)
    assert sorted(r.request_id for r in run.results) == list(range(5))
    _assert_bit_identical(engine, params, run, reqs, eos_id=1)
    by_id = {r.request_id: r for r in run.results}
    for rid in (1, 4):                       # plain slots never draft
        assert by_id[rid].drafted == 0 and by_id[rid].accepted == 0
    assert sum(by_id[rid].drafted for rid in (0, 2, 3)) > 0
    assert run.drafted == sum(r.drafted for r in run.results)
    assert run.accepted <= run.drafted


def test_scheduler_spec_compressed_target(tiny, tiny_pifa, tiny_draft):
    """PIFA target + lower-density draft through scheduler slots."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[6, 11, 4], budgets=[5, 3, 6])
    sched = ServingScheduler(model, tiny_pifa, capacity=2, chunk=2,
                             eos_id=1, prompt_buckets=(8, 16),
                             draft_params=tiny_draft, spec_k=4)
    run = sched.run(reqs)
    eng = GenerationEngine(model)
    _assert_bit_identical(eng, tiny_pifa, run, reqs, eos_id=1)


def test_scheduler_spec_variable_advance_chunk_boundaries(tiny, engine):
    """All-accept draft: slots advance k+1 tokens per round, budgets
    that are NOT multiples of the advance still finish exactly."""
    cfg, model, params, calib, _ = tiny
    reqs = _requests(cfg, lens=[6, 8], budgets=[7, 10])
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(8,), draft_params=params,
                             spec_k=3)
    run = sched.run(reqs)
    for r in run.results:
        assert r.generated == reqs[r.request_id].max_new
    _assert_bit_identical(engine, params, run, reqs, eos_id=None)
    # proposals past the budget are drafted-but-unconsumed (the final
    # round clips emit_n), so the rate stays below 1.0 by exactly that
    # tail — anything high means the variable advance really ran
    assert run.acceptance_rate > 0.7


def test_scheduler_spec_config_errors(tiny, tiny_draft):
    cfg, model, params, calib, _ = tiny
    with pytest.raises(ValueError, match="greedy-only"):
        ServingScheduler(model, params, draft_params=tiny_draft,
                         temperature=0.5)
    with pytest.raises(ValueError, match="top_k"):
        ServingScheduler(model, params, top_k=5)
    m2 = build_model(get_smoke_config("mamba2_2p7b"))
    p2 = m2.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="rollback"):
        ServingScheduler(m2, p2, draft_params=p2)
    g = build_model(get_smoke_config("gemma3_12b"))
    gp = g.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ring"):
        ServingScheduler(g, gp, draft_params=gp)
