"""M reconstruction closed forms (Eqs. 4/5/8/9 + App. A)."""
import numpy as np
import pytest

from repro.core.lowrank import svd_lowrank, whitened_svd
from repro.core.reconstruct import (CalibStats, reconstruct_uv, solve_u,
                                    solve_u_fullbatch, solve_vt)


def make_problem(seed=0, m=48, n=40, r=12, N=400, noise=0.3):
    rng = np.random.default_rng(seed)
    w = rng.normal(size=(m, n))
    xo = rng.normal(size=(N, n))
    xu = xo + noise * rng.normal(size=(N, n))
    u, vt = svd_lowrank(w, r)
    return rng, w, xo, xu, u, vt


def test_online_equals_fullbatch_eq4_eq5():
    """Associativity: Eq. 5 accumulated stats == Eq. 4 full batch."""
    _, w, xo, xu, u, vt = make_problem()
    st = CalibStats(40, 48)
    for i in range(0, 400, 37):  # uneven chunks on purpose
        xb = xu[i:i + 37]
        st.update_inputs(w, xb, xb, lam=0.0)  # SVD-LLM target: W X_u
    u_online = solve_u(st, vt)
    u_batch = solve_u_fullbatch(w, vt, xu.T)
    np.testing.assert_allclose(u_online, u_batch, rtol=1e-8, atol=1e-8)


def test_solve_u_is_least_squares_optimum():
    """Perturbing the Eq. 5 solution can only increase the objective."""
    _, w, xo, xu, u, vt = make_problem()
    st = CalibStats(40, 48)
    st.update_inputs(w, xo, xu, lam=0.25)
    u_star = solve_u(st, vt)
    yt = (0.25 * xo + 0.75 * xu) @ w.T

    def obj(uu):
        return np.linalg.norm(yt - (xu @ vt.T) @ uu.T) ** 2

    base = obj(u_star)
    rng = np.random.default_rng(1)
    for _ in range(5):
        assert obj(u_star + 1e-3 * rng.normal(size=u_star.shape)) >= base - 1e-9


def test_solve_vt_matches_appendix_a():
    """V^T = (U^T U)^{-1} U^T Y X^T (X X^T)^{-1} (alpha=0 limit)."""
    _, w, xo, xu, u, vt = make_problem(N=600)
    st = CalibStats(40, 48)
    st.update_inputs(w, xu, xu, lam=1.0)  # Y_t = W X_u, X = X_u
    vt_star = solve_vt(st, u, w=None, alpha=0.0)
    x = xu.T
    y = w @ x
    expect = (np.linalg.pinv(u.T @ u) @ u.T @ y @ x.T
              @ np.linalg.pinv(x @ x.T))
    np.testing.assert_allclose(vt_star, expect, rtol=1e-6, atol=1e-6)


def test_alpha_regularization_fixes_singularity():
    """Singular XX^T (fewer samples than dims) -> alpha ridge keeps the
    solve finite and pulls U Vt toward W (App. B.1)."""
    rng = np.random.default_rng(2)
    m, n, r = 24, 32, 6
    w = rng.normal(size=(m, n))
    x = rng.normal(size=(8, n))  # 8 samples < 32 dims: XX^T singular
    u, vt = svd_lowrank(w, r)
    st = CalibStats(n, m)
    st.update_inputs(w, x, x, lam=0.25)
    vt_r = solve_vt(st, u, w=w, alpha=1e-3)
    assert np.isfinite(vt_r).all()


def test_m_reduces_dense_flow_error():
    """The point of M: error vs the DENSE data flow shrinks (Sec. 4)."""
    _, w, xo, xu, u, vt = make_problem(noise=0.5)
    st = CalibStats(40, 48)
    st.update_inputs(w, xo, xu, lam=0.25)
    u2, vt2 = reconstruct_uv(w, u, vt, st, update_v=True)
    before = np.linalg.norm(w @ xo.T - (u @ vt) @ xu.T)
    after = np.linalg.norm(w @ xo.T - (u2 @ vt2) @ xu.T)
    assert after < before


def test_whitening_beats_vanilla_on_calibration_loss():
    rng = np.random.default_rng(3)
    n, m, r, N = 32, 48, 8, 500
    cov_half = rng.normal(size=(n, n)) / np.sqrt(n)
    x = (cov_half @ rng.normal(size=(n, N)))
    w = rng.normal(size=(m, n))
    u1, v1 = svd_lowrank(w, r)
    u2, v2 = whitened_svd(w, x @ x.T, r)
    e_plain = np.linalg.norm(w @ x - (u1 @ v1) @ x)
    e_white = np.linalg.norm(w @ x - (u2 @ v2) @ x)
    assert e_white <= e_plain + 1e-9


def test_stats_count_and_shapes():
    st = CalibStats(10, 20)
    st.update(np.ones((5, 10)), np.ones((5, 20)))
    st.update(np.ones((3, 10)), np.ones((3, 20)))
    assert st.count == 8
    assert st.xxt.shape == (10, 10)
    assert st.ytxt.shape == (20, 10)
    np.testing.assert_allclose(st.xxt, 8 * np.ones((10, 10)))
