"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # clean container: parametrized fallback below
    HAVE_HYPOTHESIS = False

from repro.kernels.lowrank_matmul.ops import lowrank_matmul, matmul
from repro.kernels.lowrank_matmul.ref import lowrank_matmul_ref, matmul_ref
from repro.kernels.pifa_matmul.ops import pifa_matmul
from repro.kernels.pifa_matmul.ref import pifa_layer_ref, pifa_matmul_ref
from repro.kernels.ssd_scan.ops import ssd_scan


def _rel_err(a, b):
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    return np.abs(a - b).max() / (np.abs(b).max() + 1e-9)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 5e-6),
                                       (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("shape", [(256, 256, 128, 128),
                                   (130, 200, 96, 160),
                                   (17, 100, 40, 60),
                                   (64, 512, 256, 384)])
def test_pifa_kernel_matches_ref(shape, dtype, tol):
    b, n, r, mnp = shape
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, n)), dtype)
    wp = jnp.asarray(rng.normal(size=(r, n)) / np.sqrt(n), dtype)
    c = jnp.asarray(rng.normal(size=(mnp, r)) / np.sqrt(r), dtype)
    y = pifa_matmul(x, wp, c, interpret=True, use_kernel=True)
    yref = pifa_matmul_ref(x, wp, c)
    assert _rel_err(y, yref) < tol


def test_pifa_kernel_with_gather():
    rng = np.random.default_rng(1)
    b, n, r, mnp = 32, 64, 16, 24
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(r, n)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(mnp, r)), jnp.float32)
    inv = jnp.asarray(np.random.default_rng(2).permutation(r + mnp),
                      jnp.int32)
    y = pifa_matmul(x, wp, c, inv, interpret=True)
    yref = pifa_layer_ref(x, wp, c, inv)
    assert _rel_err(y, yref) < 1e-5


def test_pifa_kernel_leading_dims():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(2, 7, 48)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(16, 48)), jnp.float32)
    c = jnp.asarray(rng.normal(size=(20, 16)), jnp.float32)
    y = pifa_matmul(x, wp, c, interpret=True)
    assert y.shape == (2, 7, 36)
    yref = pifa_matmul_ref(x.reshape(-1, 48), wp, c).reshape(2, 7, 36)
    assert _rel_err(y, yref) < 1e-5


def _check_pifa_kernel_case(b, n, r, mnp):
    rng = np.random.default_rng(b * 7 + n)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    wp = jnp.asarray(rng.normal(size=(r, n)) / np.sqrt(n), jnp.float32)
    c = jnp.asarray(rng.normal(size=(mnp, r)) / np.sqrt(r), jnp.float32)
    y = pifa_matmul(x, wp, c, interpret=True)
    assert _rel_err(y, pifa_matmul_ref(x, wp, c)) < 1e-4


if HAVE_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(b=st.integers(1, 80), n=st.integers(4, 160), r=st.integers(2, 64),
           mnp=st.integers(2, 96))
    def test_pifa_kernel_property(b, n, r, mnp):
        _check_pifa_kernel_case(b, n, r, mnp)


_PIFA_RNG = np.random.default_rng(11)
_PIFA_CASES = [(1, 4, 2, 2), (80, 160, 64, 96), (1, 160, 2, 96)] + [
    (int(_PIFA_RNG.integers(1, 81)), int(_PIFA_RNG.integers(4, 161)),
     int(_PIFA_RNG.integers(2, 65)), int(_PIFA_RNG.integers(2, 97)))
    for _ in range(9)]


@pytest.mark.parametrize("b,n,r,mnp", _PIFA_CASES)
def test_pifa_kernel_sweep(b, n, r, mnp):
    _check_pifa_kernel_case(b, n, r, mnp)


@pytest.mark.parametrize("dims", [(64, 96, 80), (128, 128, 128),
                                  (33, 250, 70)])
def test_matmul_kernel(dims):
    b, n, m = dims
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    assert _rel_err(matmul(x, w, interpret=True), matmul_ref(x, w)) < 1e-5


def test_lowrank_two_stage():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(70, 200)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(150, 48)), jnp.float32)
    vt = jnp.asarray(rng.normal(size=(48, 200)), jnp.float32)
    y = lowrank_matmul(x, u, vt, interpret=True)
    assert _rel_err(y, lowrank_matmul_ref(x, u, vt)) < 1e-5


@pytest.mark.parametrize("seq,chunk", [(32, 16), (50, 16), (64, 64)])
def test_ssd_scan_kernel(seq, chunk):
    rng = np.random.default_rng(2)
    b, h, p, n = 2, 3, 8, 4
    x = jnp.asarray(rng.normal(size=(b, seq, h, p)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, seq, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, seq, n)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, seq, h))) * 0.1, jnp.float32)
    da = -0.5 * dt
    yk, hk = ssd_scan(x, bm, cm, dt, da, chunk=chunk, interpret=True,
                      use_kernel=True)
    yr, hr = ssd_scan(x, bm, cm, dt, da, chunk=chunk, use_kernel=False)
    assert _rel_err(yk, yr) < 1e-5
    assert _rel_err(hk, hr) < 1e-5


def test_ssd_scan_bf16():
    rng = np.random.default_rng(3)
    b, seq, h, p, n = 1, 32, 2, 4, 4
    x = jnp.asarray(rng.normal(size=(b, seq, h, p)), jnp.bfloat16)
    bm = jnp.asarray(rng.normal(size=(b, seq, n)), jnp.bfloat16)
    cm = jnp.asarray(rng.normal(size=(b, seq, n)), jnp.bfloat16)
    dt = jnp.asarray(np.abs(rng.normal(size=(b, seq, h))) * 0.1, jnp.float32)
    da = -0.5 * dt
    yk, _ = ssd_scan(x, bm, cm, dt, da, chunk=16, interpret=True)
    yr, _ = ssd_scan(x, bm, cm, dt, da, chunk=16, use_kernel=False)
    assert _rel_err(yk, yr) < 3e-2
