"""Cross-family serving conformance matrix.

{transformer, encdec, mamba2, hybrid} x {dense, PIFA, MPIFA_NS} x
{engine scan, scheduler continuous, speculative engine, speculative
scheduler slots, PAGED scheduler, prefix-sharing scheduler}: greedy
token BIT-identity everywhere the combo is supported, and a LOUD
refusal (never a silent skip or fallback) where it is not — the
scheduler serves token-prompt families, so encdec x scheduler raises,
and ring-cache archs (gemma3) refuse ``cache="paged"`` (their circular
writes overwrite history in place).

The ``paged_scheduler`` column runs the SAME request mix through both
cache modes at one page-aligned ``cache_len`` and asserts the paged
run equals the contiguous run request-for-request (token arrays, not
just the engine reference) — the block-table refactor must be
invisible in the output.  The ``preempt_scheduler`` column forces an
eviction at a chunk boundary (paged save/restore, ISSUE 6) and holds
the same engine-reference bit-identity: preemption must be invisible
too.  The ``prefix_scheduler`` column serves two requests sharing a
page-aligned prompt prefix through ``prefix_cache=True``: attention
families must actually HIT (the second admission maps the first's
indexed pages and prefills only its tail), conv/SSM-bearing families
must not share at all (their prompt state is not positional), and
every stream must still equal the independent batch-1 engine run
bit-for-bit — shared pages are an addressing detail, never a value
change.

The reference stream for every (family, compression) cell is the
single-dispatch engine's batch-1 greedy generation; the engine cell
itself is checked against an independent per-token prefill/decode
loop, so no runtime is compared only against itself.  Compressed
params for non-transformer families come from the family-agnostic
PIFA walker (``launch/serve.compress_generic``); the transformer cells
reuse the calibrated MPIFA fixtures from conftest.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_smoke_config
from repro.launch.serve import compress_generic
from repro.models.model import build_model
from repro.runtime.engine import GenerationEngine
from repro.runtime.scheduler import FaultPlan, Request, ServingScheduler

FAMILIES = ("transformer", "encdec", "mamba2", "hybrid")
COMPRESSIONS = ("dense", "pifa", "ns")
RUNTIMES = ("engine", "scheduler", "spec_engine", "spec_scheduler",
            "paged_scheduler", "preempt_scheduler", "prefix_scheduler")
# combos that must REFUSE loudly (asserted below, never skipped):
# enc-dec prefill needs frames, which the token-queue scheduler cannot
# carry — all scheduler runtimes raise at construction.
UNSUPPORTED = {("encdec", "scheduler"), ("encdec", "spec_scheduler"),
               ("encdec", "paged_scheduler"),
               ("encdec", "preempt_scheduler"),
               ("encdec", "prefix_scheduler")}
PAGE_SIZE = 4

ARCHS = {"encdec": "whisper_medium", "mamba2": "mamba2_2p7b",
         "hybrid": "zamba2_1p2b"}
MAX_NEW = 6
LENS = (6, 9)          # two requests per scheduler cell
BUDGETS = (6, 5)
SPEC_K = 2


class _Zoo:
    """Lazy per-(family, compression) model/param/reference cache so 48
    matrix cells share builds and compiles."""

    def __init__(self, tiny, tiny_pifa, tiny_ns, tiny_draft):
        self._tiny = tiny
        self._tiny_params = {"dense": tiny[2], "pifa": tiny_pifa,
                             "ns": tiny_ns}
        self._tiny_draft = tiny_draft
        self._base = {}
        self._params = {}
        self._draft = {}
        self._eng = {}
        self._ref = {}
        self._frames = {}

    def base(self, family):
        if family == "transformer":
            return self._tiny[0], self._tiny[1]
        if family not in self._base:
            cfg = get_smoke_config(ARCHS[family])
            self._base[family] = (cfg, build_model(cfg))
        return self._base[family]

    def engine(self, family):
        if family not in self._eng:
            self._eng[family] = GenerationEngine(self.base(family)[1])
        return self._eng[family]

    def params_for(self, family, comp):
        if family == "transformer":
            return self._tiny_params[comp]
        key = (family, comp)
        if key not in self._params:
            cfg, model = self.base(family)
            if comp == "dense":
                p = model.init(jax.random.PRNGKey(0))
            elif comp == "pifa":
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)),
                                     0.6)
            else:  # ns: heterogeneous per-block densities
                p = compress_generic(model,
                                     model.init(jax.random.PRNGKey(0)),
                                     0.6, per_block=(0.45, 0.7))
            self._params[key] = p
        return self._params[key]

    def draft_for(self, family):
        if family == "transformer":
            return self._tiny_draft
        if family not in self._draft:
            cfg, model = self.base(family)
            self._draft[family] = compress_generic(
                model, model.init(jax.random.PRNGKey(0)), 0.45)
        return self._draft[family]

    def prompt(self, family, ln):
        cfg, _ = self.base(family)
        rng = np.random.default_rng(100 + ln)
        return jnp.asarray(rng.integers(0, cfg.vocab_size, (1, ln)),
                           jnp.int32)

    def prefill_inputs(self, family, ln):
        """Enc-dec prefill needs frames alongside the tokens."""
        if family != "encdec":
            return None
        cfg, _ = self.base(family)
        if ln not in self._frames:
            rng = np.random.default_rng(7)
            frames = jnp.asarray(
                rng.normal(size=(1, cfg.encoder_seq, cfg.d_model)) * 0.1,
                jnp.float32)
            self._frames[ln] = {"frames": frames,
                                "tokens": self.prompt(family, ln)}
        return self._frames[ln]

    def ref_tokens(self, family, comp, ln, budget):
        """Reference stream: batch-1 engine greedy generation."""
        key = (family, comp, ln, budget)
        if key not in self._ref:
            res = self.engine(family).generate(
                self.params_for(family, comp), self.prompt(family, ln),
                budget, prefill_inputs=self.prefill_inputs(family, ln))
            self._ref[key] = np.asarray(res.tokens[0])
        return self._ref[key]


@pytest.fixture(scope="module")
def zoo(tiny, tiny_pifa, tiny_ns, tiny_draft):
    return _Zoo(tiny, tiny_pifa, tiny_ns, tiny_draft)


def _legacy_tokens(zoo, family, comp, ln, budget):
    """Independent per-token greedy loop (jitted prefill + decode_step
    re-dispatched from Python) — the engine cell's cross-check."""
    cfg, model = zoo.base(family)
    params = zoo.params_for(family, comp)
    rp = (model.restack_blocks(params, pad=True, max_buckets=4)
          if hasattr(model, "restack_blocks") else params)
    if rp is None:
        raise AssertionError("restack failed for legacy loop")
    prompt = zoo.prompt(family, ln)
    pf_in = zoo.prefill_inputs(family, ln)
    cache = model.init_cache(1, ln + budget + 1, dtype=jnp.float32)
    logits, cache = jax.jit(model.prefill)(
        rp, prompt if pf_in is None else pf_in, cache)
    decode = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    out = [prompt, tok]
    for _ in range(budget - 1):
        logits, cache = decode(rp, tok, cache)
        tok = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        out.append(tok)
    return np.asarray(jnp.concatenate(out, axis=1)[0])


def _run_scheduler(zoo, family, comp, speculative, **extra):
    cfg, model = zoo.base(family)
    params = zoo.params_for(family, comp)
    reqs = [Request(request_id=i,
                    prompt=np.asarray(zoo.prompt(family, ln)[0]),
                    max_new=budget)
            for i, (ln, budget) in enumerate(zip(LENS, BUDGETS))]
    kw = {}
    if speculative:
        kw = dict(draft_params=zoo.draft_for(family), spec_k=SPEC_K)
    kw.update(extra)
    sched = ServingScheduler(model, params, capacity=2, chunk=2,
                             prompt_buckets=(16,), **kw)
    return sched.run(reqs)


@pytest.mark.parametrize("runtime", RUNTIMES)
@pytest.mark.parametrize("comp", COMPRESSIONS)
@pytest.mark.parametrize("family", FAMILIES)
def test_greedy_conformance(zoo, family, comp, runtime):
    """Every supported (family, compression, runtime) cell emits the
    reference greedy stream bit-for-bit; unsupported cells raise."""
    if (family, runtime) in UNSUPPORTED:
        kw = {}
        if runtime in ("paged_scheduler", "preempt_scheduler",
                       "prefix_scheduler"):
            kw["cache"] = "paged"
        if runtime == "prefix_scheduler":
            kw["prefix_cache"] = True
        with pytest.raises(ValueError, match="frames"):
            _run_scheduler(zoo, family, comp,
                           speculative=runtime == "spec_scheduler", **kw)
        return

    if runtime == "engine":
        ref = zoo.ref_tokens(family, comp, LENS[0], BUDGETS[0])
        legacy = _legacy_tokens(zoo, family, comp, LENS[0], BUDGETS[0])
        assert np.array_equal(ref, legacy), (
            f"{family}/{comp}: engine diverged from per-token loop")
        return

    if runtime == "spec_engine":
        ln, budget = LENS[0], BUDGETS[0]
        ref = zoo.ref_tokens(family, comp, ln, budget)
        res = zoo.engine(family).generate_speculative(
            zoo.params_for(family, comp), zoo.draft_for(family),
            zoo.prompt(family, ln), budget, spec_k=SPEC_K,
            prefill_inputs=zoo.prefill_inputs(family, ln))
        assert np.array_equal(np.asarray(res.tokens[0]), ref), (
            f"{family}/{comp}: speculative engine diverged")
        assert res.rounds >= 1
        return

    if runtime == "prefix_scheduler":
        # two prompts sharing a 2-page-aligned prefix, capacity 1 so
        # the second admission arrives AFTER the first's pages are
        # indexed: attention families must map them shared (a real
        # prefix hit), conv/SSM-bearing families must refuse to share
        # (their prompt state is not positional KV), and both streams
        # must equal the independent engine run bit-for-bit
        cfgf, model = zoo.base(family)
        params = zoo.params_for(family, comp)
        rng = np.random.default_rng(5)
        shared = rng.integers(0, cfgf.vocab_size, 2 * PAGE_SIZE)
        prompts = [np.concatenate(
            [shared, rng.integers(0, cfgf.vocab_size, t)]).astype(np.int32)
            for t in (3, 5)]
        cache_len = 16 + max(BUDGETS) + PAGE_SIZE
        cache_len -= cache_len % PAGE_SIZE
        sched = ServingScheduler(model, params, capacity=1, chunk=2,
                                 prompt_buckets=(16,), cache="paged",
                                 page_size=PAGE_SIZE, cache_len=cache_len,
                                 num_pages=16, prefix_cache=True)
        run = sched.run([Request(request_id=i, prompt=p, max_new=b)
                         for i, (p, b) in enumerate(zip(prompts, BUDGETS))])
        if family == "transformer":
            assert run.prefix_hits >= 1, (
                f"{family}/{comp}: second admission missed the shared "
                "prefix")
        else:
            assert run.prefix_hits == 0, (
                f"{family}/{comp}: conv/SSM prompt state must never "
                "be shared")
        assert sorted(r.request_id for r in run.results) == [0, 1]
        for r in run.results:
            ref = np.asarray(zoo.engine(family).generate(
                params, jnp.asarray(prompts[r.request_id][None, :]),
                BUDGETS[r.request_id]).tokens[0])
            n = r.prompt_len + r.generated
            assert np.array_equal(r.tokens[:n], ref[:n]), (
                f"{family}/{comp}/prefix: request {r.request_id} "
                "diverged from the engine reference")
        if sched._prefix is not None:
            sched._prefix.drop()
            assert sched._alloc.free_pages == sched.num_pages
        return

    if runtime == "paged_scheduler":
        # the paged cell runs BOTH cache modes at one page-aligned
        # cache_len: the block-table addressing must be invisible —
        # request-for-request token equality against the contiguous
        # scheduler cell, plus the usual engine-reference identity
        cache_len = 16 + max(BUDGETS) + SPEC_K + PAGE_SIZE
        cache_len -= cache_len % PAGE_SIZE
        run_c = _run_scheduler(zoo, family, comp, speculative=False,
                               cache_len=cache_len)
        run_p = _run_scheduler(zoo, family, comp, speculative=False,
                               cache="paged", page_size=PAGE_SIZE,
                               cache_len=cache_len)
        contig = {r.request_id: r.tokens for r in run_c.results}
        for r in run_p.results:
            assert np.array_equal(r.tokens, contig[r.request_id]), (
                f"{family}/{comp}: paged diverged from contiguous")
        run = run_p
    elif runtime == "preempt_scheduler":
        # forced eviction at boundary 1 + paged save/restore
        # re-admission: the interruption must be invisible — the same
        # engine-reference bit-identity as every other scheduler cell,
        # plus the run must actually have preempted and resumed
        cache_len = 16 + max(BUDGETS) + PAGE_SIZE
        cache_len -= cache_len % PAGE_SIZE
        run = _run_scheduler(zoo, family, comp, speculative=False,
                             cache="paged", page_size=PAGE_SIZE,
                             cache_len=cache_len,
                             preemption="save_restore",
                             fault_plan=FaultPlan().at(1, "preempt", 0))
        assert run.preemptions >= 1 and run.resumes >= 1
    else:
        # scheduler / spec_scheduler: every request bit-identical to
        # the batch-1 engine reference
        run = _run_scheduler(zoo, family, comp,
                             speculative=runtime == "spec_scheduler")
    assert sorted(r.request_id for r in run.results) == [0, 1]
    for r in run.results:
        ln, budget = LENS[r.request_id], BUDGETS[r.request_id]
        ref = zoo.ref_tokens(family, comp, ln, budget)
        n = r.prompt_len + r.generated
        assert r.generated == budget
        assert np.array_equal(r.tokens[:n], ref[:n]), (
            f"{family}/{comp}/{runtime}: request {r.request_id} "
            "diverged from the engine reference")
    if runtime == "spec_scheduler":
        assert run.drafted > 0


def test_paged_refuses_ring_arch():
    """The paged column's ring cell: gemma3-style local:global archs
    keep their windowed circular buffers and refuse ``cache="paged"``
    loudly (never a silent contiguous fallback)."""
    cfg = get_smoke_config("gemma3_12b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="ring"):
        ServingScheduler(model, params, cache="paged")


def test_matrix_covers_issue_floor():
    """The acceptance bar asks for >= 30 parametrized cases (the
    prefix_scheduler column grows the matrix to 4 x 3 x 7 = 84)."""
    assert len(FAMILIES) * len(COMPRESSIONS) * len(RUNTIMES) >= 30
