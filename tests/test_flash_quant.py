"""Flash-attention kernel + quantized-PIFA composition."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pifa import pivoting_factorize
from repro.core.quantize import (apply_linear_q8, dequantize_pifa,
                                 q8_param_bytes, quantize_pifa)
from repro.kernels.flash_attention.ops import flash_attention
from repro.models.linear import apply_linear, pifa_linear
import repro.models.layers as L


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 9),
                                           (False, 0)])
@pytest.mark.parametrize("shape", [(2, 37, 53, 8, 4, 16),
                                   (1, 128, 128, 2, 2, 32)])
def test_flash_kernel_matches_mha(shape, causal, window):
    b, sq, sk, h, hkv, d = shape
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, hkv, d)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    win = jnp.int32(window) if window else None
    ref = L.mha(q, k, v, causal=causal, window=win)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_flash_kernel_vs_own_ref_padding():
    rng = np.random.default_rng(1)
    b, sq, sk, h, d = 1, 50, 70, 3, 8  # deliberately non-multiples
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, sk, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, sk, h, d)), jnp.float32)
    out = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    ref = flash_attention(q, k, v, use_kernel=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_quantized_pifa_roundtrip_and_apply():
    rng = np.random.default_rng(2)
    m, n, r = 96, 80, 32
    w = rng.normal(size=(m, r)) @ rng.normal(size=(r, n)) / np.sqrt(n)
    f = pivoting_factorize(w, r)
    p = pifa_linear(f, dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(6, n)), jnp.float32)
    y_ref = apply_linear(p, x)

    q = quantize_pifa(p)
    y_q = apply_linear_q8(q, x)
    rel = float(jnp.abs(y_q - y_ref).max() / (jnp.abs(y_ref).max() + 1e-9))
    assert rel < 0.05          # int8 rounding only

    # dequantized params run through the standard dispatch
    y_dq = apply_linear(dequantize_pifa(q), x)
    np.testing.assert_allclose(np.asarray(y_dq), np.asarray(y_q),
                               rtol=1e-5, atol=1e-5)

    # byte accounting: ~1 byte/param + scales + int32 perm
    fp_bytes = p["wp"].size * 4 + p["c"].size * 4 + p["inv_perm"].size * 4
    assert q8_param_bytes(q) < 0.45 * fp_bytes
