"""Data pipeline: determinism, exact resume, shard disjointness."""
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLM, TokenPipeline


def test_deterministic_batches():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=4, seed=7)
    a = TokenPipeline(cfg).batch_at(5)
    b = TokenPipeline(cfg).batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=128, seq_len=32, global_batch=2)
    b = TokenPipeline(cfg).batch_at(0)
    # label[t] is the next token of the same stream
    assert b["tokens"].shape == b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_resume_replays_exact_batch():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=2)
    pipe = TokenPipeline(cfg)
    seen = [next(pipe)["tokens"].copy() for _ in range(4)]
    state = pipe.state_dict()
    more = [next(pipe)["tokens"].copy() for _ in range(3)]

    pipe2 = TokenPipeline(cfg)
    pipe2.load_state_dict(state)
    replay = [next(pipe2)["tokens"].copy() for _ in range(3)]
    for a, b in zip(more, replay):
        np.testing.assert_array_equal(a, b)


def test_shards_are_disjoint_and_cover_global_batch():
    base = dict(vocab_size=64, seq_len=16, global_batch=8, seed=3)
    full = TokenPipeline(DataConfig(**base)).batch_at(2)["tokens"]
    parts = []
    for sid in range(4):
        cfg = DataConfig(num_shards=4, shard_id=sid, **base)
        parts.append(TokenPipeline(cfg).batch_at(2)["tokens"])
    stacked = np.concatenate(parts, axis=0)
    np.testing.assert_array_equal(stacked, full)


def test_markov_source_has_learnable_structure():
    src = SyntheticLM(64, seed=0)
    floor = src.entropy_floor()
    assert 0.3 < floor < np.log(64)  # far below uniform entropy
    rng = np.random.default_rng(0)
    toks = src.sample(rng, 2000)
    # empirical bigram entropy should be near the analytic floor, and far
    # from the unigram entropy (i.e. context helps => a model can learn)
    assert len(np.unique(toks)) > 10
