"""Permutation folding (beyond-paper, core/folding.py) is lossless."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.folding import fold_mlp
from repro.core.pifa import pivoting_factorize
from repro.models.layers import mlp_block
from repro.models.linear import dense_linear, pifa_linear, lowrank_linear


def _pifa_lin(rng, m, n, r, bias=False):
    w = rng.normal(size=(m, r)) @ rng.normal(size=(r, n)) / np.sqrt(n)
    f = pivoting_factorize(w, r)
    b = jnp.asarray(rng.normal(size=(m,)), jnp.float32) if bias else None
    return pifa_linear(f, bias=b, dtype=jnp.float32)


@pytest.mark.parametrize("gated", [False, True])
@pytest.mark.parametrize("bias", [False, True])
def test_fold_mlp_equivalence(gated, bias):
    rng = np.random.default_rng(0)
    d, ff, r = 32, 48, 12
    up = _pifa_lin(rng, ff, d, r, bias=bias)
    down = _pifa_lin(rng, d, ff, r, bias=bias)
    gate = _pifa_lin(rng, ff, d, r, bias=bias) if gated else None

    mlp = {"up": up, "down": down}
    if gate is not None:
        mlp["gate"] = gate
    x = jnp.asarray(rng.normal(size=(5, d)), jnp.float32)
    y_ref = mlp_block(mlp, x)

    fup, fdown, fgate = fold_mlp(up, down, gate)
    fm = {"up": fup, "down": fdown}
    if fgate is not None:
        fm["gate"] = fgate
    y_fold = mlp_block(fm, x)
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref),
                               rtol=5e-5, atol=5e-5)
    # the up gather is gone
    assert "inv_perm" not in fup


@pytest.mark.parametrize("down_kind", ["dense", "lowrank", "pifa"])
def test_fold_into_any_consumer(down_kind):
    rng = np.random.default_rng(1)
    d, ff, r = 24, 40, 10
    up = _pifa_lin(rng, ff, d, r)
    if down_kind == "dense":
        down = {"w": jnp.asarray(rng.normal(size=(d, ff)), jnp.float32)}
    elif down_kind == "lowrank":
        down = lowrank_linear(rng.normal(size=(d, 8)),
                              rng.normal(size=(8, ff)), dtype=jnp.float32)
    else:
        down = _pifa_lin(rng, d, ff, 8)
    x = jnp.asarray(rng.normal(size=(3, d)), jnp.float32)
    y_ref = mlp_block({"up": up, "down": down}, x)
    fup, fdown, _ = fold_mlp(up, down, None)
    y_fold = mlp_block({"up": fup, "down": fdown}, x)
    np.testing.assert_allclose(np.asarray(y_fold), np.asarray(y_ref),
                               rtol=5e-5, atol=5e-5)


def test_fold_noop_for_dense_up():
    rng = np.random.default_rng(2)
    up = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    down = {"w": jnp.asarray(rng.normal(size=(4, 8)), jnp.float32)}
    fup, fdown, fgate = fold_mlp(up, down, None)
    assert fup is up and fdown is down and fgate is None
