"""AdamW, schedules, gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.adamw import AdamW, global_norm
from repro.optim.compression import Int8Compressor, PowerSGDCompressor
from repro.optim.schedule import warmup_cosine, warmup_linear


def test_adamw_minimizes_quadratic():
    optim = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = optim.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda q: jnp.sum(q["x"] ** 2))(p)
        upd, s = optim.update(g, s, p)
        return jax.tree.map(lambda a, b: a + b, p, upd), s

    for _ in range(200):
        params, state = step(params, state)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_norm_bounds_update():
    optim = AdamW(lr=1.0, clip_norm=1e-6)
    params = {"x": jnp.zeros(4)}
    state = optim.init(params)
    g = {"x": jnp.full((4,), 1e6)}
    upd, _ = optim.update(g, state, params)
    # first-step Adam update magnitude is ~lr regardless, but the moment
    # buffers must only have seen the clipped gradient
    assert float(global_norm({"x": state.m["x"]})) == 0.0


def test_schedules_shapes():
    s = warmup_cosine(1e-3, 10, 100)
    assert float(s(jnp.int32(0))) == 0.0
    assert float(s(jnp.int32(10))) == pytest.approx(1e-3, rel=1e-5)
    assert float(s(jnp.int32(100))) == pytest.approx(1e-4, rel=1e-2)
    lin = warmup_linear(1e-3, 10, 100)
    assert float(lin(jnp.int32(55))) == pytest.approx(5e-4, rel=1e-2)


def test_int8_error_feedback_reduces_bias():
    """With error feedback, the AVERAGE quantized gradient over many
    steps converges to the true gradient (compression is unbiased in
    the long run)."""
    comp = Int8Compressor()
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(32, 32)),
                          jnp.float32)}
    err = comp.init(g)
    acc = jnp.zeros_like(g["w"])
    n = 50
    for _ in range(n):
        out, err = comp.roundtrip(g, err)
        acc = acc + out["w"]
    mean_err = float(jnp.abs(acc / n - g["w"]).max())
    one_shot, _ = comp.roundtrip(g, comp.init(g))
    one_err = float(jnp.abs(one_shot["w"] - g["w"]).max())
    assert mean_err < one_err  # feedback beats one-shot quantization


def test_int8_wire_is_quarter_of_f32():
    g = {"w": jnp.zeros((64, 64), jnp.float32)}
    assert Int8Compressor.wire_bytes(g) * 4 == 64 * 64 * 4


def test_powersgd_rank_reduces_wire_and_error_feedback_converges():
    comp = PowerSGDCompressor(rank=4)
    rng = np.random.default_rng(1)
    # gradient that IS low-rank: approximation should be near-exact
    g_lr = {"w": jnp.asarray(rng.normal(size=(64, 4)) @
                             rng.normal(size=(4, 48)), jnp.float32)}
    st = comp.init(g_lr)
    out, st = comp.roundtrip(g_lr, st)
    out, st = comp.roundtrip(g_lr, st)  # warm-started Q: second pass better
    rel = (float(jnp.linalg.norm(out["w"] - g_lr["w"]))
           / float(jnp.linalg.norm(g_lr["w"])))
    assert rel < 0.35
    assert comp.wire_bytes(g_lr) < g_lr["w"].size * 4


def test_powersgd_passthrough_vectors():
    comp = PowerSGDCompressor(rank=2)
    g = {"b": jnp.arange(5, dtype=jnp.float32)}
    st = comp.init(g)
    out, _ = comp.roundtrip(g, st)
    np.testing.assert_array_equal(np.asarray(out["b"]), np.arange(5))
